"""Per-architecture smoke tests: reduced same-family configs on CPU.

For each of the 10 assigned archs: instantiate the SMOKE config, run one
forward (train-style) pass and one prefill + decode step, assert output
shapes and absence of NaNs, and check prefill/decode consistency where the
math guarantees it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import build_model


def _inputs(cfg, batch=2, seq=16):
    rng = np.random.default_rng(0)
    kw = {}
    txt_seq = seq
    if cfg.frontend == "vision_patches":
        kw["embeddings"] = jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_tokens, cfg.d_model)),
            jnp.float32).astype(jnp.bfloat16)
        txt_seq = seq - cfg.frontend_tokens
    if cfg.family == "audio":
        kw["encoder_frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_seq, cfg.d_model)),
            jnp.float32).astype(jnp.bfloat16)
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(batch, txt_seq)), jnp.int32)
    return tokens, kw


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch, seq = 2, 16
    tokens, kw = _inputs(cfg, batch, seq)
    logits, aux = jax.jit(
        lambda p, t: model.forward(p, t, **kw))(params, tokens)
    assert logits.shape == (batch, seq, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_then_decode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch, seq, max_len = 2, 16, 32
    tokens, kw = _inputs(cfg, batch, seq)

    cache = model.init_cache(batch, max_len)
    logits, cache = jax.jit(
        lambda p, t, c: model.prefill(p, t, c, **kw))(params, tokens, cache)
    assert logits.shape == (batch, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    step = jax.jit(lambda p, t, c, l: model.decode_step(p, t, c, l))
    logits2, cache = step(params, next_tok, cache, jnp.int32(seq))
    assert logits2.shape == (batch, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    # a second step to exercise cache-carry
    logits3, cache = step(params, jnp.argmax(logits2, -1).astype(jnp.int32),
                          cache, jnp.int32(seq + 1))
    assert bool(jnp.all(jnp.isfinite(logits3.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["qwen3-14b", "minicpm3-4b", "mamba2-130m",
                                  "zamba2-1.2b", "gemma3-12b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits.

    Holds exactly for deterministic paths (dense attention, MLA, SSM);
    checked to ~1e-2 in f32 since decode uses the absorbed/ring formulations.
    """
    cfg = get_smoke_config(arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    batch, seq = 1, 12
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)

    full_logits, _ = model.forward(params, tokens)

    cache = model.init_cache(batch, seq + 4)
    pre_logits, cache = model.prefill(params, tokens[:, :-1], cache)
    # decode position seq-1 given prefix [0, seq-1)
    step_logits, _ = model.decode_step(params, tokens[:, -1], cache,
                                       jnp.int32(seq - 1))
    np.testing.assert_allclose(
        np.asarray(pre_logits), np.asarray(full_logits[:, -2]),
        rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(full_logits[:, -1]),
        rtol=2e-2, atol=2e-2)


def test_vocab_logit_range_vlm():
    cfg = get_smoke_config("llava-next-34b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    tokens, kw = _inputs(cfg, 1, 24)
    logits, _ = model.forward(params, tokens, **kw)
    assert logits.shape[1] == 24  # 16 image + 8 text tokens
