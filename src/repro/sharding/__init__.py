"""Distribution layer: logical-axis sharding rules and pooling strategies."""
from repro.sharding.strategies import Strategy, make_strategy  # noqa: F401
