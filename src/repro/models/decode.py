"""Decode-side programs: cache init, prefill (cache seeding), one-token step.

The cache pytrees defined here are exactly the objects the CrossPool
KV-cache pool holds; their per-layer layouts are what ``hooks.kv`` shards.

Cache layouts (T = max context length in the cache):
  gqa dense/moe/vlm : {"k","v": [L,B,T,KV,hd]}
  mla               : {"latent": [L,B,T,r], "rope": [L,B,T,rp]}
  gemma3 swa        : local ring  {"lk","lv": [G,P-1,B,W,KV,hd], "lpos": [G,P-1,B,W]}
                      global full {"gk","gv": [G,B,T,KV,hd]}
  ssm               : {"h": [L,B,H,Ph,N] f32, "conv": [L,B,Wc-1,conv]}
  hybrid            : ssm stacks + shared-attn {"k","v": [G,B,T,KV,hd]}
  audio             : self {"k","v": [L,B,T,KV,hd]} + static cross
                      {"ck","cv": [L,B,Tenc,KV,hd]}
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers, ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.hooks import Hooks, IDENTITY_HOOKS


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               kv_dtype: Optional[str] = None) -> Dict:
    """``kv_dtype``: None = model dtype; "f8" = fp8-e4m3 KV (halves cache
    memory + per-step KV read bytes; dequantized on-chip at attention)."""
    if kv_dtype == "f8":
        dt = jnp.float8_e4m3fn
    elif kv_dtype is not None:
        dt = jnp.dtype(kv_dtype)
    else:
        dt = _dtype(cfg)
    fam = cfg.family
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim

    if fam in ("dense", "vlm", "moe"):
        if cfg.attention == "mla":
            m = cfg.mla
            return {
                "latent": jnp.zeros((L, batch, max_len, m.kv_lora_rank), dt),
                "rope": jnp.zeros((L, batch, max_len, m.qk_rope_head_dim), dt),
            }
        if cfg.swa_pattern > 0:
            G = cfg.n_layers // cfg.swa_pattern
            P = cfg.swa_pattern
            W = min(cfg.sliding_window, max_len)
            return {
                "lk": jnp.zeros((G, P - 1, batch, W, KV, hd), dt),
                "lv": jnp.zeros((G, P - 1, batch, W, KV, hd), dt),
                "lpos": jnp.full((G, P - 1, batch, W), -1, jnp.int32),
                "gk": jnp.zeros((G, batch, max_len, KV, hd), dt),
                "gv": jnp.zeros((G, batch, max_len, KV, hd), dt),
            }
        return {
            "k": jnp.zeros((L, batch, max_len, KV, hd), dt),
            "v": jnp.zeros((L, batch, max_len, KV, hd), dt),
        }

    if fam == "ssm":
        st = ssm_mod.init_ssm_state(cfg, batch)
        return {
            "h": jnp.zeros((L,) + st["h"].shape, st["h"].dtype),
            "conv": jnp.zeros((L,) + st["conv"].shape, st["conv"].dtype),
        }

    if fam == "hybrid":
        st = ssm_mod.init_ssm_state(cfg, batch)
        G = cfg.hybrid_groups
        n_ssm = G * cfg.ssm_per_group
        c: Dict = {
            "h": jnp.zeros((n_ssm,) + st["h"].shape, st["h"].dtype),
            "conv": jnp.zeros((n_ssm,) + st["conv"].shape, st["conv"].dtype),
            "k": jnp.zeros((G, batch, max_len, KV, hd), dt),
            "v": jnp.zeros((G, batch, max_len, KV, hd), dt),
        }
        if cfg.tail_ssm_layers:
            c["tail_h"] = jnp.zeros((cfg.tail_ssm_layers,) + st["h"].shape,
                                    st["h"].dtype)
            c["tail_conv"] = jnp.zeros((cfg.tail_ssm_layers,) + st["conv"].shape,
                                       st["conv"].dtype)
        return c

    if fam == "audio":
        return {
            "k": jnp.zeros((L, batch, max_len, KV, hd), dt),
            "v": jnp.zeros((L, batch, max_len, KV, hd), dt),
            "ck": jnp.zeros((L, batch, cfg.encoder_seq, KV, hd), dt),
            "cv": jnp.zeros((L, batch, cfg.encoder_seq, KV, hd), dt),
        }

    raise ValueError(f"unknown family {fam}")


def _seed(cache_layer: jax.Array, new: jax.Array) -> jax.Array:
    """Write prefill KV [B,S,...] into cache layer [B,T,...] at offset 0."""
    zeros = (0,) * new.ndim
    return jax.lax.dynamic_update_slice(cache_layer, new.astype(cache_layer.dtype),
                                        zeros)


# ---------------------------------------------------------------------------
# Prefill: full-sequence forward that also seeds the decode cache
# ---------------------------------------------------------------------------

def prefill(params: Dict, cfg: ModelConfig, tokens: jax.Array, cache: Dict, *,
            embeddings: Optional[jax.Array] = None,
            encoder_frames: Optional[jax.Array] = None,
            hooks: Hooks = IDENTITY_HOOKS, impl: str = "xla",
            logit_index=None,
            ) -> Tuple[jax.Array, Dict]:
    """Returns (last-position logits [B,V], seeded cache).

    ``logit_index``: optional traced position whose logits to return instead
    of the last — used when prompts are right-padded to a bucket length
    (the engine's anti-recompile path)."""
    fam = cfg.family
    B = tokens.shape[0]
    S = tokens.shape[1] + (embeddings.shape[1] if embeddings is not None else 0)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    x = tfm.embed_inputs(params, cfg, tokens, embeddings, positions)

    if fam in ("dense", "vlm", "moe"):
        if cfg.swa_pattern > 0:
            x, cache = _prefill_swa(params, cfg, x, positions, cache, hooks, impl)
        elif cfg.attention == "mla":
            def body(xc, ys):
                p_l, c_lat, c_rope = ys
                h = layers.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
                out, (lat, rp) = attn.mla_full(p_l["attn"], cfg, h, positions,
                                               hooks=hooks)
                xc = xc + hooks.act(out)
                xc, _ = tfm._ffn_full(p_l, cfg, xc, hooks)
                return xc, (_seed(c_lat, lat), _seed(c_rope, rp))
            x, (lat, rp) = jax.lax.scan(
                body, x, (params["layers"], cache["latent"], cache["rope"]))
            cache = {"latent": lat, "rope": rp}
        else:
            def body(xc, ys):
                p_l, ck, cv = ys
                xc, (k, v) = tfm._attn_full(p_l, cfg, xc, positions, 0, hooks, impl)
                xc, _ = tfm._ffn_full(p_l, cfg, xc, hooks)
                return xc, (_seed(ck, k), _seed(cv, v))
            x, (k, v) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"]))
            cache = {"k": k, "v": v}

    elif fam == "ssm":
        def body(xc, ys):
            p_l, = ys
            h = layers.rms_norm(xc, p_l["ln"], cfg.norm_eps)
            out, st = ssm_mod.ssm_full(p_l["ssm"], cfg, h, hooks=hooks)
            return xc + hooks.act(out), (st["h"], st["conv"])
        x, (hs, convs) = jax.lax.scan(body, x, (params["layers"],))
        cache = {"h": hs, "conv": convs}

    elif fam == "hybrid":
        x, cache = _prefill_hybrid(params, cfg, x, positions, cache, hooks, impl)

    elif fam == "audio":
        enc_out = tfm.encode(params, cfg, encoder_frames, hooks=hooks)

        def body(xc, ys):
            p_l, ck, cv, cck, ccv = ys
            h = layers.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
            out, (k, v) = attn.gqa_full(p_l["self"], cfg, h, positions,
                                        hooks=hooks, impl=impl)
            xc = xc + hooks.act(out)
            kx, vx = tfm._cross_kv(p_l["cross"], cfg, enc_out)
            h = layers.rms_norm(xc, p_l["ln2"], cfg.norm_eps)
            out, _ = attn.gqa_full(p_l["cross"], cfg, h, positions,
                                   kv_override=(kx, vx), causal=False,
                                   hooks=hooks)
            xc = xc + hooks.act(out)
            h = layers.rms_norm(xc, p_l["ln3"], cfg.norm_eps)
            h = hooks.boundary_in(h)
            f = layers.apply_mlp(p_l["mlp"], h, cfg.mlp_kind,
                                 hook=hooks.ffn_hidden)
            xc = xc + hooks.act(hooks.boundary_out(f))
            return xc, (_seed(ck, k), _seed(cv, v), _seed(cck, kx), _seed(ccv, vx))

        x, (k, v, ck, cv) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["ck"], cache["cv"]))
        cache = {"k": k, "v": v, "ck": ck, "cv": cv}
    else:
        raise ValueError(f"unknown family {fam}")

    if logit_index is None:
        x_last = x[:, -1:, :]
    else:
        x_last = jax.lax.dynamic_slice_in_dim(x, logit_index, 1, axis=1)
    logits = tfm._logits(params, cfg, x_last, hooks)[:, 0]
    return logits, cache


def _prefill_swa(params, cfg, x, positions, cache, hooks, impl):
    """gemma3: groups of (P-1 local ring layers + 1 global layer)."""
    G, P = cfg.n_layers // cfg.swa_pattern, cfg.swa_pattern
    S = x.shape[1]
    W = cache["lk"].shape[3]
    grouped = jax.tree.map(
        lambda a: a.reshape(G, P, *a.shape[1:]), params["layers"])
    local_p = jax.tree.map(lambda a: a[:, : P - 1], grouped)
    global_p = jax.tree.map(lambda a: a[:, P - 1], grouped)

    # static ring layout: slot w holds the latest position p with p % W == w
    slot_pos = np.array([S - 1 - ((S - 1 - w) % W) for w in range(W)])
    slot_valid = slot_pos >= max(0, S - W)
    slot_pos = np.where(slot_valid, slot_pos, -1)
    gather_idx = jnp.asarray(np.maximum(slot_pos, 0))
    ring_pos = jnp.broadcast_to(jnp.asarray(slot_pos)[None, :], (x.shape[0], W))

    def local_body(xc, ys):
        p_l, lk, lv, lpos = ys
        xc, (k, v) = tfm._attn_full(p_l, cfg, xc, positions,
                                    cfg.sliding_window, hooks, impl)
        xc, _ = tfm._ffn_full(p_l, cfg, xc, hooks)
        rk = jnp.where(ring_pos[..., None, None] >= 0,
                       k[:, gather_idx].astype(lk.dtype), lk)
        rv = jnp.where(ring_pos[..., None, None] >= 0,
                       v[:, gather_idx].astype(lv.dtype), lv)
        return xc, (rk, rv, ring_pos.astype(lpos.dtype))

    def group_body(xc, ys):
        g_local, g_global, lk, lv, lpos, gk, gv = ys
        xc, (rk, rv, rp) = jax.lax.scan(local_body, xc, (g_local, lk, lv, lpos))
        xc, (k, v) = tfm._attn_full(g_global, cfg, xc, positions, 0, hooks, impl)
        xc, _ = tfm._ffn_full(g_global, cfg, xc, hooks)
        return xc, (rk, rv, rp, _seed(gk, k), _seed(gv, v))

    x, (lk, lv, lpos, gk, gv) = jax.lax.scan(
        group_body, x,
        (local_p, global_p, cache["lk"], cache["lv"], cache["lpos"],
         cache["gk"], cache["gv"]))
    return x, {"lk": lk, "lv": lv, "lpos": lpos, "gk": gk, "gv": gv}


def _prefill_hybrid(params, cfg, x, positions, cache, hooks, impl):
    G, per = cfg.hybrid_groups, cfg.ssm_per_group

    def ssm_body(xc, ys):
        p_l, = ys
        h = layers.rms_norm(xc, p_l["ln"], cfg.norm_eps)
        out, st = ssm_mod.ssm_full(p_l["ssm"], cfg, h, hooks=hooks)
        return xc + hooks.act(out), (st["h"], st["conv"])

    grouped = jax.tree.map(
        lambda a: a.reshape(G, per, *a.shape[1:]), params["layers"])

    def group_body(xc, ys):
        g_params, ck, cv = ys
        xc, (hs, convs) = jax.lax.scan(ssm_body, xc, (g_params,))
        xc, (k, v) = tfm._attn_full(params["shared_block"], cfg, xc, positions,
                                    0, hooks, impl)
        xc, _ = tfm._ffn_full(params["shared_block"], cfg, xc, hooks)
        return xc, (hs, convs, _seed(ck, k), _seed(cv, v))

    x, (hs, convs, k, v) = jax.lax.scan(
        group_body, x, (grouped, cache["k"], cache["v"]))
    new = {
        "h": hs.reshape(G * per, *hs.shape[2:]),
        "conv": convs.reshape(G * per, *convs.shape[2:]),
        "k": k, "v": v,
    }
    if cfg.tail_ssm_layers:
        x, (th, tc) = jax.lax.scan(ssm_body, x, (params["tail"],))
        new["tail_h"], new["tail_conv"] = th, tc
    return x, new


# ---------------------------------------------------------------------------
# One-token decode step
# ---------------------------------------------------------------------------

def decode_step(params: Dict, cfg: ModelConfig, tokens: jax.Array, cache: Dict,
                lengths, *, hooks: Hooks = IDENTITY_HOOKS, impl: str = "xla",
                ) -> Tuple[jax.Array, Dict]:
    """tokens: [B] next-token ids; lengths: scalar or [B] current context
    length.  Returns (logits [B,V], updated cache)."""
    fam = cfg.family
    B = tokens.shape[0]
    pos = (jnp.broadcast_to(jnp.asarray(lengths), (B,))[:, None]
           if jnp.ndim(lengths) > 0 else jnp.full((B, 1), lengths, jnp.int32))
    x = tfm.embed_inputs(params, cfg, tokens[:, None], None,
                         pos if cfg.rope_theta == 0 else None)

    if fam in ("dense", "vlm", "moe"):
        if cfg.swa_pattern > 0:
            x, cache = _decode_swa(params, cfg, x, cache, lengths, hooks)
        elif cfg.attention == "mla":
            def body(xc, ys):
                p_l, c_lat, c_rope = ys
                h = layers.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
                out, c_lat, c_rope = attn.mla_decode(
                    p_l["attn"], cfg, h, c_lat, c_rope, lengths, hooks=hooks)
                xc = xc + hooks.act(out)
                xc, _ = tfm._ffn_full(p_l, cfg, xc, hooks)
                return xc, (c_lat, c_rope)
            x, (lat, rp) = jax.lax.scan(
                body, x, (params["layers"], cache["latent"], cache["rope"]))
            cache = {"latent": lat, "rope": rp}
        else:
            def body(xc, ys):
                p_l, ck, cv = ys
                h = layers.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
                out, ck, cv = attn.gqa_decode(p_l["attn"], cfg, h, ck, cv,
                                              lengths, hooks=hooks, impl=impl)
                xc = xc + hooks.act(out)
                xc, _ = tfm._ffn_full(p_l, cfg, xc, hooks)
                return xc, (ck, cv)
            x, (k, v) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"]))
            cache = {"k": k, "v": v}

    elif fam == "ssm":
        def body(xc, ys):
            p_l, h_st, conv_st = ys
            h = layers.rms_norm(xc, p_l["ln"], cfg.norm_eps)
            out, st = ssm_mod.ssm_decode(p_l["ssm"], cfg, h,
                                         {"h": h_st, "conv": conv_st},
                                         hooks=hooks)
            return xc + hooks.act(out), (st["h"], st["conv"])
        x, (hs, convs) = jax.lax.scan(
            body, x, (params["layers"], cache["h"], cache["conv"]))
        cache = {"h": hs, "conv": convs}

    elif fam == "hybrid":
        x, cache = _decode_hybrid(params, cfg, x, cache, lengths, hooks, impl)

    elif fam == "audio":
        def body(xc, ys):
            p_l, ck, cv, cck, ccv = ys
            h = layers.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
            out, ck, cv = attn.gqa_decode(p_l["self"], cfg, h, ck, cv, lengths,
                                          hooks=hooks, impl=impl)
            xc = xc + hooks.act(out)
            h = layers.rms_norm(xc, p_l["ln2"], cfg.norm_eps)
            out, _ = attn.gqa_full(p_l["cross"], cfg, h, pos,
                                   kv_override=(cck, ccv), causal=False,
                                   hooks=hooks)
            xc = xc + hooks.act(out)
            h = layers.rms_norm(xc, p_l["ln3"], cfg.norm_eps)
            h = hooks.boundary_in(h)
            f = layers.apply_mlp(p_l["mlp"], h, cfg.mlp_kind,
                                 hook=hooks.ffn_hidden)
            xc = xc + hooks.act(hooks.boundary_out(f))
            return xc, (ck, cv)
        x, (k, v) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["ck"], cache["cv"]))
        cache = {"k": k, "v": v, "ck": cache["ck"], "cv": cache["cv"]}
    else:
        raise ValueError(f"unknown family {fam}")

    logits = tfm._logits(params, cfg, x, hooks)[:, 0]
    return logits, cache


def _decode_swa(params, cfg, x, cache, lengths, hooks):
    G, P = cfg.n_layers // cfg.swa_pattern, cfg.swa_pattern
    grouped = jax.tree.map(
        lambda a: a.reshape(G, P, *a.shape[1:]), params["layers"])
    local_p = jax.tree.map(lambda a: a[:, : P - 1], grouped)
    global_p = jax.tree.map(lambda a: a[:, P - 1], grouped)

    def local_body(xc, ys):
        p_l, lk, lv, lpos = ys
        h = layers.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
        out, lk, lv, lpos = attn.swa_decode(p_l["attn"], cfg, h, lk, lv, lpos,
                                            lengths, hooks=hooks)
        xc = xc + hooks.act(out)
        xc, _ = tfm._ffn_full(p_l, cfg, xc, hooks)
        return xc, (lk, lv, lpos)

    def group_body(xc, ys):
        g_local, g_global, lk, lv, lpos, gk, gv = ys
        xc, (lk, lv, lpos) = jax.lax.scan(local_body, xc, (g_local, lk, lv, lpos))
        h = layers.rms_norm(xc, g_global["ln1"], cfg.norm_eps)
        out, gk, gv = attn.gqa_decode(g_global["attn"], cfg, h, gk, gv,
                                      lengths, hooks=hooks)
        xc = xc + hooks.act(out)
        xc, _ = tfm._ffn_full(g_global, cfg, xc, hooks)
        return xc, (lk, lv, lpos, gk, gv)

    x, (lk, lv, lpos, gk, gv) = jax.lax.scan(
        group_body, x,
        (local_p, global_p, cache["lk"], cache["lv"], cache["lpos"],
         cache["gk"], cache["gv"]))
    return x, {"lk": lk, "lv": lv, "lpos": lpos, "gk": gk, "gv": gv}


def _decode_hybrid(params, cfg, x, cache, lengths, hooks, impl):
    G, per = cfg.hybrid_groups, cfg.ssm_per_group
    grouped = jax.tree.map(
        lambda a: a.reshape(G, per, *a.shape[1:]), params["layers"])
    h_g = cache["h"].reshape(G, per, *cache["h"].shape[1:])
    c_g = cache["conv"].reshape(G, per, *cache["conv"].shape[1:])

    def ssm_body(xc, ys):
        p_l, h_st, conv_st = ys
        h = layers.rms_norm(xc, p_l["ln"], cfg.norm_eps)
        out, st = ssm_mod.ssm_decode(p_l["ssm"], cfg, h,
                                     {"h": h_st, "conv": conv_st}, hooks=hooks)
        return xc + hooks.act(out), (st["h"], st["conv"])

    def group_body(xc, ys):
        g_params, hs, convs, ck, cv = ys
        xc, (hs, convs) = jax.lax.scan(ssm_body, xc, (g_params, hs, convs))
        h = layers.rms_norm(xc, params["shared_block"]["ln1"], cfg.norm_eps)
        out, ck, cv = attn.gqa_decode(params["shared_block"]["attn"], cfg, h,
                                      ck, cv, lengths, hooks=hooks, impl=impl)
        xc = xc + hooks.act(out)
        xc, _ = tfm._ffn_full(params["shared_block"], cfg, xc, hooks)
        return xc, (hs, convs, ck, cv)

    x, (hs, convs, k, v) = jax.lax.scan(
        group_body, x, (grouped, h_g, c_g, cache["k"], cache["v"]))
    new = {
        "h": hs.reshape(G * per, *hs.shape[2:]),
        "conv": convs.reshape(G * per, *convs.shape[2:]),
        "k": k, "v": v,
    }
    if cfg.tail_ssm_layers:
        x, (th, tc) = jax.lax.scan(
            ssm_body, x, (params["tail"], cache["tail_h"], cache["tail_conv"]))
        new["tail_h"], new["tail_conv"] = th, tc
    return x, new
