"""mamba2-130m — pure SSM (SSD) [arXiv:2405.21060; unverified].

Assigned config: 24L d_model=768 (attention-free) vocab=50280, ssm_state=128.
Mamba2-130m: expand=2 (d_inner=1536), headdim=64 (24 SSD heads), ngroups=1.

CrossPool applicability note (DESIGN.md §Arch-applicability): attention-free
=> no KV cache; the KV-pool/virtualizer is inapplicable.  The arch still
participates via the consolidated weights pool and constant-size per-request
SSM state, which the planner treats as fixed-size pages.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    attention="none",
    ssm=SSMConfig(d_state=128, head_dim=64, n_groups=1, expand=2, conv_width=4),
    max_position=1_048_576,
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, vocab_size=256,
    ssm=SSMConfig(d_state=16, head_dim=16, n_groups=1, expand=2, conv_width=4),
    max_position=512,
)
