"""Observability layer acceptance (ISSUE 7).

* metrics registry: counters/gauges/histograms with label sets, exact
  ``np.percentile`` quantiles, Prometheus text exposition and JSON
  snapshot, bounded structured-event log;
* span tracer: injected monotonic clock gives deterministic timestamps;
  emitted JSON is well-formed Chrome trace-event format (B/E balanced
  per track, one ``thread_name`` metadata event per tid);
* engine integration: deterministic span sequences for a
  queued→admitted→finished request and a cancelled-mid-decode request
  in BOTH lowering modes; exported counters/histograms match
  ``EngineStats`` exactly; observer disabled ⇒ bit-exact token streams;
* ``report()`` renders its last-N rebalance lines from the registry's
  event log;
* DemandTelemetry: empty-window and single-event EWMA edge cases, and
  gauge-fed EWMAs identical to direct pool sampling.
"""
import json
import re
from types import SimpleNamespace

import numpy as np
import pytest

from repro.configs import ElasticConfig, PAPER_COLOC_SET, get_smoke_config
from repro.core.weight_pool import slabs_for_config
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime.observe import (EngineObserver, MetricsRegistry,
                                   SpanTracer, percentile, summarize)
from repro.runtime.request import Request
from repro.runtime.telemetry import DemandTelemetry

MOE, MLA, MOON = "qwen3-moe-235b-a22b", "minicpm3-4b", "moonshot-v1-16b-a3b"


class FakeClock:
    """Deterministic monotonic clock: +1ms per reading."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        self.t += 0.001
        return self.t


def _models(names=PAPER_COLOC_SET):
    return {n: get_smoke_config(n).replace(dtype="float32") for n in names}


def _engine(names=PAPER_COLOC_SET, lowering=True, **kw):
    kw.setdefault("page_budget", 2048)
    kw.setdefault("page_bytes", 4096)
    kw.setdefault("slab_bytes", 4096)
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_ctx", 64)
    kw.setdefault("seed", 0)
    return CrossPoolEngine(_models(names),
                           mode=EngineMode(pipeline=True, lowering=lowering),
                           **kw)


def _backpressure_engine(observer=None, lowering=True):
    """MOE + MLA with an arena sized for ONE model: the second submit
    queues on weight pressure (the queued→admitted drain path)."""
    models = _models((MOE, MLA))
    need = {n: slabs_for_config(c, 4096) for n, c in models.items()}
    return CrossPoolEngine(
        models, page_budget=2048, page_bytes=4096,
        slot_budget=max(need.values()), slab_bytes=4096,
        max_batch=2, max_ctx=64,
        mode=EngineMode(pipeline=True, lowering=lowering),
        observer=observer)


def _lifecycle(tracer: SpanTracer, track: str):
    """The B/E/i sequence on one track (X slices carry durations, not
    lifecycle ordering — dropped here, asserted separately)."""
    return [(ph, n) for ph, n in tracer.span_names(track) if ph != "X"]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_labels():
    m = MetricsRegistry()
    c = m.counter("req_total", "requests", ("model", "outcome"))
    c.labels("a", "ok").inc()
    c.labels("a", "ok").inc(2)
    c.labels("b", "err").inc()
    assert c.labels("a", "ok").value == 3
    assert c.value == 4                      # family total
    g = m.gauge("depth", "queue depth")
    g.set(7)
    g.set(3)
    assert g.value == 3
    # get-or-create shares one family; kind mismatch is a hard error
    assert m.counter("req_total", labelnames=("model", "outcome")) is c
    with pytest.raises(AssertionError):
        m.gauge("req_total")


def test_histogram_percentile_is_exactly_numpy():
    m = MetricsRegistry()
    h = m.histogram("lat", "latency", ("model",))
    rng = np.random.default_rng(0)
    samples = {"a": rng.uniform(0, 2, 101), "b": rng.uniform(0, 0.01, 7)}
    for name, vals in samples.items():
        child = h.labels(name)
        for v in vals:
            child.observe(v)
    everything = np.concatenate(list(samples.values()))
    for q in (50, 95, 99):
        assert h.percentile(q) == float(np.percentile(everything, q))
        assert h.labels("a").percentile(q) == \
            float(np.percentile(samples["a"], q))
    assert h.count == len(everything)
    assert np.isnan(percentile([], 99))      # empty window → NaN, no raise
    assert np.isnan(summarize([])["p50"])


def test_prometheus_text_exposition():
    m = MetricsRegistry()
    m.counter("c_total", "a counter", ("model",)).labels("x").inc(5)
    m.gauge("g", "a gauge").set(1.5)
    h = m.histogram("h_seconds", "a histogram", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    text = m.prometheus_text()
    assert "# HELP c_total a counter" in text
    assert "# TYPE c_total counter" in text
    assert 'c_total{model="x"} 5' in text
    assert "# TYPE g gauge" in text and "g 1.5" in text
    # cumulative buckets: 1 ≤ 0.1, 2 ≤ 1.0, 3 ≤ +Inf == _count
    assert 'h_seconds_bucket{le="0.1"} 1' in text
    assert 'h_seconds_bucket{le="1"} 2' in text
    assert 'h_seconds_bucket{le="+Inf"} 3' in text
    assert "h_seconds_count 3" in text
    assert "h_seconds_sum 2.55" in text
    # every sample line is NAME{LABELS}? VALUE
    line_re = re.compile(
        r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? -?[0-9.+einfa]+$')
    for line in text.strip().splitlines():
        if not line.startswith("#"):
            assert line_re.match(line), line


def test_snapshot_is_jsonable_and_event_log_bounded():
    m = MetricsRegistry(event_log_size=4)
    m.histogram("h", "hist").observe(0.2)
    m.counter("c", "cnt").inc()
    snap = json.loads(json.dumps(m.snapshot()))
    assert snap["h"]["values"][0]["count"] == 1
    assert snap["h"]["values"][0]["p50"] == 0.2
    for i in range(10):
        m.log_event("rebalance", step=i)
    assert [e["step"] for e in m.recent_events("rebalance")] == [6, 7, 8, 9]
    assert [e["step"] for e in m.recent_events("rebalance", 2)] == [8, 9]
    assert m.recent_events("nope") == []


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_tracer_fake_clock_gives_deterministic_timestamps():
    tr = SpanTracer(clock=FakeClock())          # t0 = 1ms
    tr.begin("trk", "step")                     # reads 2ms → ts 1000us
    tr.instant("trk", "mark")                   # 3ms → 2000us
    tr.end("trk", "step")                       # 4ms → 3000us
    tr.complete("trk", "slice", dur_s=0.002)    # 5ms → ends at 4000us
    ev = tr.track_events("trk")
    assert [(e["ph"], e["ts"]) for e in ev] == [
        ("B", 1000.0), ("i", 2000.0), ("E", 3000.0), ("X", 2000.0)]
    assert ev[3]["dur"] == 2000.0
    # the metadata event named the track exactly once
    meta = [e for e in tr.events if e["ph"] == "M"]
    assert len(meta) == 1 and meta[0]["args"]["name"] == "trk"


def _validate_chrome_trace(trace: dict) -> None:
    """Schema check: the shape Perfetto/chrome://tracing ingests."""
    assert set(trace) == {"traceEvents", "displayTimeUnit"}
    assert trace["displayTimeUnit"] == "ms"
    events = trace["traceEvents"]
    assert events, "empty trace"
    named_tids = set()
    depth: dict = {}
    for e in events:
        assert e["ph"] in {"B", "E", "X", "i", "M", "C"}, e
        assert e["pid"] == SpanTracer.PID and isinstance(e["tid"], int)
        assert isinstance(e["name"], str) and e["name"]
        if e["ph"] == "M":
            assert e["name"] == "thread_name"
            assert e["tid"] not in named_tids    # one metadata per track
            named_tids.add(e["tid"])
            continue
        assert e["tid"] in named_tids            # named before first use
        assert e["ts"] >= 0
        if e["ph"] == "X":
            assert e["dur"] >= 0
        elif e["ph"] == "i":
            assert e["s"] == "t"
        elif e["ph"] == "C":
            assert e["args"] and all(isinstance(v, float)
                                     for v in e["args"].values())
        elif e["ph"] == "B":
            depth[e["tid"]] = depth.get(e["tid"], 0) + 1
        elif e["ph"] == "E":
            depth[e["tid"]] = depth[e["tid"]] - 1
            assert depth[e["tid"]] >= 0, f"unbalanced E on tid {e['tid']}"
    assert all(d == 0 for d in depth.values()), f"unclosed spans: {depth}"


# ---------------------------------------------------------------------------
# engine integration: one run shared by the parity/schema tests
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def observed_run():
    obs = EngineObserver(clock=FakeClock())
    engine = _engine(observer=obs)
    reqs = [Request(0, MOE, 6, 3, 0.0), Request(1, MOE, 7, 3, 0.0),
            Request(2, MLA, 5, 3, 0.0), Request(3, MOON, 20, 3, 0.0)]
    stats = engine.run(reqs)
    return engine, reqs, stats, obs


def test_metrics_match_engine_stats(observed_run):
    engine, reqs, stats, obs = observed_run
    # token volume: the per-model counter family sums to EngineStats
    assert obs.tokens_total.value == stats.tokens_out
    # latency histograms hold EXACTLY the windowed EngineStats samples
    assert sorted(obs.tbt.all_samples()) == sorted(stats.tbt)
    assert sorted(obs.ttft.all_samples()) == sorted(stats.ttft)
    assert sorted(obs.prefill_batch.all_samples()) == \
        sorted(stats.prefill_batch_sizes)
    # admission verdicts per (model, outcome) match the controller
    adm = engine.admission.stats
    for (model, outcome), child in obs.admission_total.children.items():
        assert child.value == getattr(adm.per_model[model], outcome), \
            (model, outcome)
    assert obs.admission_total.value == \
        adm.admitted + adm.queued + adm.rejected
    # every request reached exactly one terminal outcome
    assert obs.requests_total.value == len(reqs)
    # arena/KV gauges mirror the live pools
    assert obs.kv_occupancy() == \
        engine.virt.mapped_pages / max(engine.virt.page_budget, 1)
    assert obs.slab_occupancy() == \
        engine.arena.resident_slabs / max(engine.arena.slot_budget, 1)


def test_prometheus_and_snapshot_outputs_parse(observed_run):
    _, _, _, obs = observed_run
    text = obs.metrics.prometheus_text()
    assert "# TYPE crosspool_ttft_seconds histogram" in text
    assert "crosspool_ttft_seconds_bucket" in text
    assert f'crosspool_admission_total{{model="{MOE}",outcome="admitted"}}' \
        in text
    json.loads(json.dumps(obs.metrics.snapshot()))


def test_chrome_trace_schema_and_request_span_trees(observed_run):
    _, reqs, _, obs = observed_run
    trace = json.loads(json.dumps(obs.tracer.chrome_trace()))
    _validate_chrome_trace(trace)
    # one COMPLETE span tree per request: submit → admitted → decode →
    # finished, all spans closed, ≥1 K-block slice inside decode
    for r in reqs:
        track = f"req/{r.model}#{r.request_id}"
        assert _lifecycle(obs.tracer, track) == [
            ("i", "submit"), ("B", "admitted"), ("E", "admitted"),
            ("B", "decode"), ("E", "decode"), ("i", "finished")]
        assert any(ph == "X" and name == "decode_block"
                   for ph, name in obs.tracer.span_names(track))
    # the step loop bracketed every step and its phases
    seq = obs.tracer.span_names(EngineObserver.ENGINE_TRACK)
    assert ("B", "step") in seq and ("E", "step") in seq
    assert ("B", "admission_drain") in seq and ("B", "batcher") in seq


def test_observer_disabled_streams_bit_exact(observed_run):
    _, ref_reqs, ref_stats, _ = observed_run
    engine = _engine()                      # observer=None: the fast path
    assert engine.observer is None
    reqs = [Request(0, MOE, 6, 3, 0.0), Request(1, MOE, 7, 3, 0.0),
            Request(2, MLA, 5, 3, 0.0), Request(3, MOON, 20, 3, 0.0)]
    stats = engine.run(reqs)
    assert stats.tokens_out == ref_stats.tokens_out
    for a, b in zip(reqs, ref_reqs):
        assert a.output_ids == b.output_ids, a.request_id


# ---------------------------------------------------------------------------
# deterministic span sequences, both lowering modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lowering", [True, False])
def test_span_sequence_queued_admitted_finished(lowering):
    """Arena backpressure queues the MLA submit; the queued span closes
    when the front door drains it, then the normal lifecycle follows."""
    obs = EngineObserver(clock=FakeClock())
    engine = _backpressure_engine(observer=obs, lowering=lowering)
    h_moe = engine.submit(Request(0, MOE, 8, 2, 0.0))
    h_mla = engine.submit(Request(1, MLA, 8, 2, 0.0))
    assert h_moe.admission == "admitted" and h_mla.admission == "queued"
    engine.drain()
    assert _lifecycle(obs.tracer, f"req/{MLA}#1") == [
        ("i", "submit"), ("B", "queued"), ("E", "queued"),
        ("B", "admitted"), ("E", "admitted"),
        ("B", "decode"), ("E", "decode"), ("i", "finished")]
    # metrics saw the same story: one queued verdict, one drain wait
    assert obs.admission_total.labels(MLA, "queued").value == 1
    assert obs.admission_total.labels(MLA, "admitted").value == 1
    wait = obs.metrics.get("crosspool_admission_wait_seconds")
    assert wait.labels(MLA).count == 1
    assert obs.requests_total.labels(MLA, "finished").value == 1


@pytest.mark.parametrize("lowering", [True, False])
def test_span_sequence_cancelled_mid_decode(lowering):
    obs = EngineObserver(clock=FakeClock())
    engine = _engine(names=(MOE, MLA), lowering=lowering, observer=obs)
    h = engine.submit(Request(0, MOE, 6, 50, 0.0))
    engine.submit(Request(1, MLA, 5, 3, 0.0))
    engine.step()
    engine.step()
    assert len(h.tokens) >= 2               # mid-decode, slot held
    assert engine.cancel(h)
    engine.drain()
    assert _lifecycle(obs.tracer, f"req/{MOE}#0") == [
        ("i", "submit"), ("B", "admitted"), ("E", "admitted"),
        ("B", "decode"), ("E", "decode"), ("i", "cancelled")]
    assert obs.requests_total.labels(MOE, "cancelled").value == 1
    assert obs.requests_total.labels(MLA, "finished").value == 1
    _validate_chrome_trace(obs.tracer.chrome_trace())


# ---------------------------------------------------------------------------
# report() renders rebalance lines from the registry event log
# ---------------------------------------------------------------------------

def test_report_rebalance_lines_come_from_registry():
    engine = _engine(names=(MOE, MLA), elastic=ElasticConfig())
    engine.metrics.log_event(
        "rebalance", step=5, time=1.0, page_budget=(8, 16),
        slot_budget=(4, 2), swapped_out=0, evicted_models=1,
        reason="kv_pressure")
    report = engine.report()
    assert "move @step 5: pages 8->16, slabs 4->2" in report
    assert "kv_pressure" in report and "evicted 1" in report


# ---------------------------------------------------------------------------
# DemandTelemetry EWMA edge cases + gauge feeding
# ---------------------------------------------------------------------------

def _fake_virt(mapped=0, budget=10):
    return SimpleNamespace(mapped_pages=mapped, page_budget=budget,
                           swapped_now=0)


def test_telemetry_empty_window():
    tel = DemandTelemetry(_models((MLA,)), ElasticConfig())
    tel.observe(0.0, _fake_virt(), arena=None, admission=None)
    assert tel.kv_occupancy_ewma == 0.0
    assert tel.slab_occupancy_ewma == 0.0
    assert tel.queue_depth_ewma == 0.0
    assert tel.arrival_rate(MLA, 0.0) == 0.0
    assert tel.window_specs(0.0) == []       # no signal → no specs
    assert tel.snapshot()["window_completions"] == 0.0


def test_telemetry_single_event_ewma():
    cfg = ElasticConfig()
    tel = DemandTelemetry(_models((MLA,)), cfg)
    tel.note_arrival(MLA, 0.0)
    tel.note_finish(MLA, prompt_tokens=8, output_tokens=4,
                    admit_time=0.0, finish_time=0.5)
    tel.observe(0.5, _fake_virt(mapped=5), arena=None, admission=None)
    # one sample folded from zero: ewma == alpha * x exactly
    assert tel.kv_occupancy_ewma == cfg.ewma_alpha * 0.5
    # sub-second window: the rate denominator floors at 1s (no n/epsilon)
    assert tel.arrival_rate(MLA, 0.5) == 1.0
    specs = tel.window_specs(0.5)
    assert len(specs) == 1 and specs[0].arrival_rate == 1.0
    assert specs[0].prompt_tokens.tolist() == [8.0]


def test_telemetry_gauge_fed_matches_direct_sampling():
    """With an observer attached the EWMAs fold the gauge values the
    registry exports — identical to direct pool sampling, by value."""
    cfg = ElasticConfig()
    direct = DemandTelemetry(_models((MLA,)), cfg)
    obs = EngineObserver(clock=FakeClock())
    fed = DemandTelemetry(_models((MLA,)), cfg, gauges=obs)
    admission = SimpleNamespace(queued_count=lambda: 3)
    for step in range(4):
        virt = _fake_virt(mapped=2 * step, budget=10)
        direct.observe(float(step), virt, arena=None, admission=admission)
        obs.sample(virt, None, admission, waiting=0)
        fed.observe(float(step), virt, arena=None, admission=admission)
    assert fed.kv_occupancy_ewma == direct.kv_occupancy_ewma
    assert fed.queue_depth_ewma == direct.queue_depth_ewma
    assert fed.last == direct.last


# ---------------------------------------------------------------------------
# SLO burn-rate monitor edge cases (ISSUE 10)
# ---------------------------------------------------------------------------

def _slo_monitor(threshold_ms=100.0, target=0.9, window_s=10.0,
                 short_window_s=2.0):
    from repro.configs.base import SLObjective, SLOConfig
    from repro.runtime.observe import SLOMonitor
    cfg = SLOConfig(
        objectives={"m": SLObjective(ttft_ms=threshold_ms, target=target)},
        window_s=window_s, short_window_s=short_window_s)
    return SLOMonitor(cfg)


def test_slo_empty_window_never_breaches():
    mon = _slo_monitor()
    assert mon.evaluate(0.0) == [] and mon.evaluate(1e9) == []
    st = mon.status(1e9)[("m", "ttft")]
    assert st["n"] == 0 and not st["breaching"]
    assert np.isnan(st["window_value"])
    assert mon.breach_count() == 0


def test_slo_single_sample_breach_edge():
    mon = _slo_monitor()
    mon.note("ttft", "m", 0.5, 1.0)            # 500ms against a 100ms SLO
    breaches = mon.evaluate(1.0)
    assert len(breaches) == 1
    b = breaches[0]
    assert (b.model, b.metric) == ("m", "ttft")
    assert b.long_burn == b.short_burn == pytest.approx(1.0 / 0.1)
    # edge-triggered: still breaching, but no NEW edge without recovery
    assert mon.evaluate(1.5) == []
    assert mon.breach_count() == 1
    # sample ages out of the window -> condition clears -> edge re-arms
    assert mon.evaluate(20.0) == []
    mon.note("ttft", "m", 0.5, 21.0)
    assert len(mon.evaluate(21.0)) == 1
    assert mon.breach_count() == 2


def test_slo_exact_threshold_is_within_slo():
    """A sample EQUAL to the objective does not burn budget (bad is
    strictly greater-than)."""
    mon = _slo_monitor(threshold_ms=100.0)
    for i in range(8):
        mon.note("ttft", "m", 0.1, float(i) * 0.1)
    assert mon.evaluate(0.8) == []
    st = mon.status(0.8)[("m", "ttft")]
    assert st["bad_fraction"] == 0.0 and st["long_burn"] == 0.0
    # one ulp above the threshold and the whole window burns
    mon.note("ttft", "m", np.nextafter(0.1, 1.0), 0.9)
    assert len(mon.evaluate(0.9)) == 1


def test_slo_reset_mid_window_drops_samples_and_rearms():
    """The ``engine.reset_stats()`` path: windows clear and the edge
    re-arms, so the same condition fires a fresh breach afterwards."""
    mon = _slo_monitor()
    mon.note("ttft", "m", 0.5, 1.0)
    assert len(mon.evaluate(1.0)) == 1
    mon.reset()
    st = mon.status(1.0)[("m", "ttft")]
    assert st["n"] == 0 and not st["breaching"]
    assert mon.evaluate(1.1) == []             # empty again, no breach
    mon.note("ttft", "m", 0.5, 1.2)
    assert len(mon.evaluate(1.2)) == 1         # re-armed edge fires
    assert mon.breach_count() == 2


def test_slo_window_value_matches_np_percentile_of_histogram():
    """Breach parity: the monitor's window quantile is EXACTLY
    ``np.percentile`` over the same raw samples a registry histogram
    holds (same linear interpolation, no bucketing error)."""
    mon = _slo_monitor(threshold_ms=100.0, target=0.95, window_s=100.0)
    hist = mon.metrics.histogram("ttft_seconds", "raw ttft", ("model",))
    rng = np.random.default_rng(3)
    samples = rng.uniform(0.0, 0.4, 64)
    for i, v in enumerate(samples):
        mon.note("ttft", "m", v, float(i))
        hist.labels("m").observe(v)
    mon.evaluate(float(len(samples) - 1))
    st = mon.status(float(len(samples) - 1))[("m", "ttft")]
    assert st["window_value"] == float(np.percentile(samples, 95.0))
    assert st["window_value"] == hist.labels("m").percentile(95.0)
    ev = mon.metrics.recent_events("slo_breach")
    assert ev and ev[-1]["window_value_ms"] == st["window_value"] * 1e3


def test_metrics_registry_counts_dropped_events():
    reg = MetricsRegistry(event_log_size=4)
    for i in range(7):
        reg.log_event("rebalance", step=i)
    assert reg.events_dropped("rebalance") == 3
    assert reg.events_dropped() == {"rebalance": 3}
    assert reg.events_dropped("slo_breach") == 0
    # the companion counter family is exported for scrapes
    ctr = reg.get("crosspool_events_dropped_total")
    assert ctr is not None and ctr.labels("rebalance").value == 3
    # the log still holds the most recent events only
    assert [e["step"] for e in reg.recent_events("rebalance")] == [3, 4, 5, 6]
