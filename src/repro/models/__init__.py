"""Pure-JAX model zoo: dense / MoE / MLA / SSM / hybrid / enc-dec backbones.

Public entry point: :func:`repro.models.model.build_model`.
"""
from repro.models.model import Model, build_model  # noqa: F401
