"""Training substrate tests: learning, microbatching, checkpointing,
compression, optimizer behaviour."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.training import checkpoint as ckpt
from repro.training import compression
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import AdamW
from repro.training.train_step import init_train_state, make_train_step


def _setup(arch="qwen3-14b", **opt_kw):
    cfg = get_smoke_config(arch).replace(dtype="float32")
    model = build_model(cfg)
    optimizer = AdamW(lr=3e-3, warmup_steps=5, **opt_kw)
    state = init_train_state(model, optimizer, jax.random.PRNGKey(0))
    return cfg, model, optimizer, state


class TestTrainStep:
    def test_loss_decreases_on_structured_data(self):
        cfg, model, optimizer, state = _setup()
        step = jax.jit(make_train_step(model, optimizer, remat=False))
        data = SyntheticLM(DataConfig(cfg.vocab_size, seq_len=32,
                                      global_batch=8, seed=1))
        losses = []
        for i, batch in zip(range(40), data.batches()):
            state, metrics = step(state, {"tokens": jnp.asarray(batch["tokens"])})
            losses.append(float(metrics["ce"]))
        assert losses[-1] < losses[0] * 0.8, losses[::8]

    def test_moe_aux_loss_flows(self):
        cfg, model, optimizer, state = _setup("qwen3-moe-235b-a22b")
        step = jax.jit(make_train_step(model, optimizer, remat=False,
                                       aux_weight=0.05))
        data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 8, seed=2))
        batch = next(data.batches())
        state, metrics = step(state, {"tokens": jnp.asarray(batch["tokens"])})
        assert float(metrics["aux"]) > 0.0
        assert np.isfinite(float(metrics["loss"]))

    def test_microbatch_grad_equivalence(self):
        """G microbatches must produce the same update as one big batch
        (linearity of gradient accumulation)."""
        cfg, model, optimizer, state = _setup()
        step1 = jax.jit(make_train_step(model, optimizer,
                                        num_microbatches=1, remat=False))
        step4 = jax.jit(make_train_step(model, optimizer,
                                        num_microbatches=4, remat=False))
        data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 8, seed=3))
        batch = {"tokens": jnp.asarray(next(data.batches())["tokens"])}
        s1, m1 = step1(state, batch)
        s4, m4 = step4(state, batch)
        np.testing.assert_allclose(float(m1["ce"]), float(m4["ce"]),
                                   rtol=1e-5)
        d1 = jax.tree.leaves(s1.params)
        d4 = jax.tree.leaves(s4.params)
        for a, b in zip(d1, d4):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-5)

    def test_remat_matches_no_remat(self):
        cfg, model, optimizer, state = _setup()
        step_r = jax.jit(make_train_step(model, optimizer, remat=True))
        step_n = jax.jit(make_train_step(model, optimizer, remat=False))
        data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 4, seed=4))
        batch = {"tokens": jnp.asarray(next(data.batches())["tokens"])}
        s_r, m_r = step_r(state, batch)
        s_n, m_n = step_n(state, batch)
        np.testing.assert_allclose(float(m_r["loss"]), float(m_n["loss"]),
                                   rtol=1e-6)

    def test_ssm_arch_trains(self):
        cfg, model, optimizer, state = _setup("mamba2-130m")
        step = jax.jit(make_train_step(model, optimizer, remat=False))
        data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 8, seed=5))
        losses = []
        for i, batch in zip(range(25), data.batches()):
            state, metrics = step(state, {"tokens": jnp.asarray(batch["tokens"])})
            losses.append(float(metrics["ce"]))
        assert losses[-1] < losses[0]


class TestOptimizer:
    def test_bf16_moments_halve_state_bytes(self):
        cfg, model, _, _ = _setup()
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        s32 = AdamW(moment_dtype="float32").init(params)
        s16 = AdamW(moment_dtype="bfloat16").init(params)
        b32 = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(s32.m))
        b16 = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(s16.m))
        assert b16 * 2 == b32

    def test_grad_clip_caps_update(self):
        opt = AdamW(lr=1.0, grad_clip=1e-3, warmup_steps=1)
        params = {"w": jnp.ones((4,))}
        state = opt.init(params)
        huge = {"w": jnp.full((4,), 1e6)}
        new_params, _ = opt.update(huge, state, params)
        delta = np.abs(np.asarray(new_params["w"] - params["w"]))
        assert delta.max() < 10.0   # clipped, not 1e6-scaled


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                              jnp.float32)}
        e = compression.init_error_feedback(g)
        used, e2 = compression.compress_grads(g, e)
        err = np.abs(np.asarray(used["w"] - g["w"]))
        assert err.max() <= float(jnp.max(jnp.abs(g["w"]))) / 127 + 1e-6

    def test_error_feedback_carries_residual(self):
        """Sum of dequantized grads over steps converges to sum of true
        grads (the error-feedback telescoping property)."""
        rng = np.random.default_rng(1)
        g_true = jnp.asarray(rng.normal(size=(32,)) * 1e-4, jnp.float32)
        e = compression.init_error_feedback({"w": g_true})
        total = jnp.zeros_like(g_true)
        for _ in range(50):
            used, e = compression.compress_grads({"w": g_true}, e)
            total = total + used["w"]
        np.testing.assert_allclose(np.asarray(total),
                                   np.asarray(g_true * 50), rtol=0.05)

    def test_training_with_compression_still_learns(self):
        cfg, model, optimizer, _ = _setup()
        state = init_train_state(model, optimizer, jax.random.PRNGKey(0),
                                 compress=True)
        step = jax.jit(make_train_step(model, optimizer, compress=True,
                                       remat=False))
        data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 8, seed=6))
        losses = []
        for i, batch in zip(range(30), data.batches()):
            state, metrics = step(state, {"tokens": jnp.asarray(batch["tokens"])})
            losses.append(float(metrics["ce"]))
        assert losses[-1] < losses[0] * 0.9


class TestCheckpoint:
    def test_roundtrip_exact(self):
        cfg, model, optimizer, state = _setup()
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(state, 7, d)
            assert ckpt.latest_step(d) == 7
            spec = jax.eval_shape(lambda: state)
            restored, step = ckpt.restore(d, target_tree=spec)
            assert step == 7
            for a, b in zip(jax.tree.leaves(state),
                            jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_resume_training_continuity(self):
        cfg, model, optimizer, state = _setup()
        step = jax.jit(make_train_step(model, optimizer, remat=False))
        data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 4, seed=7))
        batches = [{"tokens": jnp.asarray(b["tokens"])}
                   for b, _ in zip(data.batches(), range(6))]
        # path A: 6 straight steps
        sA = state
        for b in batches:
            sA, _ = step(sA, b)
        # path B: 3 steps, checkpoint, restore, 3 more
        sB = state
        for b in batches[:3]:
            sB, _ = step(sB, b)
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(sB, 3, d)
            spec = jax.eval_shape(lambda: sB)
            sB, _ = ckpt.restore(d, target_tree=spec)
        for b in batches[3:]:
            sB, _ = step(sB, b)
        for a, b_ in zip(jax.tree.leaves(sA.params),
                         jax.tree.leaves(sB.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-6, atol=1e-7)

    def test_async_save(self):
        cfg, model, optimizer, state = _setup()
        with tempfile.TemporaryDirectory() as d:
            t = ckpt.save_async(state, 1, d)
            t.join(timeout=60)
            assert ckpt.latest_step(d) == 1

    def test_gc_keeps_last_three(self):
        with tempfile.TemporaryDirectory() as d:
            tree = {"w": jnp.ones((2,))}
            for s in range(5):
                ckpt.save(tree, s, d)
            kept = sorted(os.listdir(d))
            assert len(kept) == 3
            assert ckpt.latest_step(d) == 4
