"""Disaggregated memory pools: the engine-level objects.

``KVCachePool`` owns a device, every colocated model's *non-FFN* params,
and the shared physical KV page pool (virtualizer) — the SINGLE KV
allocation serving every colocated model's decode.  ``WeightsPool`` owns
another device and the consolidated FFN/MoE weights of ALL colocated
models.  Hidden states are the only tensors that cross between them
(``transfer``), matching the paper's NVSHMEM boundary.

On a one-device host both pools may map to the same device — the data-path
structure (split params, explicit transfers, page accounting) is identical;
on the production mesh the same roles are expressed by the ``crosspool``
sharding strategy inside one SPMD program.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import split_exec
from repro.core.virtualizer import (DEFAULT_PAGE_BYTES, KVVirtualizer,
                                    ModelView)


@dataclass
class PooledModel:
    cfg: ModelConfig
    kv_params: Dict            # embeddings, norms, attention (KV pool device)
    w_params: Dict             # FFN/MoE weights (weights pool device)
    view: ModelView            # how this model types the shared pages
    # None for fused-fallback families (SSM/hybrid/enc-dec/SWA)
    stage_fns: Optional[split_exec.StageFns]


class WeightsPool:
    """Consolidated FFN weights of all colocated cold models."""

    def __init__(self, device):
        self.device = device
        self.ffn_params: Dict[str, Dict] = {}

    def add_model(self, name: str, w_params: Dict) -> None:
        self.ffn_params[name] = jax.device_put(w_params, self.device)

    def total_bytes(self) -> int:
        return sum(
            leaf.size * leaf.dtype.itemsize
            for tree in self.ffn_params.values()
            for leaf in jax.tree.leaves(tree))


class KVCachePool:
    """Attention-side pool: non-FFN params + the shared paged KV space."""

    def __init__(self, device, models: Dict[str, ModelConfig], *,
                 page_budget: int, page_bytes: int = DEFAULT_PAGE_BYTES,
                 pool_dtype=jnp.bfloat16,
                 allocate_device_pool: bool = True):
        self.device = device
        self.attn_params: Dict[str, Dict] = {}
        self.virtualizer = KVVirtualizer(
            models, page_budget=page_budget, page_bytes=page_bytes,
            dtype=pool_dtype, allocate_device_pool=allocate_device_pool,
            device=device)

    def add_model(self, name: str, kv_params: Dict) -> None:
        self.attn_params[name] = jax.device_put(kv_params, self.device)

    def total_param_bytes(self) -> int:
        return sum(
            leaf.size * leaf.dtype.itemsize
            for tree in self.attn_params.values()
            for leaf in jax.tree.leaves(tree))


def transfer(x: jax.Array, device) -> jax.Array:
    """The pool boundary: explicit async hidden-state transfer."""
    return jax.device_put(x, device)


def build_pools(models: Dict[str, ModelConfig], params: Dict[str, Dict], *,
                kv_device=None, w_device=None, page_budget: int,
                page_bytes: int = DEFAULT_PAGE_BYTES,
                pool_dtype=jnp.bfloat16,
                allocate_device_pool: bool = True,
                ):
    """Split every model's params across the two pools.

    Models that support split execution get paged :class:`StageFns`
    compiled against the virtualizer's page geometry; fused-fallback
    families get ``stage_fns=None`` and keep serving through their dense
    per-model caches.
    """
    devs = jax.devices()
    kv_device = kv_device or devs[0]
    w_device = w_device or devs[-1]
    kv_pool = KVCachePool(kv_device, models, page_budget=page_budget,
                          page_bytes=page_bytes, pool_dtype=pool_dtype,
                          allocate_device_pool=allocate_device_pool)
    w_pool = WeightsPool(w_device)
    pooled: Dict[str, PooledModel] = {}
    for name, cfg in models.items():
        kv_tree, w_tree = split_exec.split_params(params[name], cfg)
        kv_pool.add_model(name, kv_tree)
        w_pool.add_model(name, w_tree)
        view = kv_pool.virtualizer.views[name]
        stage_fns = (split_exec.make_stage_fns(cfg, view)
                     if split_exec.supports_split(cfg) else None)
        pooled[name] = PooledModel(
            cfg=cfg,
            kv_params=kv_pool.attn_params[name],
            w_params=w_pool.ffn_params[name],
            view=view,
            stage_fns=stage_fns,
        )
    return kv_pool, w_pool, pooled
