"""Workload traces: ShareGPT-like (balanced) and LongAlign-like (long-ctx).

Offline datasets are unavailable in this container, so we synthesize traces
whose marginal token statistics match the published dataset summaries:

* ShareGPT (Vicuna conversations): prompt/output token counts are
  log-normal-ish with medians of a few hundred tokens and a heavy tail
  (median prompt ~220, median output ~180, p99 ~2k) — the "balanced
  input/output" workload of paper §5.1.
* LongAlign-10k: context lengths spread 1k..64k with substantial mass
  beyond 8k (the long-context scalability workload of Fig. 6), outputs a
  few hundred tokens.

Arrivals are Poisson at a configurable per-model RPS (paper: 0.2-1.0).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.runtime.request import Request


@dataclass(frozen=True)
class TraceStats:
    prompt_tokens: np.ndarray
    output_tokens: np.ndarray


def sharegpt_like(n: int, rng: np.random.Generator,
                  clip: int = 4096) -> TraceStats:
    prompt = np.clip(rng.lognormal(mean=5.4, sigma=0.9, size=n), 8,
                     clip).astype(int)
    output = np.clip(rng.lognormal(mean=5.2, sigma=0.8, size=n), 8,
                     clip).astype(int)
    return TraceStats(prompt, output)


def longalign_like(n: int, rng: np.random.Generator,
                   max_ctx: int = 65536) -> TraceStats:
    """Context lengths across 1k..64k bins with heavy long-tail mass."""
    bins = np.array([1024, 2048, 4096, 8192, 16384, 32768, 65536])
    weights = np.array([0.18, 0.2, 0.2, 0.16, 0.12, 0.09, 0.05])
    hi = rng.choice(bins, size=n, p=weights / weights.sum())
    prompt = (hi * rng.uniform(0.55, 1.0, size=n)).astype(int)
    prompt = np.minimum(prompt, max_ctx - 512)
    output = np.clip(rng.lognormal(5.0, 0.7, size=n), 16, 512).astype(int)
    return TraceStats(prompt, output)


def poisson_arrivals(rate: float, horizon_s: float,
                     rng: np.random.Generator) -> np.ndarray:
    n = rng.poisson(rate * horizon_s)
    return np.sort(rng.uniform(0.0, horizon_s, n))


def make_requests(models: List[str], *, rps_per_model: float,
                  horizon_s: float, kind: str = "sharegpt",
                  seed: int = 0, scale_tokens: float = 1.0,
                  max_new_cap: Optional[int] = None) -> List[Request]:
    """Interleaved multi-model request stream sorted by arrival time.

    ``scale_tokens`` shrinks token counts for CPU-scale engine runs while
    preserving the distribution shape.
    """
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    rid = 0
    for model in models:
        arrivals = poisson_arrivals(rps_per_model, horizon_s, rng)
        stats = (sharegpt_like(len(arrivals), rng) if kind == "sharegpt"
                 else longalign_like(len(arrivals), rng))
        for t, p, o in zip(arrivals, stats.prompt_tokens,
                           stats.output_tokens):
            p = max(int(p * scale_tokens), 1)
            o = max(int(o * scale_tokens), 1)
            if max_new_cap:
                o = min(o, max_new_cap)
            reqs.append(Request(rid, model, p, o, float(t)))
            rid += 1
    reqs.sort(key=lambda r: r.arrival_time)
    return reqs
