"""Placement capacity models: Static Partition vs kvcached vs CrossPool.

Analytic models of how much KV capacity each placement exposes — used by
the Fig. 2 (KV availability fraction) and Fig. 6 (context-length
scalability) reproductions, and by the engine to configure itself.

All three placements get the SAME hardware budget (n_gpus x hbm_bytes) and
must hold the same model weights; they differ in where weights sit and
which fraction of the remaining KV memory one request can reach.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class Hardware:
    n_gpus: int = 5
    hbm_bytes: float = 40e9            # A100-40G testbed of the paper
    bytes_per_param: int = 2


@dataclass(frozen=True)
class PlacementResult:
    system: str
    # per-model: (kv_bytes_visible_to_one_request, total_kv_bytes)
    per_model: Dict[str, Tuple[float, float]]

    def max_context(self, cfg: ModelConfig) -> int:
        vis, _ = self.per_model[cfg.name]
        kappa = cfg.kv_bytes_per_token()
        return int(vis // kappa) if kappa else 1 << 30


def _weights_bytes(cfg: ModelConfig, hw: Hardware) -> float:
    return cfg.param_counts()["total"] * hw.bytes_per_param


def _ffn_bytes(cfg: ModelConfig, hw: Hardware) -> float:
    return cfg.param_counts()["ffn"] * hw.bytes_per_param


def _tp_width(cfg: ModelConfig, gpus: int) -> int:
    """TP degree a monolithic engine uses: min(kv_heads, gpus)  (paper §2.2:
    DP attention beyond the KV-head count)."""
    if cfg.attn_free:
        return gpus
    if cfg.attention == "mla":
        return 1
    return min(cfg.n_kv_heads, gpus)


def static_partition(models: Sequence[ModelConfig], hw: Hardware,
                     gpus_per_model: Sequence[int]) -> PlacementResult:
    """Each model owns a fixed GPU subset; weights + KV colocated there."""
    per = {}
    for cfg, g in zip(models, gpus_per_model):
        budget = g * hw.hbm_bytes - _weights_bytes(cfg, hw)
        budget = max(budget, 0.0)
        tp = _tp_width(cfg, g)
        replicas = max(g // tp, 1)
        visible = budget / replicas        # one request -> one replica
        per[cfg.name] = (visible, budget)
    return PlacementResult("static", per)


def kvcached(models: Sequence[ModelConfig], hw: Hardware) -> PlacementResult:
    """Elastic colocated pool (Chimera/kvcached): weights are stored once
    (FFN shared across the DP-attention group via TP/EP), KV memory is
    elastically shared — but weights and KV stay in ONE pool per GPU, and
    a request under DP attention only reaches its own rank group's KV
    (paper §2.2 / Fig. 2a): visible fraction = min(kv_heads, G) / G."""
    total_hbm = hw.n_gpus * hw.hbm_bytes
    weights = sum(_weights_bytes(cfg, hw) for cfg in models)
    kv_total = max(total_hbm - weights, 0.0)
    per = {}
    for cfg in models:
        frac = kv_availability_fraction(
            1 if cfg.attention == "mla" else cfg.n_kv_heads,
            hw.n_gpus, disaggregated=False) if not cfg.attn_free else 1.0
        per[cfg.name] = (kv_total * frac, kv_total)
    return PlacementResult("kvcached", per)


def crosspool(models: Sequence[ModelConfig], hw: Hardware,
              kv_gpus: int = 1) -> PlacementResult:
    """The paper: FFN weights of ALL models consolidated on (n-kv_gpus)
    weight-pool GPUs; attention + non-FFN weights + the shared KV pool on
    ``kv_gpus``; KV is sequence-shared so one request sees the whole pool."""
    non_ffn = sum(_weights_bytes(c, hw) - _ffn_bytes(c, hw) for c in models)
    ffn = sum(_ffn_bytes(c, hw) for c in models)
    weight_pool_hbm = (hw.n_gpus - kv_gpus) * hw.hbm_bytes
    assert ffn <= weight_pool_hbm, (
        f"FFN weights {ffn / 1e9:.1f}GB exceed weights pool "
        f"{weight_pool_hbm / 1e9:.1f}GB")
    kv_total = max(kv_gpus * hw.hbm_bytes - non_ffn, 0.0)
    per = {c.name: (kv_total, kv_total) for c in models}
    return PlacementResult("crosspool", per)


def kv_availability_fraction(n_kv_heads: int, n_gpus: int,
                             disaggregated: bool) -> float:
    """Fig. 2: fraction of total KV capacity visible to a single request."""
    if disaggregated:
        return 1.0
    tp = min(max(n_kv_heads, 1), n_gpus)
    replicas = n_gpus // tp
    return 1.0 / max(replicas, 1)
