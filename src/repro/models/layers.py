"""Shared primitive layers: norms, RoPE, MLPs, embeddings, initializers."""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0) -> jax.Array:
    """LeCun-normal over the input dimension(s)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis]))
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 statistics regardless of input dtype."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def head_rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (Qwen3/gemma3 style): normalizes the head_dim axis."""
    return rms_norm(x, weight, eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_sin_cos(positions: jax.Array, dim: int, theta: float
                 ) -> Tuple[jax.Array, jax.Array]:
    """sin/cos tables for given integer positions.  Returns [..., dim//2]."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # [..., dim/2]
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """Rotate pairs (x_even, x_odd) of the trailing dim.

    ``x``: [..., S, H, D]; ``sin``/``cos``: [..., S, D//2] broadcastable after
    inserting the head axis.
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    s = sin[..., None, :]
    c = cos[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dtype)


def sinusoidal_positions(positions: jax.Array, dim: int) -> jax.Array:
    """Whisper-style absolute sinusoidal position embeddings [..., dim]."""
    half = dim // 2
    log_timescale = math.log(10_000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    scaled = positions.astype(jnp.float32)[..., None] * inv
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "wg": dense_init(ks[0], (d_model, d_ff), dtype),
            "wu": dense_init(ks[1], (d_model, d_ff), dtype),
            "wd": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    if kind == "gelu":
        return {
            "wi": dense_init(ks[0], (d_model, d_ff), dtype),
            "wo": dense_init(ks[1], (d_ff, d_model), dtype),
        }
    raise ValueError(f"unknown mlp kind {kind}")


def apply_mlp(params: dict, x: jax.Array, kind: str, hook=None) -> jax.Array:
    """Position-wise MLP.  ``hook`` (optional) constrains the hidden layout —
    this is where the weights-pool sharding of dense FFNs attaches."""
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wu"])
        if hook is not None:
            h = hook(h)
        return h @ params["wd"]
    if kind == "gelu":
        h = jax.nn.gelu(x @ params["wi"], approximate=True)
        if hook is not None:
            h = hook(h)
        return h @ params["wo"]
    raise ValueError(f"unknown mlp kind {kind}")


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int, dtype, tie: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": embed_init(k1, (vocab, d_model), dtype)}
    if not tie:
        p["head"] = dense_init(k2, (d_model, vocab), dtype)
    return p


def embed_tokens(params: dict, tokens: jax.Array) -> jax.Array:
    return params["tok"][tokens]


def unembed(params: dict, x: jax.Array) -> jax.Array:
    if "head" in params:
        return x @ params["head"]
    return x @ params["tok"].T
