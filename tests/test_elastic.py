"""Elastic rebalancer invariants (DESIGN.md §8).

* property test: arbitrary grow/shrink/swap/fault sequences on the
  virtualizer never lose or alias a mapped page — device ids stay unique
  and account exactly against the budget, host swap slots stay unique,
  and ``utilization()`` stays consistent, including across mid-sequence
  ``OutOfPagesError``;
* token-level bit-exactness: a decode stream crossing a forced
  shrink -> swap-out -> fault-in -> grow cycle reproduces the
  unperturbed paged stream EXACTLY (and the dense reference numerically)
  in BOTH lowering modes;
* arena: shrink evicts idle LRU models, compacts survivors bit-exactly,
  and respects the pinned floor;
* hysteresis determinism: two rebalancers fed the same recorded
  observation stream make identical decisions;
* engine acceptance: under a page-pressure burst the rebalancer converts
  idle arena slack into KV pages, and every request's token stream is
  bit-exact with the frozen-split engine's.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ElasticConfig, PAPER_COLOC_SET, get_smoke_config
from repro.core.control import HostDrivenStep, PagedFusedStep
from repro.core.elastic import ElasticRebalancer
from repro.core.pools import build_pools
from repro.core.virtualizer import KVVirtualizer, OutOfPagesError
from repro.core.weight_pool import OutOfSlabsError
from repro.models import build_model
from repro.runtime.telemetry import DemandTelemetry


# ---------------------------------------------------------------------------
# property: no page is ever lost or aliased
# ---------------------------------------------------------------------------

def _check_invariants(virt: KVVirtualizer) -> None:
    device = []
    swapped = []
    for req in virt.requests.values():
        dev = [(id(tab), i, p) for tab, i, p in req.device_entries()]
        sw = [(id(tab), i, s) for tab, i, s in req.swapped_entries()]
        assert req.n_swapped == len(sw), "n_swapped drifted"
        device.extend(p for _, _, p in dev)
        swapped.extend(s for _, _, s in sw)
    assert len(device) == len(set(device)), "aliased device page"
    assert len(swapped) == len(set(swapped)), "aliased swap slot"
    assert not set(device) & set(virt.free_list), "mapped page in free list"
    assert all(0 <= p < virt.page_budget for p in device), \
        "device page out of budget"
    assert len(device) + virt.free_pages == virt.page_budget, "page leak"
    if virt.swap_buffer is not None:
        assert not set(swapped) & set(virt.swap_free), \
            "held swap slot in swap free list"
        assert len(swapped) + len(virt.swap_free) == len(virt.swap_buffer)
    assert virt.swapped_now == len(swapped)
    u = virt.utilization()
    assert u["mapped_pages"] == len(device)
    assert u["swapped_pages"] == len(swapped)


@settings(max_examples=15, deadline=None)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["register", "extend", "release", "swap",
                               "fault", "grow", "shrink"]),
              st.sampled_from(list(PAPER_COLOC_SET)),
              st.integers(1, 600)),
    min_size=1, max_size=40))
def test_property_elastic_never_loses_or_aliases_pages(ops):
    """Random map/extend/release/swap/fault/resize interleavings keep the
    page accounting exact — including sequences where a resize or fault
    raises OutOfPagesError mid-run."""
    budget = 64
    virt = KVVirtualizer({n: get_smoke_config(n) for n in PAPER_COLOC_SET},
                         page_budget=budget, page_bytes=4096,
                         allocate_device_pool=False)
    live = {}
    next_id = 0
    for op, model, arg in ops:
        try:
            if op == "register" or not live:
                virt.register_request(next_id, model, arg)
                live[next_id] = model
                next_id += 1
            elif op == "extend":
                virt.extend_request(next(iter(live)), arg)
            elif op == "release":
                rid = next(iter(live))
                virt.release_request(rid)
                del live[rid]
            elif op == "swap":
                virt.swap_out(next(iter(live)), max_pages=arg)
            elif op == "fault":
                virt.ensure_resident(next(iter(live)))
            elif op == "grow":
                virt.resize(virt.page_budget + (arg % 64) + 1)
            else:                                     # shrink
                target = max(virt.page_budget - (arg % 64) - 1, 1)
                virt.resize(target)
        except OutOfPagesError:
            pass
        _check_invariants(virt)
    for rid in list(live):
        virt.release_request(rid)
    assert virt.free_pages == virt.page_budget
    assert virt.swapped_now == 0


# ---------------------------------------------------------------------------
# token-level bit-exactness across a forced shrink -> swap -> grow cycle
# ---------------------------------------------------------------------------

def _paged_setup(name):
    cfg = get_smoke_config(name).replace(dtype="float32")
    models = {name: cfg}
    model = build_model(cfg)
    params = {name: model.init(jax.random.PRNGKey(0))}
    kv_pool, w_pool, pooled = build_pools(
        models, params, page_budget=256, page_bytes=4096,
        pool_dtype=jnp.float32)
    return cfg, model, params, kv_pool.virtualizer, pooled


def _fresh_stream_virt(virt_proto, name, model, params, seq, B):
    """A fresh virtualizer over the same geometry with both requests'
    prompt KV written (the same bytes every stream starts from)."""
    virt = KVVirtualizer({name: virt_proto.configs[name]},
                         page_budget=virt_proto.page_budget,
                         page_bytes=virt_proto.page_bytes,
                         dtype=virt_proto.dtype)
    rng = np.random.default_rng(0)
    cfg = virt_proto.configs[name]
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, seq)), jnp.int32)
    cache = model.init_cache(B, 16)
    _, cache = model.prefill(params[name], tokens, cache)
    for b in range(B):
        virt.register_request(b, name, seq)
        virt.write_prompt_from_cache(name, b, cache, seq, batch_index=b)
    return virt, cache


@pytest.mark.parametrize("name", ["qwen3-moe-235b-a22b", "minicpm3-4b"])
@pytest.mark.parametrize("lowering", [True, False])
def test_decode_bitexact_across_shrink_swap_fault_cycle(name, lowering):
    """Greedy-decode two requests; mid-stream, force the full elastic
    cycle on the live pool (swap the ACTIVE requests out, shrink+compact,
    grow back, fault in).  Every post-cycle step's logits must equal the
    unperturbed paged stream bit-for-bit, and the dense reference
    numerically."""
    cfg, model, params, virt_proto, pooled = _paged_setup(name)
    B, seq, n_steps, cycle_at = 2, 8, 5, 2
    view = virt_proto.views[name]
    max_pages = max(1, math.ceil(16 / view.tokens_per_page))
    devs = jax.devices()
    step = (PagedFusedStep(pooled[name]) if lowering
            else HostDrivenStep(pooled[name], devs[0], devs[-1]))

    def run(perturb: bool):
        virt, cache = _fresh_stream_virt(virt_proto, name, model, params,
                                         seq, B)
        dense_cache = jax.tree.map(lambda x: x, cache)
        out = []
        next_tok = jnp.zeros((B,), jnp.int32)
        for t in range(n_steps):
            if perturb and t == cycle_at:
                # the full cycle, against ACTIVE requests: swap out both
                # streams' pages, shrink (compacts survivors: none left
                # mapped, so this exercises the degenerate gather too),
                # grow back, fault in on "next touch"
                assert virt.swap_out(0) > 0
                virt.swap_out(1)
                mapped = virt.mapped_pages
                virt.resize(max(mapped + 2, 8))
                assert virt.page_budget < 256
                virt.resize(256)
            length = seq + t
            want, dense_cache = model.decode_step(
                params[name], next_tok, dense_cache, jnp.int32(length))
            for b in range(B):
                virt.ensure_resident(b)        # the swap tier's next touch
                virt.extend_request(b, 1)
            tables = virt.batch_tables(name, [0, 1], max_pages)
            got, virt.pool = step(next_tok, virt.pool, tables,
                                  jnp.full((B,), length, jnp.int32))
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)
            out.append(np.asarray(got))
            next_tok = jnp.argmax(want, axis=-1).astype(jnp.int32)
        return out

    reference = run(perturb=False)
    perturbed = run(perturb=True)
    assert len(reference) == len(perturbed) == n_steps
    for t, (a, b) in enumerate(zip(reference, perturbed)):
        np.testing.assert_array_equal(
            a, b, err_msg=f"step {t} diverged across the elastic cycle")


# ---------------------------------------------------------------------------
# arena shrink/grow
# ---------------------------------------------------------------------------

def test_arena_resize_evicts_idle_compacts_pinned_bitexact():
    names = list(PAPER_COLOC_SET)
    models = {n: get_smoke_config(n).replace(dtype="float32") for n in names}
    params = {n: build_model(c).init(jax.random.PRNGKey(i))
              for i, (n, c) in enumerate(models.items())}
    _, w_pool, pooled = build_pools(models, params, page_budget=32,
                                    page_bytes=4096, slab_bytes=4096)
    arena = w_pool.arena
    keep = names[0]
    arena.pin(keep)
    ref = arena.views[keep].unpack_layer(arena.arena,
                                         arena.slot_table(keep)[0])
    ref_leaves = [np.asarray(x) for x in jax.tree.leaves(ref)]

    floor = arena.min_slot_budget()
    r = arena.resize(floor)
    assert r["evicted"] >= 1, "idle models should be LRU-evicted"
    assert set(arena.residency) == {keep}
    assert arena.slot_budget == floor
    # compaction moved the pinned model's slabs; the unpacked weights are
    # bit-for-bit identical through the remapped slot table
    got = arena.views[keep].unpack_layer(arena.arena,
                                         arena.slot_table(keep)[0])
    for a, b in zip(ref_leaves, jax.tree.leaves(got)):
        np.testing.assert_array_equal(a, np.asarray(b))
    # shrinking below the pinned resident set must refuse loudly
    assert floor > 1
    with pytest.raises(OutOfSlabsError):
        arena.resize(floor - 1)
    assert arena.slot_budget == floor
    # grow back: an evicted model re-activates and reproduces its weights
    grow_to = floor + arena.views[names[1]].total_slabs
    arena.resize(grow_to)
    arena.activate(names[1])
    assert arena.is_resident(names[1])


# ---------------------------------------------------------------------------
# hysteresis determinism on a fixed observation stream
# ---------------------------------------------------------------------------

def _scripted_rebalancer(cfg):
    models = {n: get_smoke_config(n) for n in PAPER_COLOC_SET[:2]}
    virt = KVVirtualizer(models, page_budget=64, page_bytes=4096,
                         allocate_device_pool=False)
    params = {n: build_model(c.replace(dtype="float32")).init(
        jax.random.PRNGKey(i)) for i, (n, c) in enumerate(models.items())}
    _, w_pool, _ = build_pools(
        {n: c.replace(dtype="float32") for n, c in models.items()}, params,
        page_budget=64, page_bytes=4096, slab_bytes=4096,
        allocate_device_pool=False, allocate_device_arena=False)
    telemetry = DemandTelemetry(models, cfg)
    reb = ElasticRebalancer(virt, w_pool.arena, telemetry=telemetry,
                            cfg=cfg, seed=7)
    return virt, w_pool.arena, telemetry, reb


def test_hysteresis_decisions_deterministic_on_fixed_trace():
    """The same recorded observation stream (arrivals, completions,
    occupancy samples on a virtual clock) must produce the IDENTICAL
    decision sequence — the re-plan Monte Carlo runs on a fixed seed."""
    cfg = ElasticConfig(interval_steps=2, cooldown_steps=2, hysteresis=0.02,
                        window_s=40.0, min_page_budget=4)
    m0 = PAPER_COLOC_SET[0]

    def drive(reb, virt, telemetry):
        decisions = []
        rng = np.random.default_rng(3)
        now = 0.0
        for step in range(30):
            now += 0.25
            if step % 2 == 0:
                telemetry.note_arrival(m0, now)
            if step % 5 == 4:
                telemetry.note_finish(m0, int(rng.integers(8, 32)),
                                      int(rng.integers(2, 8)),
                                      now - 1.0, now)
            telemetry.observe(now, virt, reb.arena, None)
            d = reb.step(now)
            decisions.append(None if d is None else
                             (d.step, d.new_page_budget, d.new_slot_budget,
                              d.reason))
        return decisions

    virt1, arena1, tel1, reb1 = _scripted_rebalancer(cfg)
    virt2, arena2, tel2, reb2 = _scripted_rebalancer(cfg)
    d1 = drive(reb1, virt1, tel1)
    d2 = drive(reb2, virt2, tel2)
    assert d1 == d2
    assert any(d is not None for d in d1), \
        "the scripted trace should trigger at least one rebalance"
    # applied decisions conserve device bytes
    for d in reb1.events:
        assert (d.new_page_budget * virt1.page_bytes
                + d.new_slot_budget * arena1.slab_bytes) <= reb1.total_bytes


# ---------------------------------------------------------------------------
# telemetry / admission pressure signals
# ---------------------------------------------------------------------------

def test_telemetry_window_and_admission_reserve():
    models = {n: get_smoke_config(n) for n in PAPER_COLOC_SET[:1]}
    name = next(iter(models))
    cfg = ElasticConfig(window_s=10.0, ewma_alpha=0.5)
    tel = DemandTelemetry(models, cfg)
    virt = KVVirtualizer(models, page_budget=16, page_bytes=4096,
                         allocate_device_pool=False)
    tel.note_arrival(name, 0.0)
    tel.note_arrival(name, 1.0)
    tel.note_finish(name, 8, 4, 0.5, 2.0)
    virt.register_request(0, name, 8)
    tel.observe(2.0, virt, None, None)
    assert tel.kv_occupancy_ewma > 0.0
    assert tel.arrival_rate(name, 2.0) == pytest.approx(2 / 2.0)
    specs = tel.window_specs(2.0)
    assert len(specs) == 1 and specs[0].model.name == name
    # events age out of the window
    tel.observe(50.0, virt, None, None)
    assert tel.window_specs(50.0) == []
    # admission reserve: held-back pages make can_admit conservative
    assert virt.can_admit(name, 1, 0, reserve=0)
    assert not virt.can_admit(name, 1, 0, reserve=virt.free_pages)


# ---------------------------------------------------------------------------
# engine acceptance: burst converts arena slack into KV pages, bit-exact
# ---------------------------------------------------------------------------

class TestEngineElastic:
    def _engine(self, elastic):
        from repro.runtime.engine import CrossPoolEngine, EngineMode
        # minicpm3 (MLA, dense FFN -> batch-independent logits) is the
        # serving target; qwen3-moe is registered but never used, so its
        # all-resident arena share is idle slack the rebalancer can
        # convert into KV pages
        models = {n: get_smoke_config(n).replace(dtype="float32")
                  for n in ("minicpm3-4b", "qwen3-moe-235b-a22b")}
        return CrossPoolEngine(
            models, page_budget=24, page_bytes=4096, slab_bytes=4096,
            max_batch=4, max_ctx=64,
            mode=EngineMode(pipeline=True, lowering=True),
            elastic=elastic)

    def _burst(self, n=6):
        from repro.runtime.request import Request
        rng = np.random.default_rng(11)
        cfg = get_smoke_config("minicpm3-4b")
        return [Request(i, "minicpm3-4b", 16, 3, 0.0,
                        prompt_ids=rng.integers(0, cfg.vocab_size, 16))
                for i in range(n)]

    def test_burst_rebalances_and_streams_bitexact(self):
        elastic = ElasticConfig(interval_steps=1, cooldown_steps=1,
                                hysteresis=0.05, window_s=60.0,
                                min_page_budget=8, quantile=0.95)
        eng_e = self._engine(elastic)
        eng_f = self._engine(None)
        stats_e = eng_e.run(self._burst())
        reqs_f = self._burst()
        stats_f = eng_f.run(reqs_f)
        assert stats_e.tokens_out == stats_f.tokens_out > 0
        # the page-pressure burst must trigger at least one KV grow
        assert stats_e.rebalance_events, "burst never rebalanced"
        assert any(e.kv_delta_bytes > 0 for e in stats_e.rebalance_events)
        assert eng_e.virt.page_budget > 24
        # byte conservation across every applied move
        for e in stats_e.rebalance_events:
            total = (e.page_budget[1] * eng_e.virt.page_bytes
                     + e.slot_budget[1] * eng_e.arena.slab_bytes)
            assert total <= eng_e.rebalancer.total_bytes
        # token-level bit-exactness per request vs the frozen split
        done_e = {h.request.request_id: h.request.output_ids
                  for h in eng_e.handles.values()}
        for req in reqs_f:
            assert done_e[req.request_id] == req.output_ids, \
                f"request {req.request_id} diverged under rebalancing"

    def test_queued_only_load_unblocked_by_rebalance(self):
        """A request too large for the frozen KV split queues forever on
        the frozen engine; with elastic on, the queue itself is the
        demand signal — the rebalancer grows the pool and the SAME step
        re-drains the front door, so run() keeps making progress instead
        of exiting on an event-less step."""
        from repro.runtime.engine import CrossPoolEngine, EngineMode
        from repro.runtime.request import Request
        models = {n: get_smoke_config(n).replace(dtype="float32")
                  for n in ("minicpm3-4b", "qwen3-moe-235b-a22b")}
        elastic = ElasticConfig(interval_steps=1, cooldown_steps=1,
                                hysteresis=0.05, min_page_budget=4,
                                max_step_fraction=64.0, window_s=60.0)
        engine = CrossPoolEngine(
            models, page_budget=4, page_bytes=1024, slab_bytes=4096,
            max_batch=2, max_ctx=64,
            mode=EngineMode(pipeline=True, lowering=True), elastic=elastic)
        # needs more pages than the whole initial budget -> queued
        req = Request(0, "minicpm3-4b", 32, 2, 0.0)
        assert not engine.virt.can_admit("minicpm3-4b", 32, 2)
        stats = engine.run([req])
        assert engine.rebalancer.events, "queue pressure never rebalanced"
        assert engine.virt.page_budget > 4
        assert req.finish_time > 0 and stats.tokens_out > 0, \
            "queued-only load was never admitted after the grow"
