"""Session flight recorder: the engine's black box (DESIGN.md §13).

The recorder captures everything that DETERMINES a session — the causal
input stream — plus enough derived state to audit a replay:

  * **ops** — submits (full request fields, prompt ids included),
    ``step``/``advance`` virtual-clock reads, cancels (with their
    in-step flag), ``reset_stats`` calls, and test-harness corruption
    injections.  Replaying the ops bit-exactly reproduces the session.
  * **clock** — every wall-clock dt the engine folded into virtual time
    (one entry per dispatch, tagged by site).  On replay the engine
    consumes this stream instead of ``time.perf_counter`` — the ONLY
    nondeterministic input the engine has.
  * informational events — applied rebalance decisions, cache
    hit/evict/fault, swap traffic, admission verdicts, K-block commits,
    SLO breaches.  Derived, so a replay must REPRODUCE them; the
    replayer diffs the whole event ring.
  * **snapshots** — periodic pool accounting at quiescent step
    boundaries (page holder classes, slab residency, refcounts,
    cache tree), and one final snapshot at dump time.
  * **streams** — per-request token ids and virtual emission times,
    accumulated at the emission site so ``reset_stats()`` pruning
    cannot lose them.

Everything bounded is a ring with a per-kind drop counter; the replayer
refuses a record whose *causal* kinds dropped (informational drops only
degrade the diff).  The recorder is a :class:`CoreHooks` sink attached
BEFORE the sanitizer, so a raising audit cannot hide the event that
tripped it.  Pure observation: attaching a recorder never changes
engine behavior, which is what makes "record the original, re-record
the replay, diff the records" a sound equality check.
"""
from __future__ import annotations

import collections
import dataclasses
import json
from typing import Any, Deque, Dict, List, Optional

import numpy as np

from repro.configs.base import (
    CacheConfig,
    ElasticConfig,
    EngineConfig,
    FlightRecorderConfig,
    MLAConfig,
    ModelConfig,
    SLObjective,
    SLOConfig,
    SSMConfig,
)
from repro.core.hooks import CoreHooks

RECORD_VERSION = 1

# op kinds whose loss makes a record non-replayable (vs. merely degrading
# the informational diff)
CAUSAL_KINDS = ("op", "clock")


class ReplayDivergence(RuntimeError):
    """A replayed session stopped matching its record's causal structure
    (clock stream exhausted or tag-mismatched) — the state diverged
    before the output diff could even run."""


# ---------------------------------------------------------------------------
# config (de)serialization — the record header must round-trip through
# JSON into an engine constructed bit-identically
# ---------------------------------------------------------------------------


def model_config_to_dict(cfg: ModelConfig) -> Dict[str, Any]:
    return dataclasses.asdict(cfg)


def model_config_from_dict(d: Dict[str, Any]) -> ModelConfig:
    d = dict(d)
    if d.get("mla") is not None:
        d["mla"] = MLAConfig(**d["mla"])
    if d.get("ssm") is not None:
        d["ssm"] = SSMConfig(**d["ssm"])
    return ModelConfig(**d)


def slo_config_to_dict(cfg: Optional[SLOConfig]) -> Optional[Dict[str, Any]]:
    if cfg is None:
        return None
    return {
        "objectives": {m: dataclasses.asdict(o)
                       for m, o in cfg.objectives.items()},
        "window_s": cfg.window_s,
        "short_window_s": cfg.short_window_s,
        "burn_rate_threshold": cfg.burn_rate_threshold,
    }


def slo_config_from_dict(d: Optional[Dict[str, Any]]) -> Optional[SLOConfig]:
    if d is None:
        return None
    return SLOConfig(
        objectives={m: SLObjective(**o) for m, o in d["objectives"].items()},
        window_s=d["window_s"],
        short_window_s=d["short_window_s"],
        burn_rate_threshold=d["burn_rate_threshold"],
    )


def engine_header(*, models, page_budget, page_bytes, slot_budget,
                  slab_bytes, max_batch, max_ctx, seed, mode, elastic,
                  cache, sanitize, slo, flightrec) -> Dict[str, Any]:
    """Everything the replayer needs to rebuild the engine.  Model order
    matters (params are initialized from ``PRNGKey(i)`` in dict order)
    and JSON objects preserve it."""
    return {
        "models": {name: model_config_to_dict(cfg)
                   for name, cfg in models.items()},
        "page_budget": page_budget,
        "page_bytes": page_bytes,
        "slot_budget": slot_budget,
        "slab_bytes": slab_bytes,
        "max_batch": max_batch,
        "max_ctx": max_ctx,
        "seed": seed,
        "mode": dataclasses.asdict(mode),
        "elastic": dataclasses.asdict(elastic) if elastic is not None else None,
        "cache": dataclasses.asdict(cache) if cache is not None else None,
        "sanitize": bool(sanitize),
        "slo": slo_config_to_dict(slo),
        "flightrec": dataclasses.asdict(flightrec),
    }


def engine_config_from_header(h: Dict[str, Any], *,
                              dump_path: Optional[str] = None) -> EngineConfig:
    """Header -> :class:`EngineConfig` (EngineMode is reconstructed by
    the replayer, which may import the runtime layer)."""
    fr = dict(h["flightrec"])
    fr["dump_path"] = dump_path
    return EngineConfig(
        elastic=ElasticConfig(**h["elastic"]) if h["elastic"] else None,
        cache=CacheConfig(**h["cache"]) if h["cache"] else None,
        sanitize=h["sanitize"],
        slo=slo_config_from_dict(h["slo"]),
        flightrec=FlightRecorderConfig(**fr),
    )


def request_to_dict(req) -> Dict[str, Any]:
    ids = req.prompt_ids
    return {
        "request_id": req.request_id,
        "model": req.model,
        "prompt_tokens": req.prompt_tokens,
        "max_new_tokens": req.max_new_tokens,
        "arrival_time": req.arrival_time,
        "prompt_ids": (None if ids is None
                       else np.asarray(ids).astype(int).tolist()),
        "eos_id": req.eos_id,
        "cache": bool(req.cache),
    }


# ---------------------------------------------------------------------------
# pool snapshots
# ---------------------------------------------------------------------------


def pool_snapshot(virt, arena=None, cache=None) -> Dict[str, Any]:
    """One quiescent-boundary pool snapshot: KV pages partitioned by
    holder class, slab residency by model, swap depth, cache tree.  All
    integer counters over deterministic state — a replay reproduces it
    bit-exactly, so the replayer diffs snapshots too."""
    kv = virt.accounting_snapshot()
    tree = int(cache.device_pages_held) if cache is not None else 0
    kv["tree_pages"] = tree
    return {
        "kv": kv,
        "arena": (None if arena is None else {
            "slot_budget": arena.slot_budget,
            "resident_slabs": arena.resident_slabs,
            "free_slabs": arena.free_slabs,
            "resident": arena.residency_by_model(),
        }),
        "cache": cache.snapshot() if cache is not None else None,
    }


# ---------------------------------------------------------------------------
# the recorder
# ---------------------------------------------------------------------------


class FlightRecorder(CoreHooks):
    """Bounded black-box recorder; also a pool-hook sink.

    Constructed by the engine (``EngineConfig(flightrec=...)``) with
    references to the pools so on-demand/auto dumps can snapshot final
    accounting.  All methods are cheap appends; the engine guards every
    call site with one ``is not None`` check so the recorder-off path
    does no work and no allocation.
    """

    def __init__(self, cfg: FlightRecorderConfig, *, header: Dict[str, Any],
                 virt=None, arena=None, cache=None):
        self.cfg = cfg
        self.header = header
        self.virt = virt
        self.arena = arena
        self.cache = cache
        self.ring: Deque[Dict[str, Any]] = collections.deque(
            maxlen=max(int(cfg.ring_size), 1))
        self.snapshots: Deque[Dict[str, Any]] = collections.deque(
            maxlen=max(int(cfg.max_snapshots), 1))
        self.dropped: collections.Counter = collections.Counter()
        self.streams: Dict[int, Dict[str, Any]] = {}
        self.failure: Optional[Dict[str, Any]] = None
        self.step = 0                  # stamped onto every ring entry
        self.dumps = 0
        self._breach_dumped = False

    # -- ring ----------------------------------------------------------
    def _push(self, kind: str, **fields) -> None:
        ring = self.ring
        if len(ring) == ring.maxlen:
            self.dropped[ring[0]["kind"]] += 1
        entry = {"kind": kind, "step": self.step}
        entry.update(fields)
        ring.append(entry)

    # -- causal input ops (driven by the engine session API) -----------
    def record_step(self, step: int, now: float) -> None:
        self.step = step
        self._push("op", op="step", now=now)

    def record_op(self, op: str, **fields) -> None:
        self._push("op", op=op, **fields)

    def record_submit(self, req, now: float) -> None:
        self._push("op", op="submit", now=now, request=request_to_dict(req))

    def record_cancel(self, rid: int, now: float, *, in_step: bool) -> None:
        self._push("op", op="cancel", rid=rid, now=now, in_step=in_step)

    def record_dt(self, tag: str, dt: float) -> None:
        self._push("clock", tag=tag, dt=dt)

    # -- derived events (diffed on replay, not re-driven) ---------------
    def record_commit(self, rid: int, model: str, tokens: int,
                      dt: float, *, first: bool = False) -> None:
        self._push("commit", rid=rid, model=model, tokens=tokens, dt=dt,
                   first=first)

    def note_token(self, rid: int, model: str, token: int,
                   when: float) -> None:
        stream = self.streams.get(rid)
        if stream is None:
            stream = self.streams[rid] = {
                "model": model, "tokens": [], "times": []}
        stream["tokens"].append(int(token))
        stream["times"].append(float(when))

    # -- pool hook overrides (informational ring events) ----------------
    def kv_swap_out(self, pages):
        self._push("kv_swap_out", pages=pages)

    def kv_swap_in(self, pages):
        self._push("kv_swap_in", pages=pages)

    def kv_resize(self, old_pages, new_pages, swapped_out, moved):
        self._push("kv_resize", old=old_pages, new=new_pages,
                   swapped_out=swapped_out, moved=moved)

    def arena_activate(self, model, slabs):
        self._push("arena_activate", model=model, slabs=slabs)

    def arena_evict(self, model, slabs):
        self._push("arena_evict", model=model, slabs=slabs)

    def arena_resize(self, old_slots, new_slots, evicted, moved):
        self._push("arena_resize", old=old_slots, new=new_slots,
                   evicted=evicted, moved=moved)

    def admission(self, model, outcome, blocker):
        self._push("admission", model=model, outcome=outcome,
                   blocker=blocker)

    def cache_hit(self, model, tokens):
        self._push("cache_hit", model=model, tokens=tokens)

    def cache_evict(self, pages):
        self._push("cache_evict", pages=pages)

    def cache_fault(self, pages):
        self._push("cache_fault", pages=pages)

    def rebalance(self, decision):
        self._push("rebalance", decision=decision.to_record())

    def slo_breach(self, breach):
        self._push("slo_breach", model=breach.model, metric=breach.metric,
                   long_burn=breach.long_burn, short_burn=breach.short_burn)
        if (self.cfg.dump_path and self.cfg.dump_on_breach
                and not self._breach_dumped):
            # deferred to the step boundary (engine calls
            # maybe_breach_dump): a mid-step dump would capture pool state
            # the replayed step — which always runs to completion — can
            # never land on, breaking the bit-exact diff
            self._breach_dumped = True

    def maybe_breach_dump(self) -> bool:
        """Quiescent-boundary half of the breach auto-dump: called by the
        engine after step-end bookkeeping and the sanitizer audit."""
        if self._breach_dumped and self.dumps == 0 and self.cfg.dump_path:
            self.dump(self.cfg.dump_path)
            return True
        return False

    # (kv_reserved/kv_trimmed/arena_upload/admission_wait/cache_miss are
    # deliberately NOT ringed: high-volume and fully derivable.)

    # -- snapshots -------------------------------------------------------
    def snapshot_due(self, step: int) -> bool:
        return step % max(int(self.cfg.snapshot_interval_steps), 1) == 0

    def snapshot(self, step: int, now: float, snap: Dict[str, Any]) -> None:
        entry = {"step": step, "now": now}
        entry.update(snap)
        self.snapshots.append(entry)

    # -- failure + dump --------------------------------------------------
    def note_failure(self, step: int, err: BaseException) -> None:
        """Stamp the failing step and auto-dump (once stamped, the record
        is an incident artifact: the replayer asserts the SAME error type
        and sanitizer rule at the SAME step)."""
        self.failure = {
            "step": step,
            "type": type(err).__name__,
            "rule": getattr(err, "rule", None),
            "error": str(err),
        }
        if self.cfg.dump_path:
            self.dump(self.cfg.dump_path)

    def to_record(self) -> Dict[str, Any]:
        return {
            "version": RECORD_VERSION,
            "engine": self.header,
            "events": list(self.ring),
            "dropped": dict(self.dropped),
            "snapshots": list(self.snapshots),
            "streams": {str(rid): stream
                        for rid, stream in self.streams.items()},
            "failure": self.failure,
            "final": (pool_snapshot(self.virt, self.arena, self.cache)
                      if self.virt is not None else None),
        }

    def dump(self, path: Optional[str] = None) -> str:
        path = path or self.cfg.dump_path
        if not path:
            raise ValueError("no dump path: pass one or set "
                             "FlightRecorderConfig.dump_path")
        with open(path, "w") as f:
            json.dump(self.to_record(), f)
        self.dumps += 1
        return str(path)


# ---------------------------------------------------------------------------
# record accessors (shared by the replayer and tests)
# ---------------------------------------------------------------------------


def record_ops(record: Dict[str, Any]) -> List[Dict[str, Any]]:
    return [e for e in record["events"] if e["kind"] == "op"]


def record_clock(record: Dict[str, Any]) -> List[tuple]:
    return [(e["tag"], e["dt"]) for e in record["events"]
            if e["kind"] == "clock"]


def causal_drops(record: Dict[str, Any]) -> Dict[str, int]:
    dropped = record.get("dropped", {})
    return {k: v for k, v in dropped.items() if k in CAUSAL_KINDS and v}


# ---------------------------------------------------------------------------
# corruption injection (test/debug surface)
# ---------------------------------------------------------------------------

INJECTION_KINDS = ("double_free", "refcount_drift")


def inject_corruption(engine, kind: str) -> None:
    """Deliberately corrupt pool state AND record the injection as a
    causal op, so a replay re-applies it and trips the SAME sanitizer
    rule at the SAME step — how a dumped incident record proves the
    replayer reproduces failures, not just healthy runs."""
    if engine.recorder is not None:
        engine.recorder.record_op("inject", corruption=kind, now=engine.now)
    virt = engine.virt
    if kind == "double_free":
        if not virt.free_list:
            raise ValueError("double_free needs a non-empty free list")
        # page now on the free list while still free -> SAN01
        virt.free_list.append(virt.free_list[0])
    elif kind == "refcount_drift":
        if not virt.requests:
            raise ValueError("refcount_drift needs a live request")
        req = next(iter(virt.requests.values()))
        for _, _, page in req.device_entries():
            # explicit refcount with no matching holders -> SAN03
            virt._refs[page] = 7
            break
        else:
            raise ValueError("refcount_drift needs a device-resident page")
    else:
        raise ValueError(
            f"unknown injection {kind!r}; known: {INJECTION_KINDS}")
