import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend initialization, and the multi-pod dry-run needs 512
# placeholder host devices to build the production meshes.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. constructs the Strategy (train / crosspool / monolithic),
  3. lowers the cell's step function against ShapeDtypeStruct inputs
     (NO real allocation anywhere),
  4. compiles, printing ``memory_analysis()`` (proves per-device fit) and
     ``cost_analysis()`` (FLOPs/bytes for §Roofline),
  5. parses collective bytes from the partitioned HLO,
  6. emits a JSON record consumed by EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape decode_32k
  python -m repro.launch.dryrun --all --multi-pod --out reports/dryrun.json
"""
import argparse
import json
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_NAMES, SHAPES_BY_NAME, get_config,
                           shape_applicable)
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.models import build_model
from repro.runtime.sampler import sample
from repro.sharding.strategies import Strategy, make_strategy
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step, TrainState

SDS = jax.ShapeDtypeStruct

# Gradient-accumulation depth per arch for train_4k (activation-memory
# lever; tuned against memory_analysis -- see EXPERIMENTS.md §Dry-run).
TRAIN_MICROBATCHES = {
    "llama3-405b": 16,
    "qwen3-moe-235b-a22b": 8,
    "llava-next-34b": 8,
    "gemma3-12b": 4,
    "qwen3-14b": 4,
    "moonshot-v1-16b-a3b": 4,
    "minicpm3-4b": 2,
    "zamba2-1.2b": 2,
    "mamba2-130m": 1,
    "whisper-small": 1,
}


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    specs: Dict[str, SDS] = {}
    if shape.kind in ("train", "prefill"):
        txt = S
        if cfg.frontend == "vision_patches":
            txt = S - cfg.frontend_tokens
            specs["embeddings"] = SDS((B, cfg.frontend_tokens, cfg.d_model), dt)
        if cfg.family == "audio":
            specs["encoder_frames"] = SDS((B, cfg.encoder_seq, cfg.d_model), dt)
        specs["tokens"] = SDS((B, txt), jnp.int32)
    else:  # decode: one new token against a seq_len-deep cache
        specs["tokens"] = SDS((B,), jnp.int32)
        specs["lengths"] = SDS((), jnp.int32)
    return specs


def _spec_shardings(strategy: Strategy, specs: Dict[str, SDS]) -> Dict:
    out = {}
    for k, v in specs.items():
        if k == "lengths":
            out[k] = strategy.scalar_sharding()
        else:
            out[k] = strategy.input_sharding(len(v.shape))
    return out


# ---------------------------------------------------------------------------
# Cell builders: (fn, arg_specs, in_shardings)
# ---------------------------------------------------------------------------

def build_train_cell(cfg: ModelConfig, shape: ShapeConfig,
                     strategy: Strategy):
    model = build_model(cfg)
    optimizer = AdamW(
        moment_dtype="bfloat16" if cfg.param_counts()["total"] > 5e10
        else "float32")
    mb = strategy.perf.microbatches or TRAIN_MICROBATCHES.get(cfg.name, 1)
    compress = strategy.perf.compress_grads
    specs = input_specs(cfg, shape)
    extra = None
    if "embeddings" in specs or "encoder_frames" in specs:
        keys = [k for k in ("embeddings", "encoder_frames") if k in specs]
        extra = lambda batch: {k: batch[k] for k in keys}
    step = make_train_step(model, optimizer, hooks=strategy.hooks(),
                           num_microbatches=mb, remat=True,
                           compress=compress, extra_inputs=extra)

    params_spec = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    opt_spec = jax.eval_shape(lambda: optimizer.init(params_spec))
    if compress:
        from repro.training import compression
        ef_spec = jax.eval_shape(
            lambda: compression.init_error_feedback(params_spec))
    else:
        ef_spec = None
    state_spec = TrainState(params_spec, opt_spec, ef_spec)

    p_sh = strategy.params_shardings(params_spec)
    mesh = strategy.mesh
    opt_sh = type(opt_spec)(
        count=NamedSharding(mesh, P()),
        m=strategy.params_shardings(params_spec),
        v=strategy.params_shardings(params_spec),
    )
    ef_sh = strategy.params_shardings(params_spec) if compress else None
    state_sh = TrainState(p_sh, opt_sh, ef_sh)
    batch_spec = dict(specs)
    batch_sh = _spec_shardings(strategy, specs)
    # donate the train state: params/m/v buffers alias their updates —
    # without this the step holds two copies of every 405B-param tensor
    jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    return jitted, (state_spec, batch_spec)


def build_serve_cell(cfg: ModelConfig, shape: ShapeConfig,
                     strategy: Strategy):
    """decode shapes -> serve_step (one token, seq_len-deep cache);
    prefill shapes -> prefill (seed the cache + first logits)."""
    model = build_model(cfg)
    hooks = strategy.hooks()
    mesh = strategy.mesh
    B, S = shape.global_batch, shape.seq_len

    params_spec = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = strategy.params_shardings(params_spec)
    cache_spec = model.cache_specs(B, S, kv_dtype=strategy.perf.kv_dtype)
    c_sh = strategy.cache_shardings(cache_spec)
    specs = input_specs(cfg, shape)
    in_sh = _spec_shardings(strategy, specs)

    if shape.is_decode:
        def serve_step(params, tokens, cache, lengths):
            logits, cache = model.decode_step(params, tokens, cache, lengths,
                                              hooks=hooks)
            return sample(logits), cache

        # donate the KV cache: the updated cache aliases the old buffers
        jitted = jax.jit(
            serve_step,
            in_shardings=(p_sh, in_sh["tokens"], c_sh, in_sh["lengths"]),
            out_shardings=(strategy.input_sharding(1), c_sh),
            donate_argnums=(2,))
        arg_specs = (params_spec, specs["tokens"], cache_spec,
                     specs["lengths"])
        return jitted, arg_specs

    # prefill
    extra_keys = [k for k in ("embeddings", "encoder_frames") if k in specs]

    def prefill_fn(params, tokens, cache, *extra):
        kw = dict(zip(extra_keys, extra))
        logits, cache = model.prefill(params, tokens, cache, hooks=hooks,
                                      **kw)
        return sample(logits), cache

    jitted = jax.jit(
        prefill_fn,
        in_shardings=(p_sh, in_sh["tokens"], c_sh,
                      *[in_sh[k] for k in extra_keys]),
        out_shardings=(strategy.input_sharding(1), c_sh),
        donate_argnums=(2,))
    arg_specs = (params_spec, specs["tokens"], cache_spec,
                 *[specs[k] for k in extra_keys])
    return jitted, arg_specs


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             strategy_name: str = "auto", verbose: bool = True,
             perf=None) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "strategy": strategy_name, "ok": False}

    ok, why = shape_applicable(cfg, shape)
    if not ok:
        record.update(skipped=True, reason=why)
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {why}")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    strategy = make_strategy(strategy_name, mesh, cfg, shape, perf=perf)
    record["strategy"] = strategy.name
    if perf is not None:
        record["perf"] = {k: v for k, v in vars(perf).items()
                          if v not in (None, False)}

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            jitted, arg_specs = build_train_cell(cfg, shape, strategy)
        else:
            jitted, arg_specs = build_serve_cell(cfg, shape, strategy)
        lowered = jitted.lower(*arg_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        from repro.launch.hlo_analysis import xla_cost_analysis
        cost = xla_cost_analysis(compiled)
        hlo = compiled.as_text()

    chips = mesh_chip_count(mesh)
    mb = 1
    if shape.kind == "train":
        mb = (perf.microbatches if perf and perf.microbatches
              else TRAIN_MICROBATCHES.get(arch, 1))
    kv_item = 1 if (perf and perf.kv_dtype == "f8") else 2
    report = rf.build_report(arch=arch, shape=shape, mesh_name=mesh_name,
                             strategy=strategy.name, chips=chips,
                             cost=cost, hlo_text=hlo, cfg=cfg,
                             microbatches=mb, kv_itemsize=kv_item)
    record.update(
        ok=True,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        roofline=report.to_dict(),
    )
    if verbose:
        m = record["memory"]
        arg_gb = (m["argument_bytes"] or 0) / 2 ** 30
        tmp_gb = (m["temp_bytes"] or 0) / 2 ** 30
        r = record["roofline"]
        print(f"[ok] {arch} x {shape_name} x {mesh_name} ({strategy.name}) "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"args {arg_gb:.2f} GiB temp {tmp_gb:.2f} GiB /dev | "
              f"compute {r['t_compute']:.3e}s memory {r['t_memory']:.3e}s "
              f"collective {r['t_collective']:.3e}s -> {r['dominant']}")
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES))
    ap.add_argument("--shape", choices=list(SHAPES_BY_NAME))
    ap.add_argument("--strategy", default="auto",
                    choices=["auto", "train", "crosspool", "monolithic"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape) cell")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPES_BY_NAME:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    records = []
    failures = 0
    for arch, shape in cells:
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           strategy_name=args.strategy)
        except Exception as e:  # a failing cell is a bug in our system
            failures += 1
            rec = {"arch": arch, "shape": shape, "ok": False,
                   "mesh": "pod2x16x16" if args.multi_pod else "pod16x16",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print(f"[FAIL] {arch} x {shape}: {type(e).__name__}: {e}")
        records.append(rec)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")

    n_ok = sum(1 for r in records if r.get("ok"))
    n_skip = sum(1 for r in records if r.get("skipped"))
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped, {failures} failed, "
          f"{len(records)} total ==")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
