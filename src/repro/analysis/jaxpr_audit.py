"""Jaxpr/closure auditor: structural proofs on the fused decode programs.

The lint leg (``repro.analysis.lint``) checks what the SOURCE says; this
leg checks what the COMPILER actually built.  The two failure modes it
exists for are silent by construction:

  * a pool or arena buffer captured by closure instead of passed as an
    argument traces fine and runs fine — but the buffer is baked into
    the executable as a constant, so every recompile embeds a stale
    snapshot and the donated in-place update quietly stops being shared;
  * ``donate_argnums`` is a REQUEST: XLA drops the input-output alias
    silently when a shape/layout mismatch prevents in-place reuse, and
    the only artifact is a second pool-sized allocation per dispatch.

Checks (each finding carries its CPAxx id):

  CPA01  closure-captured constant: tracing the step body yields a
         jaxpr const at or above ``max_const_bytes`` (pool/arena-sized
         data must arrive as parameters, never as baked constants)
  CPA02  dropped donation: the compiled ``HloModule`` header's
         ``input_output_alias`` does not alias the pool parameter
  CPA03  mid-program host transfer: ``host_transfer_count`` > 0 under
         the entry computation (outfeed/infeed/send/recv)
  CPA04  dispatch structure: the while-loop nesting does not match
         ``control.dispatch_count``'s one-dispatch claim — K>1 needs a
         depth-0 while of trip K wrapping the depth-1 layer scan; K=1
         needs the depth-0 layer scan

CLI (the CI static-analysis job): builds the smoke-scale MoE fixture,
compiles the single-step and K=4 fused programs, audits both::

    python -m repro.analysis.jaxpr_audit [--k 4] [-v]
"""
from __future__ import annotations

import argparse
import sys
from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.launch import hlo_analysis as ha

CHECKS = {
    "CPA01": "pool/arena-sized closure constant baked into the program",
    "CPA02": "donate_argnums dropped: pool parameter not aliased in-place",
    "CPA03": "mid-program host transfer inside a fused body",
    "CPA04": "while-loop structure contradicts the dispatch-count claim",
}

#: Consts this large can only be pool/arena/weight data; the legitimate
#: trace-time consts (iota bases, masks for tiny smoke vocabularies)
#: stay well under it.
DEFAULT_MAX_CONST_BYTES = 64 * 1024


@dataclass(frozen=True)
class AuditFinding:
    check: str                 # CPAxx
    target: str                # which program / which object
    message: str

    def __str__(self) -> str:
        return f"{self.target}: {self.check} {self.message}"


# ---------------------------------------------------------------------------
# CPA01: closure-captured constants
# ---------------------------------------------------------------------------

def audit_closure(fn, args: Sequence, *, target: str = "step",
                  max_const_bytes: int = DEFAULT_MAX_CONST_BYTES
                  ) -> List[AuditFinding]:
    """Trace ``fn(*args)`` and flag large jaxpr consts.

    ``fn`` is the UNJITTED body (``jitted.__wrapped__``): anything the
    trace closes over — instead of receiving through ``args`` — lands in
    ``ClosedJaxpr.consts`` and is baked into every executable built from
    the trace.
    """
    import jax
    import numpy as np

    closed = jax.make_jaxpr(fn)(*args)
    findings = []
    for c in closed.consts:
        nbytes = getattr(c, "nbytes", None)
        if nbytes is None:
            nbytes = np.asarray(c).nbytes
        if nbytes >= max_const_bytes:
            shape = getattr(c, "shape", ())
            findings.append(AuditFinding(
                "CPA01", target,
                f"closure-captured constant of {nbytes} bytes "
                f"(shape {tuple(shape)}) baked into the trace — pass it "
                f"as an argument so updates flow and donation can alias "
                f"it"))
    return findings


# ---------------------------------------------------------------------------
# CPA02..CPA04: compiled-HLO structure
# ---------------------------------------------------------------------------

def audit_hlo(hlo: str, *, pool_param: int, n_layers: int, k: int = 1,
              target: str = "step", expect_donation: bool = True
              ) -> List[AuditFinding]:
    """Audit one compiled module's text (donation, transfers, loops).

    ``pool_param`` is the FLATTENED entry-parameter number of the donated
    pool buffer (see :func:`flat_param_index`); ``k`` is the program's
    decode-steps-per-dispatch.  ``expect_donation=False`` skips CPA02 —
    the repo's ``kernels.ops.donate_argnums`` gate disables donation
    wholesale on backends that cannot alias (CPU), and an alias that was
    never requested cannot be "dropped".
    """
    findings = []
    donated = ha.donated_params(hlo)
    if expect_donation and pool_param not in donated:
        findings.append(AuditFinding(
            "CPA02", target,
            f"pool parameter {pool_param} is not input-output aliased "
            f"(aliased params: {donated or 'none'}) — XLA dropped the "
            f"donation, every dispatch double-buffers the pool"))
    transfers = ha.host_transfer_count(hlo)
    if transfers:
        findings.append(AuditFinding(
            "CPA03", target,
            f"{transfers} mid-program host transfer op(s) under ENTRY — "
            f"the fused body must stay on device end to end"))
    trips = ha.while_trip_structure(hlo)
    if k > 1:
        ok = (0, k) in trips and (1, n_layers) in trips
        want = f"a depth-0 while of trip {k} wrapping a depth-1 " \
               f"{n_layers}-trip layer scan"
    else:
        ok = (0, n_layers) in trips
        want = f"a depth-0 {n_layers}-trip layer scan"
    if not ok:
        findings.append(AuditFinding(
            "CPA04", target,
            f"while structure {trips} lacks {want} — the one-dispatch "
            f"claim of control.dispatch_count does not hold for this "
            f"program"))
    return findings


def flat_param_index(args: Sequence, argnum: int) -> int:
    """Flattened entry-parameter number of positional arg ``argnum``.

    jit flattens pytree arguments into one parameter per leaf, in
    order; the pool is positional arg 4 of the fused steps but its HLO
    parameter number is offset by every leaf of the params pytree ahead
    of it.
    """
    import jax

    return sum(len(jax.tree_util.tree_leaves(a)) for a in args[:argnum])


def audit_fused_step(step, args: Sequence, *, n_layers: int, k: int = 1,
                     pool_argnum: int = 4, target: str = "step",
                     max_const_bytes: int = DEFAULT_MAX_CONST_BYTES
                     ) -> List[AuditFinding]:
    """Full audit of one fused step object (``PagedFusedStep`` /
    ``MultiStepFusedStep``): closure trace + compiled-HLO structure.

    CPA02 is checked exactly when the repo actually requested donation
    for this backend (``kernels.ops.donate_argnums`` is the single gate
    every fused step goes through)."""
    from repro.kernels.ops import donate_argnums

    findings = audit_closure(step._step.__wrapped__, args, target=target,
                             max_const_bytes=max_const_bytes)
    hlo = step._step.lower(*args).compile().as_text()
    findings += audit_hlo(hlo, pool_param=flat_param_index(args, pool_argnum),
                          n_layers=n_layers, k=k, target=target,
                          expect_donation=bool(donate_argnums(pool_argnum)))
    return findings


# ---------------------------------------------------------------------------
# CLI fixture: the smoke-scale MoE colocation cell
# ---------------------------------------------------------------------------

def build_and_audit(k: int = 4, *, batch: int = 2, seq: int = 8
                    ) -> List[AuditFinding]:
    """Build the smoke MoE model's fused programs and audit both
    lowerings: the single-step program and the K-step program."""
    import math

    import jax
    import jax.numpy as jnp

    from repro.configs import PAPER_COLOC_SET, get_smoke_config
    from repro.core.control import MultiStepFusedStep, PagedFusedStep
    from repro.core.pools import build_pools
    from repro.models import build_model
    from repro.runtime.sampler import sample

    name = next(n for n in PAPER_COLOC_SET if get_smoke_config(n).is_moe)
    cfg = get_smoke_config(name).replace(dtype="float32")
    models = {name: cfg}
    model = build_model(cfg)
    params = {name: model.init(jax.random.PRNGKey(0))}
    kv_pool, _, pooled = build_pools(models, params, page_budget=256,
                                     page_bytes=4096,
                                     pool_dtype=jnp.float32)
    virt = kv_pool.virtualizer
    for b in range(batch):
        virt.register_request(b, name, seq)
        virt.reserve_decode_block(b, max(k, 1))
    view = virt.views[name]
    max_pages = max(1, math.ceil((seq + k) / view.tokens_per_page))
    tables = virt.batch_tables(name, list(range(batch)), max_pages)
    tokens = jnp.zeros((batch,), jnp.int32)
    lengths = jnp.full((batch,), seq, jnp.int32)

    findings: List[AuditFinding] = []

    one = PagedFusedStep(pooled[name], postprocess=sample)
    abuf, slot_table = pooled[name].arena.acquire(name)
    findings += audit_fused_step(
        one, (one._p_kv, abuf, slot_table, tokens, virt.pool, tables,
              lengths),
        n_layers=cfg.n_layers, k=1, target="PagedFusedStep")

    if k > 1:
        multi = MultiStepFusedStep(pooled[name], k=k)
        abuf, slot_table = pooled[name].arena.acquire(name)
        findings += audit_fused_step(
            multi, (multi._p_kv, abuf, slot_table, tokens, virt.pool,
                    tables, lengths, jnp.full((batch,), k, jnp.int32),
                    jnp.full((batch,), -1, jnp.int32),
                    jax.random.PRNGKey(0)),
            n_layers=cfg.n_layers, k=k, target=f"MultiStepFusedStep(k={k})")
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.jaxpr_audit",
        description="Structural audit of the compiled fused decode "
                    "programs (CPA01..CPA04).")
    ap.add_argument("--k", type=int, default=4,
                    help="decode steps per dispatch for the multi-step "
                         "program (default 4)")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    findings = build_and_audit(args.k)
    for f in findings:
        print(f)
    n = len(findings)
    print(f"repro.analysis.jaxpr_audit: {n} finding{'s' if n != 1 else ''} "
          f"(single-step + k={args.k} programs)")
    if args.verbose and not n:
        for cid, desc in CHECKS.items():
            print(f"  {cid}: clean — {desc}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
