"""Windowed per-model demand telemetry feeding the elastic rebalancer.

The offline planner (``repro.core.planner``) sizes the KV/weights split
once, from trace files.  This module is its ONLINE twin (DESIGN.md §8):
it watches the live session — page occupancy, slab pressure,
admission-queue depth, arrival and completion streams — and reconstructs
the planner's own input type (:class:`~repro.core.planner.WorkloadSpec`)
from a sliding window, so the step-boundary re-plan runs the SAME
Eq. (1)-(2) machinery the offline plan did, just on what the session
actually observed instead of what was provisioned for.

Design rules:

  * observation is PASSIVE and host-only — one ``observe`` call per
    session step reads counters the pools already maintain; nothing here
    touches device state;
  * joint rows are preserved: a completed request contributes its
    (prompt, output, service-time) TOGETHER, exactly like the offline
    trace rows, so windowed sizing keeps the correlations the paper's
    Monte Carlo argument rests on;
  * everything is deterministic given the event stream: EWMAs and ring
    buffers only — no wall clock, no randomness — which is what lets the
    rebalancer's hysteresis decisions be replayed bit-identically on a
    recorded trace.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ElasticConfig, ModelConfig
from repro.core.planner import WorkloadSpec


@dataclass
class CompletedRow:
    """One finished request's joint workload row (the Eq. 1 sample)."""

    model: str
    prompt_tokens: int
    output_tokens: int
    service_s: float               # admission -> finish residency in the pool
    finish_time: float


class DemandTelemetry:
    """Sliding-window observer of the session's per-model demand."""

    def __init__(self, models: Dict[str, ModelConfig],
                 cfg: Optional[ElasticConfig] = None, *, gauges=None):
        self.models = dict(models)
        self.cfg = cfg or ElasticConfig()
        # optional gauge source (runtime.observe.EngineObserver): when the
        # engine runs with an observer, the EWMAs fold the SAME sampled
        # values the metrics registry exports (``observer.sample`` runs
        # first each step), so telemetry and /metrics can never disagree;
        # without one, observe() computes identical values from the pools.
        self.gauges = gauges
        a = self.cfg.ewma_alpha
        assert 0.0 < a <= 1.0, a
        # event streams (pruned to the window on observe)
        self.arrivals: Dict[str, Deque[float]] = collections.defaultdict(
            collections.deque)
        self.completed: Deque[CompletedRow] = collections.deque()
        # step-sampled EWMAs (the smoothed pressure signals)
        self.kv_occupancy_ewma = 0.0
        self.slab_occupancy_ewma = 0.0
        self.queue_depth_ewma = 0.0
        # instantaneous snapshot of the last observe()
        self.last: Dict[str, float] = {}
        self.steps_observed = 0
        self._t0: Optional[float] = None

    # ------------------------------------------------------------------
    # event hooks (called by the engine)
    # ------------------------------------------------------------------
    def note_arrival(self, model: str, now: float) -> None:
        if self._t0 is None:
            self._t0 = now
        self.arrivals[model].append(now)

    def note_finish(self, model: str, prompt_tokens: int,
                    output_tokens: int, admit_time: float,
                    finish_time: float) -> None:
        self.completed.append(CompletedRow(
            model=model, prompt_tokens=max(int(prompt_tokens), 1),
            output_tokens=max(int(output_tokens), 1),
            service_s=max(finish_time - admit_time, 1e-3),
            finish_time=finish_time))

    # ------------------------------------------------------------------
    # per-step observation
    # ------------------------------------------------------------------
    def observe(self, now: float, virt, arena, admission) -> None:
        """Sample the pools once per session step and fold the EWMAs."""
        self.steps_observed += 1
        horizon = now - self.cfg.window_s
        for q in self.arrivals.values():
            while q and q[0] < horizon:
                q.popleft()
        while self.completed and self.completed[0].finish_time < horizon:
            self.completed.popleft()

        a = self.cfg.ewma_alpha
        if self.gauges is not None:
            kv_occ = self.gauges.kv_occupancy()
            slab_occ = self.gauges.slab_occupancy() if arena is not None \
                else 0.0
            queued = self.gauges.queue_depth()
        else:
            kv_occ = virt.mapped_pages / max(virt.page_budget, 1)
            slab_occ = (arena.resident_slabs / max(arena.slot_budget, 1)
                        if arena is not None else 0.0)
            queued = admission.queued_count() if admission is not None else 0
        self.kv_occupancy_ewma += a * (kv_occ - self.kv_occupancy_ewma)
        self.slab_occupancy_ewma += a * (slab_occ - self.slab_occupancy_ewma)
        self.queue_depth_ewma += a * (queued - self.queue_depth_ewma)
        self.last = {
            "now": now,
            "kv_occupancy": kv_occ,
            "slab_occupancy": slab_occ,
            "queued": float(queued),
            "swapped_pages": float(getattr(virt, "swapped_now", 0)),
        }

    # ------------------------------------------------------------------
    # the planner bridge
    # ------------------------------------------------------------------
    def window_elapsed(self, now: float) -> float:
        if self._t0 is None:
            return 0.0
        return min(max(now - self._t0, 0.0), self.cfg.window_s)

    def arrival_rate(self, model: str, now: float) -> float:
        n = len(self.arrivals.get(model, ()))
        if n == 0:
            return 0.0
        # floor the denominator at 1s: at the head of a burst the window
        # has barely elapsed, and n / epsilon would be a meaninglessly
        # huge rate while 0 would hide the burst entirely — n per second
        # is the conservative early read, refined as the window fills
        return n / max(self.window_elapsed(now), 1.0)

    def _rows_for(self, model: str) -> List[CompletedRow]:
        return [r for r in self.completed if r.model == model]

    def window_specs(self, now: float, live_requests: Optional[Dict] = None
                     ) -> List[WorkloadSpec]:
        """Reconstruct per-model :class:`WorkloadSpec`s from the window.

        A model's joint samples are its completed rows in the window PLUS
        its LIVE (slotted / waiting / queued) requests — live rows' prompt
        is known, the output is the declared ``max_new_tokens`` and the
        service time is the window so far.  Merging (not falling back)
        matters twice: the head of a long-context burst shows up in the
        windowed Eq. (1) inputs while it is still decoding, and a wave of
        QUEUED long prompts is never shadowed by short completed rows.
        Live demand also floors the arrival rate, so a starved queue
        whose arrival events aged out of the window still reads as
        demand instead of silently vanishing.  ``live_requests`` maps
        model -> [(prompt_tokens, max_new_tokens)].  Models with no
        signal at all are omitted.
        """
        specs: List[WorkloadSpec] = []
        for name, cfg in self.models.items():
            rows = self._rows_for(name)
            live = (live_requests or {}).get(name) or []
            if not rows and not live:
                continue
            horizon = max(self.window_elapsed(now), 1.0)
            prompt = np.asarray([r.prompt_tokens for r in rows]
                                + [max(p, 1) for p, _ in live], float)
            output = np.asarray([r.output_tokens for r in rows]
                                + [max(o, 1) for _, o in live], float)
            service = np.asarray([r.service_s for r in rows]
                                 + [horizon] * len(live), float)
            rate = max(self.arrival_rate(name, now), len(live) / horizon)
            if rate <= 0.0:
                continue
            specs.append(WorkloadSpec(
                model=cfg, arrival_rate=rate, prompt_tokens=prompt,
                output_tokens=output, decode_time=service))
        return specs

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """The pressure signals, for ``engine.report()`` and benchmarks."""
        out = {
            "kv_occupancy_ewma": self.kv_occupancy_ewma,
            "slab_occupancy_ewma": self.slab_occupancy_ewma,
            "queue_depth_ewma": self.queue_depth_ewma,
            "window_completions": float(len(self.completed)),
            "window_arrivals": float(
                sum(len(q) for q in self.arrivals.values())),
            "steps_observed": float(self.steps_observed),
        }
        out.update({f"last_{k}": v for k, v in self.last.items()})
        return out


def arrival_rates(telemetry: DemandTelemetry, now: float
                  ) -> Dict[str, Tuple[float, int]]:
    """(rate, windowed-arrival-count) per model — report helper."""
    return {m: (telemetry.arrival_rate(m, now),
                len(telemetry.arrivals.get(m, ())))
            for m in telemetry.models}
