"""Weights-arena acceptance: the PR-2 tentpole invariants.

* FFN-stage bit-for-bit parity: expert / dense-MLP weights gathered out of
  the shared slab arena reproduce the resident-``w_params`` FFN outputs
  exactly (f32 AND bf16 — the untyped byte slabs round-trip every dtype);
* multi-model decode parity through the arena for both lowering modes
  (GQA+moe and MLA+dense colocated in ONE arena);
* device FFN bytes are fixed by ``slot_budget`` alone — constant as the
  colocated model count grows (the weights twin of the PR-1 KV claim);
* evict + re-activate of an idle model reproduces identical logits;
* property test: activate/evict/pin sequences, including ones that hit
  ``OutOfSlabsError`` mid-sequence, never leak slabs, never double-map,
  and failed activations leave the arena byte-for-byte unchanged;
* `split_params`/`merge_params` round-trip: leaf-exact over every config,
  no leaf in both trees (the boundary the arena's accounting relies on).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_NAMES, PAPER_COLOC_SET, get_smoke_config
from repro.core import split_exec
from repro.core.control import HostDrivenStep, PagedFusedStep
from repro.core.pools import build_pools
from repro.core.weight_pool import (OutOfSlabsError, WeightArena,
                                    slabs_for_config)
from repro.models import build_model, layers as layers_mod, moe as moe_mod

MOE, MLA = "qwen3-moe-235b-a22b", "minicpm3-4b"


def _build(names, dtype="float32", slot_budget=None, slab_bytes=4096,
           page_budget=256, activate=True):
    models = {n: get_smoke_config(n).replace(dtype=dtype) for n in names}
    params = {n: build_model(c).init(jax.random.PRNGKey(i))
              for i, (n, c) in enumerate(models.items())}
    kv_pool, w_pool, pooled = build_pools(
        models, params, page_budget=page_budget, page_bytes=4096,
        pool_dtype=jnp.float32 if dtype == "float32" else jnp.bfloat16,
        slot_budget=slot_budget, slab_bytes=slab_bytes,
        activate_resident=activate)
    return models, params, kv_pool, w_pool, pooled


# ---------------------------------------------------------------------------
# bit-for-bit FFN parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", [MOE, MLA])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_ffn_stage_bit_for_bit(name, dtype):
    """Arena-gathered FFN weights must reproduce the resident-tree FFN
    outputs EXACTLY — the gather/bitcast path may not perturb one bit."""
    models, params, kv_pool, w_pool, pooled = _build((name,), dtype=dtype)
    cfg = models[name]
    pm = pooled[name]
    arena = pm.arena
    table = arena.slot_table(name)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 1, cfg.d_model),
                          jnp.float32).astype(pm.kv_params["embed"]["tok"].dtype)
    _, w_tree = split_exec.split_params(params[name], cfg)
    for layer in range(cfg.n_layers):
        got = pm.stage_fns.ffn_stage(arena.arena, table, x, layer)
        p_l = jax.tree.map(lambda a, l=layer: a[l], w_tree["layers"])
        if cfg.is_moe:
            want, _ = moe_mod.apply_moe(p_l["moe"], x, cfg)
        else:
            want = layers_mod.apply_mlp(p_l["mlp"], x, cfg.mlp_kind)
        assert np.array_equal(np.asarray(got), np.asarray(want)), \
            f"{name}/{dtype} layer {layer}: arena FFN != resident FFN"


def test_ffn_stage_single_expert_moe():
    """n_experts == 1 keeps its stacked [E=1, ...] expert axis through the
    arena unpacker (apply_moe expects the init_moe layout)."""
    cfg = get_smoke_config(MOE).replace(dtype="float32", n_experts=1,
                                        experts_per_token=1)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    kv_pool, w_pool, pooled = build_pools(
        {cfg.name: cfg}, {cfg.name: params}, page_budget=64,
        page_bytes=4096, pool_dtype=jnp.float32, slab_bytes=4096)[0:3]
    pm = pooled[cfg.name]
    table = pm.arena.slot_table(cfg.name)
    x = jnp.ones((2, 1, cfg.d_model), jnp.float32)
    _, w_tree = split_exec.split_params(params, cfg)
    for layer in range(cfg.n_layers):
        got = pm.stage_fns.ffn_stage(pm.arena.arena, table, x, layer)
        p_l = jax.tree.map(lambda a, l=layer: a[l], w_tree["layers"])
        want, _ = moe_mod.apply_moe(p_l["moe"], x, cfg)
        assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("lowering", [True, False])
def test_multi_model_arena_decode_matches_dense(lowering):
    """GQA+moe and MLA+dense colocated in ONE arena: paged decode through
    arena-gathered weights matches the dense-cache fused model for both."""
    models, params, kv_pool, w_pool, pooled = _build((MOE, MLA))
    virt = kv_pool.virtualizer
    B, seq, max_len, n_steps = 2, 8, 16, 3
    devs = jax.devices()
    for mi, name in enumerate(models):
        cfg = models[name]
        model = build_model(cfg)
        rng = np.random.default_rng(mi)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, seq)),
                             jnp.int32)
        cache = model.init_cache(B, max_len)
        _, cache = model.prefill(params[name], tokens, cache)
        rids = (10 * mi, 10 * mi + 1)
        for row, rid in enumerate(rids):
            virt.register_request(rid, name, seq)
            virt.write_prompt_from_cache(name, rid, cache, seq,
                                         batch_index=row)
        view = virt.views[name]
        max_pages = max(1, math.ceil(max_len / view.tokens_per_page))
        step = (PagedFusedStep(pooled[name]) if lowering
                else HostDrivenStep(pooled[name], devs[0], devs[-1]))
        next_tok = jnp.zeros((B,), jnp.int32)
        for t in range(n_steps):
            length = seq + t
            want, cache = model.decode_step(params[name], next_tok, cache,
                                            jnp.int32(length))
            for rid in rids:
                virt.extend_request(rid, 1)
            tables = virt.batch_tables(name, list(rids), max_pages)
            got, virt.pool = step(next_tok, virt.pool, tables,
                                  jnp.full((B,), length, jnp.int32))
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)
            next_tok = jnp.argmax(want, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# device bytes fixed by slot_budget; evict/re-activate determinism
# ---------------------------------------------------------------------------

def test_device_ffn_bytes_fixed_by_slot_budget():
    """Arena bytes stay constant as colocated models grow 1 -> 3, and the
    weights pool holds NO per-model device FFN trees."""
    budget = 256
    _, _, _, w_one, _ = _build(PAPER_COLOC_SET[:1], slot_budget=budget)
    _, _, _, w_three, _ = _build(PAPER_COLOC_SET, slot_budget=budget)
    assert w_one.total_bytes() == w_three.total_bytes() \
        == budget * w_one.arena.slab_bytes
    assert w_one.arena.arena.nbytes == w_three.arena.arena.nbytes
    # split models keep ONE host master (the packed slabs), no unpacked
    # device or host FFN tree
    assert not w_three.ffn_params
    assert set(w_three.arena.host_slabs) == set(PAPER_COLOC_SET)


def test_evict_reactivate_reproduces_identical_logits():
    """Masters live on the host, so an evict/re-activate round trip must be
    bit-for-bit invisible to decode."""
    models, params, kv_pool, w_pool, pooled = _build((MOE, MLA))
    virt = kv_pool.virtualizer
    arena = w_pool.arena
    name, cfg = MOE, models[MOE]
    model = build_model(cfg)
    B, seq, max_len = 2, 8, 16
    tokens = jnp.zeros((B, seq), jnp.int32)
    cache = model.init_cache(B, max_len)
    _, cache = model.prefill(params[name], tokens, cache)
    for rid in (0, 1):
        virt.register_request(rid, name, seq)
        virt.write_prompt_from_cache(name, rid, cache, seq, batch_index=rid)
        virt.extend_request(rid, 1)
    view = virt.views[name]
    max_pages = max(1, math.ceil(max_len / view.tokens_per_page))
    tables = virt.batch_tables(name, [0, 1], max_pages)
    step = PagedFusedStep(pooled[name])
    pool0 = virt.pool
    lengths = jnp.full((B,), seq, jnp.int32)
    next_tok = jnp.zeros((B,), jnp.int32)

    logits1, _ = step(next_tok, pool0, tables, lengths)
    rev1 = arena.residency[name].rev
    arena.evict(name)                    # both models idle -> evictable
    arena.evict(MLA)
    assert not arena.is_resident(name)
    assert arena.free_slabs == arena.slot_budget
    arena.activate(MLA)                  # reshuffle the free list
    arena.activate(name)                 # re-upload from host masters
    assert arena.residency[name].rev != rev1
    logits2, _ = step(next_tok, pool0, tables, lengths)
    assert np.array_equal(np.asarray(logits1), np.asarray(logits2))


def test_lru_eviction_respects_pins():
    """Activation under slab pressure evicts the LRU idle model, never a
    pinned one; an impossible activation raises without evicting."""
    models = {n: get_smoke_config(n).replace(dtype="float32")
              for n in PAPER_COLOC_SET}
    params = {n: build_model(c).init(jax.random.PRNGKey(i))
              for i, (n, c) in enumerate(models.items())}
    trees = {n: split_exec.split_params(params[n], c)[1]
             for n, c in models.items()}
    slabs = {n: None for n in models}
    arena = WeightArena(slab_bytes=4096)
    for n, c in models.items():
        arena.add_model(n, c, jax.tree.map(np.asarray, trees[n]))
        slabs[n] = arena.views[n].total_slabs
    a, b, c = PAPER_COLOC_SET
    # budget: the two big MoE models cannot be resident together
    arena.finalize(max(slabs[a], slabs[b]) + slabs[c], allocate=False)
    arena.activate(a)
    arena.activate(c)
    arena.pin(c)
    arena.activate(b)                    # must evict idle a, not pinned c
    assert arena.is_resident(b) and arena.is_resident(c)
    assert not arena.is_resident(a)
    arena.pin(b)
    with pytest.raises(OutOfSlabsError):
        arena.activate(a)                # everything else pinned
    assert arena.is_resident(b) and arena.is_resident(c)
    arena.unpin(b)
    arena.activate(a)                    # now b is the LRU victim
    assert arena.is_resident(a) and not arena.is_resident(b)


# ---------------------------------------------------------------------------
# property: atomic map/evict under OutOfSlabsError
# ---------------------------------------------------------------------------

_PROP_STATE = {}


def _prop_trees():
    if not _PROP_STATE:
        models = {n: get_smoke_config(n).replace(dtype="float32")
                  for n in PAPER_COLOC_SET}
        params = {n: build_model(c).init(jax.random.PRNGKey(i))
                  for i, (n, c) in enumerate(models.items())}
        _PROP_STATE["models"] = models
        _PROP_STATE["trees"] = {
            n: jax.tree.map(np.asarray,
                            split_exec.split_params(params[n], c)[1])
            for n, c in models.items()}
    return _PROP_STATE["models"], _PROP_STATE["trees"]


def _snapshot(arena):
    return (sorted(arena.free_list),
            {n: r.slots.copy() for n, r in arena.residency.items()},
            dict(arena.pins))


def _check_invariants(arena, budget):
    assigned = [int(s) for r in arena.residency.values()
                for s in r.slots.ravel()]
    assert len(assigned) == len(set(assigned)), "double-mapped slab"
    assert len(assigned) + arena.free_slabs == budget, "slab leak"
    for n, r in arena.residency.items():
        v = arena.views[n]
        assert r.slots.shape == (v.n_layers, v.slabs_per_layer)
        assert r.slots.min() >= 0 and r.slots.max() < budget


@settings(max_examples=25, deadline=None)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["activate", "evict", "pin", "unpin"]),
              st.sampled_from(list(PAPER_COLOC_SET))),
    min_size=1, max_size=40))
def test_property_atomic_map_evict_no_leaks(ops):
    """Random activate/evict/pin interleavings over a budget too small for
    full residency: no slab is ever double-mapped or leaked, and an op
    that raises leaves the arena state EXACTLY as it was."""
    models, trees = _prop_trees()
    arena = WeightArena(slab_bytes=4096)
    for n, c in models.items():
        arena.add_model(n, c, trees[n])
    sizes = sorted(v.total_slabs for v in arena.views.values())
    budget = sizes[-1] + sizes[0]         # biggest + smallest, not all three
    arena.finalize(budget, allocate=False)
    for op, name in ops:
        before = _snapshot(arena)
        try:
            if op == "activate":
                arena.activate(name)
            elif op == "evict":
                if arena.is_resident(name):
                    arena.evict(name)
            elif op == "pin":
                if arena.is_resident(name):
                    arena.pin(name)
            else:
                arena.unpin(name)
        except (OutOfSlabsError, ValueError):
            after = _snapshot(arena)
            assert after[0] == before[0], "failed op changed the free list"
            assert after[2] == before[2], "failed op changed pins"
            assert after[1].keys() == before[1].keys()
            for n in after[1]:
                assert np.array_equal(after[1][n], before[1][n]), \
                    "failed op moved a resident model's slabs"
        _check_invariants(arena, budget)


# ---------------------------------------------------------------------------
# split_params / merge_params round trip (the residency boundary)
# ---------------------------------------------------------------------------

def _paths(tree, prefix=()):
    out = set()
    for k, v in tree.items():
        if isinstance(v, dict):
            out |= _paths(v, prefix + (k,))
        else:
            out.add(prefix + (k,))
    return out


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_split_merge_roundtrip_all_configs(name):
    """Leaf-exact round trip over EVERY assigned arch; the two halves are
    disjoint and jointly exhaustive — what arena residency accounting
    (host masters vs kv params) relies on."""
    cfg = get_smoke_config(name)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    kv_t, w_t = split_exec.split_params(params, cfg)
    assert not (_paths(kv_t) & _paths(w_t)), "leaf present in both pools"
    assert (_paths(kv_t) | _paths(w_t)) == _paths(params), "leaf dropped"
    merged = split_exec.merge_params(kv_t, w_t)
    assert jax.tree.structure(merged) == jax.tree.structure(params)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(params)):
        assert a is b, "round trip must be leaf-identical, not a copy"


_key = st.sampled_from(["mlp", "moe", "attn", "ln1", "embed", "wg", "head"])


@st.composite
def _trees(draw, depth=0):
    n = draw(st.integers(1, 3))
    out = {}
    for _ in range(n):
        k = draw(_key)
        if depth < 2 and draw(st.booleans()):
            out[k] = draw(_trees(depth=depth + 1))
        else:
            out[k] = np.arange(draw(st.integers(1, 4)), dtype=np.float32)
    return out


@settings(max_examples=50, deadline=None)
@given(tree=_trees())
def test_property_split_merge_roundtrip_random_trees(tree):
    cfg = get_smoke_config(MOE)          # split_params keys off paths only
    kv_t, w_t = split_exec.split_params(tree, cfg)
    assert not (_paths(kv_t) & _paths(w_t))
    assert (_paths(kv_t) | _paths(w_t)) == _paths(tree)
    merged = split_exec.merge_params(kv_t, w_t)
    assert _paths(merged) == _paths(tree)
    for p in _paths(tree):
        a, b = tree, merged
        for k in p:
            a, b = a[k], b[k]
        assert a is b


# ---------------------------------------------------------------------------
# streaming prefetch + engine-level activation/eviction
# ---------------------------------------------------------------------------

def test_pipeline_streaming_prefetch_matches_eager_upload():
    """activate(upload=False) + the scheduler's layer prefetch must produce
    the same logits as an eagerly uploaded arena."""
    from repro.core.pipeline import InflightBatch, LayerPipelineScheduler
    models, params, kv_pool, w_pool, pooled = _build((MLA,))
    name, cfg = MLA, models[MLA]
    model = build_model(cfg)
    virt = kv_pool.virtualizer
    arena = w_pool.arena
    B, seq, max_len = 2, 8, 16
    devs = jax.devices()

    def make_batch(bid, base):
        tokens = jnp.zeros((B, seq), jnp.int32)
        cache = model.init_cache(B, max_len)
        _, cache = model.prefill(params[name], tokens, cache)
        rids = (base, base + 1)
        for row, rid in enumerate(rids):
            virt.register_request(rid, name, seq)
            virt.write_prompt_from_cache(name, rid, cache, seq,
                                         batch_index=row)
            virt.extend_request(rid, 1)
        view = virt.views[name]
        max_pages = max(1, math.ceil(max_len / view.tokens_per_page))
        return InflightBatch(
            batch_id=bid, model=name, tokens=jnp.zeros((B,), jnp.int32),
            page_tables=virt.batch_tables(name, list(rids), max_pages),
            lengths=jnp.full((B,), seq, jnp.int32))

    sched = LayerPipelineScheduler(pooled, devs[0], devs[-1])
    eager, virt.pool = sched.run([make_batch(0, 0)], virt.pool)

    arena.evict(name)                    # back to cold
    arena.activate(name, upload=False)   # slots mapped, nothing uploaded
    assert not arena.residency[name].uploaded.any()
    uploads_before = arena.layer_uploads
    sched2 = LayerPipelineScheduler(pooled, devs[0], devs[-1])
    streamed, virt.pool = sched2.run([make_batch(1, 10)], virt.pool)
    assert arena.residency[name].uploaded.all()
    assert arena.layer_uploads - uploads_before == cfg.n_layers
    assert np.array_equal(np.asarray(eager[0].logits),
                          np.asarray(streamed[0].logits))


def test_engine_cold_activation_and_eviction():
    """Two models served far apart in time through a one-model arena: the
    engine activates on demand, evicts the idle model, and the report
    surfaces per-model admission counters."""
    from repro.runtime.engine import CrossPoolEngine, EngineMode
    from repro.runtime.request import Request
    models = {n: get_smoke_config(n).replace(dtype="float32")
              for n in (MOE, MLA)}
    need = {n: slabs_for_config(c.replace(dtype="float32"), 4096)
            for n, c in models.items()}
    engine = CrossPoolEngine(
        models, page_budget=2048, page_bytes=4096,
        slot_budget=max(need.values()), slab_bytes=4096,
        max_batch=2, max_ctx=64, mode=EngineMode(pipeline=True,
                                                 lowering=True))
    reqs = [Request(request_id=0, model=MOE, prompt_tokens=8,
                    max_new_tokens=3, arrival_time=0.0),
            Request(request_id=1, model=MLA, prompt_tokens=8,
                    max_new_tokens=3, arrival_time=10_000.0)]
    stats = engine.run(reqs)
    assert stats.tokens_out > 0
    w = stats.weights_pool
    assert w["activations"] >= 2 and w["evictions"] >= 1
    assert engine.arena.is_resident(MLA) and not engine.arena.is_resident(MOE)
    rep = engine.report()
    assert MOE in rep and "admitted=1" in rep and "evictions" in rep
    assert stats.admission.per_model[MOE].admitted == 1


def test_engine_overlapping_requests_wait_out_arena_pressure():
    """Two models arriving together through a one-model arena: the second
    request WAITS while the first model is pinned (no crash), then serves
    after the first drains and is evicted."""
    from repro.runtime.engine import CrossPoolEngine, EngineMode
    from repro.runtime.request import Request
    models = {n: get_smoke_config(n).replace(dtype="float32")
              for n in (MOE, MLA)}
    need = {n: slabs_for_config(c, 4096) for n, c in models.items()}
    engine = CrossPoolEngine(
        models, page_budget=2048, page_bytes=4096,
        slot_budget=max(need.values()), slab_bytes=4096,
        max_batch=2, max_ctx=64, mode=EngineMode(pipeline=True,
                                                 lowering=True))
    reqs = [Request(request_id=0, model=MOE, prompt_tokens=8,
                    max_new_tokens=3, arrival_time=0.0),
            Request(request_id=1, model=MLA, prompt_tokens=8,
                    max_new_tokens=3, arrival_time=0.0)]
    stats = engine.run(reqs)
    assert all(r.finish_time > 0 for r in reqs), "a request was dropped"
    assert stats.weights_pool["evictions"] >= 1
    assert not engine.arena.pins                  # all pins released
