"""Bench regression guard: diff BENCH_summary.json against a committed
baseline and fail CI on >20% regressions in the headline paper claims.

  PYTHONPATH=src python -m benchmarks.check_regression \
      [--baseline BENCH_baseline.json] [--summary BENCH_summary.json] \
      [--tolerance 0.20]

Guarded metrics (lower is better for all of them):

  * table1: consolidated-arena device FFN bytes — the phase-invariant
    (prefill AND decode) device-bytes claim; a >20% growth means the
    slab layout or slot accounting regressed;
  * fig7: crosspool P99 TBT at 0.8 and 1.0 RPS — the tail-latency
    headline (the simulation is seeded, so drift is a code change, not
    noise);
  * online: the session API's online/batch median-TBT ratio — machine
    speed cancels in the ratio, but the measured medians still jitter
    with host load, so this entry carries a wide per-metric tolerance:
    only a multiple-x online-path slowdown (lost prefill coalescing,
    per-token host work creeping in) trips it, not scheduler noise.
    The recorded P99s ride along in BENCH_summary.json unguarded;
  * elastic: the static/elastic peak-admitted-concurrency ratio on the
    scripted long-context burst — deterministic integers (machine speed
    cancels), so any growth is the rebalancer losing its win;
  * multistep: the worst MoE-model K=4/K=1 P99-TBT ratio — the
    multi-step decode dispatch-amortization win (a ratio, so machine
    speed cancels; the benchmark hard-asserts the 2x bound itself);
  * multiturn: the worst MoE-model warm-turn TTFT cache-on/cache-off
    ratio — the prefix-cache win (a ratio; the benchmark hard-asserts
    the 0.5x bound itself, this guard carries a wide tolerance).

Metrics present in the baseline but missing from the new summary (or
produced by a failed benchmark) are hard failures: a silently skipped
benchmark must not read as green.
"""
from __future__ import annotations

import argparse
import json
import sys


def _get(tree, path):
    for k in path:
        if not isinstance(tree, dict) or k not in tree:
            return None
        tree = tree[k]
    return tree


#: (label, path into the summary JSON, index into the value or None,
#:  per-metric tolerance overriding --tolerance or None)
GUARDED = [
    ("table1 device FFN bytes (arena, prefill+decode GiB)",
     ("table1", "metrics", "arena", "consolidated_arena_GiB"), None, None),
    ("fig7 crosspool P99 TBT @ 0.8 RPS (s)",
     ("fig7", "metrics", "('crosspool', 0.8)"), 1, None),
    ("fig7 crosspool P99 TBT @ 1.0 RPS (s)",
     ("fig7", "metrics", "('crosspool', 1.0)"), 1, None),
    # wall-clock medians on shared CI hosts jitter ~2x; guard only a
    # multiple-x online-path regression
    ("online session online/batch P50 TBT ratio",
     ("online", "metrics", "online_over_batch_p50"), None, 3.0),
    # the P99 ratio is noisier still (single worst step); same wide gate
    ("online session online/batch P99 TBT ratio",
     ("online", "metrics", "online_over_batch_p99"), None, 3.0),
    # multi-step decode: worst MoE-model K=4/K=1 P99-TBT ratio.  Machine
    # speed cancels in the ratio; the benchmark itself hard-asserts the
    # 2x acceptance bound, so this guard only has to catch the
    # amortization quietly eroding (e.g. per-token host work sneaking
    # back into the K-block commit)
    ("multistep worst MoE K=4/K=1 P99 TBT ratio",
     ("multistep", "metrics", "moe_k4_over_k1_p99"), None, 1.0),
    # deterministic integer ratio (peak admitted concurrency, static over
    # elastic, on the scripted burst): machine speed cancels entirely, so
    # the tolerance is ZERO — any growth means the rebalancer stopped
    # converting arena slack into admitted requests
    ("elastic burst static/elastic peak-admitted ratio",
     ("elastic", "metrics", "static_over_elastic_peak_admitted"),
     None, 0.0),
    # prefix cache: worst MoE-model warm-turn TTFT cache-on/cache-off
    # ratio.  Machine speed cancels in the ratio and the benchmark
    # hard-asserts the 0.5x acceptance bound itself; wall-clock TTFT
    # medians on shared CI hosts still jitter, so the guard is wide and
    # only catches the cache win eroding wholesale (suffix prefill
    # quietly recomputing the prefix, eager host work creeping into the
    # warm path)
    ("multiturn worst MoE warm-TTFT cache-on/off ratio",
     ("multiturn", "metrics", "ttft_warm_ratio"), None, 1.0),
]


def extract(summary: dict, path, index):
    bench = path[0]
    entry = summary.get(bench)
    if entry is None:
        return None, f"benchmark {bench!r} missing from summary"
    if not entry.get("ok", False):
        return None, f"benchmark {bench!r} FAILED: {entry.get('error')}"
    v = _get(summary, path)
    if v is None:
        return None, f"metric path {'/'.join(path)} missing"
    if index is not None:
        v = v[index]
    return float(v), None


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--summary", default="BENCH_summary.json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="max allowed fractional regression (default 20%%)")
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.summary) as f:
        new = json.load(f)

    failures = []
    for label, path, index, tol in GUARDED:
        tolerance = args.tolerance if tol is None else tol
        b, err = extract(base, path, index)
        if err is not None:
            print(f"SKIP (not in baseline) {label}: {err}")
            continue
        n, err = extract(new, path, index)
        if err is not None:
            failures.append(f"{label}: {err}")
            continue
        ratio = n / b if b else float("inf")
        verdict = "OK"
        if n > b * (1.0 + tolerance):
            verdict = "REGRESSED"
            failures.append(
                f"{label}: {b:.6g} -> {n:.6g} "
                f"(+{(ratio - 1) * 100:.1f}% > {tolerance * 100:.0f}%)")
        print(f"{verdict:9s} {label}: baseline={b:.6g} new={n:.6g} "
              f"({(ratio - 1) * 100:+.1f}%)")

    if failures:
        print("\nbench regression guard FAILED:")
        for f_ in failures:
            print(f"  - {f_}")
        sys.exit(1)
    print("\nbench regression guard: all guarded metrics within tolerance")


if __name__ == "__main__":
    main()
