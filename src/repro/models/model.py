"""Model facade: config -> init / forward / prefill / decode_step.

This is the public surface the serving engine, training substrate and the
dry-run all consume.  Models are pure functions over param pytrees; sharding
is injected via :class:`repro.models.hooks.Hooks`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.models import decode as dec
from repro.models import transformer as tfm
from repro.models.hooks import Hooks, IDENTITY_HOOKS


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- parameters -----------------------------------------------------
    def init(self, key) -> Dict:
        return tfm.init_params(key, self.cfg)

    def param_specs(self, key=None) -> Dict:
        """ShapeDtypeStruct pytree of the params (no allocation)."""
        return jax.eval_shape(lambda k: tfm.init_params(k, self.cfg),
                              jax.random.PRNGKey(0))

    # ---- full-sequence (train / prefill-no-cache) ------------------------
    def forward(self, params: Dict, tokens: jax.Array, *,
                embeddings: Optional[jax.Array] = None,
                encoder_frames: Optional[jax.Array] = None,
                hooks: Hooks = IDENTITY_HOOKS, impl: str = "xla",
                ) -> Tuple[jax.Array, jax.Array]:
        return tfm.forward(params, self.cfg, tokens, embeddings=embeddings,
                           encoder_frames=encoder_frames, hooks=hooks,
                           impl=impl)

    # ---- decode ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int,
                   kv_dtype: Optional[str] = None) -> Dict:
        return dec.init_cache(self.cfg, batch, max_len, kv_dtype)

    def cache_specs(self, batch: int, max_len: int,
                    kv_dtype: Optional[str] = None) -> Dict:
        return jax.eval_shape(
            lambda: dec.init_cache(self.cfg, batch, max_len, kv_dtype))

    def prefill(self, params: Dict, tokens: jax.Array, cache: Dict, *,
                embeddings: Optional[jax.Array] = None,
                encoder_frames: Optional[jax.Array] = None,
                hooks: Hooks = IDENTITY_HOOKS, impl: str = "xla",
                logit_index=None,
                ) -> Tuple[jax.Array, Dict]:
        return dec.prefill(params, self.cfg, tokens, cache,
                           embeddings=embeddings,
                           encoder_frames=encoder_frames, hooks=hooks,
                           impl=impl, logit_index=logit_index)

    def decode_step(self, params: Dict, tokens: jax.Array, cache: Dict,
                    lengths, *, hooks: Hooks = IDENTITY_HOOKS,
                    impl: str = "xla") -> Tuple[jax.Array, Dict]:
        return dec.decode_step(params, self.cfg, tokens, cache, lengths,
                               hooks=hooks, impl=impl)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
