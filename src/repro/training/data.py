"""Synthetic LM data pipeline: seeded, shard-aware, infinite.

A production pipeline would stream tokenized shards; offline we generate
deterministic pseudo-corpora.  ``structured=True`` produces sequences with
learnable bigram structure (each token determined by the previous one via a
fixed random permutation + noise) so small models can demonstrably learn —
the quickstart/example training curves are meaningful, not noise-fitting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structured: bool = True
    noise: float = 0.1


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.perm = rng.permutation(cfg.vocab_size)

    def batches(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        cfg = self.cfg
        step = start_step
        while True:
            rng = np.random.default_rng((cfg.seed, step))
            if cfg.structured:
                tok = np.empty((cfg.global_batch, cfg.seq_len), np.int32)
                tok[:, 0] = rng.integers(0, cfg.vocab_size, cfg.global_batch)
                for t in range(1, cfg.seq_len):
                    nxt = self.perm[tok[:, t - 1]]
                    noise = rng.random(cfg.global_batch) < cfg.noise
                    rand = rng.integers(0, cfg.vocab_size, cfg.global_batch)
                    tok[:, t] = np.where(noise, rand, nxt)
            else:
                tok = rng.integers(0, cfg.vocab_size,
                                   (cfg.global_batch, cfg.seq_len),
                                   dtype=np.int32)
            yield {"tokens": tok}
            step += 1
