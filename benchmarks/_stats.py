"""Shared quantile helpers for every benchmark.

One implementation: ``repro.runtime.observe.percentile`` — the same
``np.percentile`` the metrics histograms expose — so every benchmark,
the serving report, and the exported metrics compute quantiles
identically (ISSUE 7 satellite).
"""
from repro.runtime.observe import percentile, summarize  # noqa: F401

__all__ = ["percentile", "summarize", "p50", "p99"]


def p50(values) -> float:
    return percentile(values, 50)


def p99(values) -> float:
    return percentile(values, 99)
