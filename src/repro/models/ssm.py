"""Mamba2 (SSD) block: in_proj -> causal conv1d -> SSD scan -> gated out_proj.

The recurrent state ``h [B,H,P,N]`` plus the conv tail are this family's
entire per-request "cache" — constant size, independent of context length.
The CrossPool planner treats it as a fixed page allocation per request
(DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import layers
from repro.models.hooks import Hooks, IDENTITY_HOOKS
from repro.kernels import ops as kops
from repro.kernels.ssd_chunked import ssd_decode_step


def _dims(cfg: ModelConfig) -> Tuple[SSMConfig, int, int, int, int]:
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, nh, conv_dim, s.d_state


def init_ssm(key, cfg: ModelConfig, dtype) -> Dict:
    s, d_in, nh, conv_dim, N = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    # in_proj emits [z (gate), x, B, C, dt] concatenated.
    proj_out = 2 * d_in + 2 * s.n_groups * N + nh
    return {
        "in_proj": layers.dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": layers.dense_init(ks[1], (s.conv_width, conv_dim), dtype,
                                    in_axis=0),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": layers.dense_init(ks[2], (d_in, d), dtype),
    }


def _split_proj(proj: jax.Array, cfg: ModelConfig):
    s, d_in, nh, _, N = _dims(cfg)
    gN = s.n_groups * N
    z = proj[..., :d_in]
    xs = proj[..., d_in: 2 * d_in]
    B_ = proj[..., 2 * d_in: 2 * d_in + gN]
    C_ = proj[..., 2 * d_in + gN: 2 * d_in + 2 * gN]
    dt = proj[..., 2 * d_in + 2 * gN:]
    return z, xs, B_, C_, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.

    x: [B,S,C]; w: [W,C]; tail: [B,W-1,C] previous context (decode chaining).
    Returns (y [B,S,C], new_tail [B,W-1,C]).
    """
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)                  # [B,S+W-1,C]
    y = sum(xp[:, i: i + x.shape[1]] * w[i][None, None, :] for i in range(W))
    new_tail = xp[:, -(W - 1):] if W > 1 else tail
    return y + b[None, None, :], new_tail


def ssm_full(p: Dict, cfg: ModelConfig, x: jax.Array, *,
             hooks: Hooks = IDENTITY_HOOKS,
             state: Optional[Dict] = None,
             ) -> Tuple[jax.Array, Dict]:
    """Whole-sequence SSD block.  x: [B,S,D] -> (out [B,S,D], final state).

    ``state``: {"h": [B,H,P,N] f32, "conv": [B,W-1,conv_dim]} or None.
    """
    s, d_in, nh, conv_dim, N = _dims(cfg)
    B, S, _ = x.shape
    proj = x @ p["in_proj"]
    z, xs, B_, C_, dt = _split_proj(proj, cfg)

    xbc = jnp.concatenate([xs, B_, C_], axis=-1)             # [B,S,conv_dim]
    tail_in = state["conv"] if state is not None else None
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], tail_in)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in]
    B_ = xbc[..., d_in: d_in + s.n_groups * N]
    C_ = xbc[..., d_in + s.n_groups * N:]

    xh = xs.reshape(B, S, nh, s.head_dim)
    Bh = B_.reshape(B, S, s.n_groups, N)
    Ch = C_.reshape(B, S, s.n_groups, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])                                 # [H]

    h0 = state["h"] if state is not None else None
    chunk = min(s.chunk_size, S) if S % min(s.chunk_size, S) == 0 else 1
    # choose the largest chunk that divides S (pads are upstream's concern)
    for cand in (s.chunk_size, 128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= S and S % cand == 0:
            chunk = cand
            break
    y, h_final = kops.ssd_scan(xh, dt, A, Bh, Ch, chunk=chunk, h0=h0)
    y = y + xh * p["D"][None, None, :, None]                 # skip connection
    y = y.reshape(B, S, d_in).astype(x.dtype)

    y = layers.rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"h": hooks.kv_state(h_final), "conv": conv_tail}


def init_ssm_state(cfg: ModelConfig, batch: int) -> Dict:
    s, d_in, nh, conv_dim, N = _dims(cfg)
    return {
        "h": jnp.zeros((batch, nh, s.head_dim, N), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim),
                          jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32),
    }


def ssm_decode(p: Dict, cfg: ModelConfig, x: jax.Array, state: Dict, *,
               hooks: Hooks = IDENTITY_HOOKS) -> Tuple[jax.Array, Dict]:
    """Single-token SSD recurrence.  x: [B,1,D] -> (out [B,1,D], new state)."""
    s, d_in, nh, conv_dim, N = _dims(cfg)
    B = x.shape[0]
    proj = x[:, 0] @ p["in_proj"]                            # [B,P]
    z, xs, B_, C_, dt = _split_proj(proj, cfg)

    xbc = jnp.concatenate([xs, B_, C_], axis=-1)             # [B,conv_dim]
    # roll the conv window: tail holds the last W-1 inputs
    window = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # [B,W,C]
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    new_tail = window[:, 1:].astype(state["conv"].dtype)
    xbc = jax.nn.silu(y).astype(x.dtype)
    xs = xbc[..., :d_in]
    B_ = xbc[..., d_in: d_in + s.n_groups * N]
    C_ = xbc[..., d_in + s.n_groups * N:]

    xh = xs.reshape(B, nh, s.head_dim)
    Bh = B_.reshape(B, s.n_groups, N)
    Ch = C_.reshape(B, s.n_groups, N)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])

    y_t, h_next = ssd_decode_step(state["h"], xh, dtv, A, Bh, Ch)
    y_t = y_t + xh * p["D"][None, :, None]
    y_t = y_t.reshape(B, d_in).astype(x.dtype)
    y_t = layers.rms_norm(y_t * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y_t @ p["out_proj"])[:, None, :]
    return out, {"h": hooks.kv_state(h_next), "conv": new_tail}
