"""CrossPool core: the paper's contribution.

* planner      — Eq. (1)-(2) Monte Carlo P95/P99 pool sizing + plans
* virtualizer  — paged KV virtualization of one shared physical pool
* admission    — queue-or-reject enforcement of the planned budget
* pools        — KVCachePool / WeightsPool engine-level disaggregation
* split_exec   — proxy-layer split of attention vs FFN execution
* pipeline     — layer-wise two-batch pipeline scheduler
* control      — host-driven vs fused ("persistent kernel") decode steps
* placement    — StaticPartition / kvcached / CrossPool capacity models
"""
from repro.core.admission import AdmissionController, PendingRequest  # noqa: F401
from repro.core.planner import (PoolPlan, WorkloadSpec, plan_pool,  # noqa: F401
                                worst_case_pages)
from repro.core.virtualizer import KVVirtualizer, OutOfPagesError  # noqa: F401
