"""Radix-tree prefix cache over the shared KV pool (DESIGN.md §11).

The sglang ``match_prefix`` / ``prefix_indices`` idiom applied to the
CrossPool virtualizer: committed prompt KV stays in the tree after the
producing request finishes, keyed by token content, and a later request
with the same prefix maps those pages READ-ONLY instead of re-prefilling
them.  The tree is the MemServe "context caching over an elastic memory
pool" layer on top of the PR-5 swap tier.

Layout:

  * one trie per ``(model, prefill bucket)``.  The bucket is part of the
    key because the prefill program's shapes — attention reduction
    extent AND MoE expert capacity — are bucket-determined; only a
    same-bucket consumer reproduces the producer's prefix KV and routing
    bit-for-bit (the suffix pass pads its KV extent back to the bucket,
    see ``split_exec``).
  * a node is exactly ``tokens_per_page`` tokens (ONE chunk: the same
    page of every layer), keyed by its token tuple; each node also
    carries PARTIAL tail leaves (< tokens_per_page tokens) for prompts
    that end mid-page.  Node payload: per-layer page ids, the captured
    MoE routing of its tokens (consumers rebuild full-pass expert-slot
    offsets from it), an LRU stamp, and the swapped/resident state
    implied by the page-id encoding.
  * sharing is by refcount: ``insert`` RETAINS the producing request's
    pages (``KVVirtualizer.retain_page``); a matching consumer retains
    full chunks read-only and copies the boundary chunk (copy-on-write
    at the fork point, ``register_request_with_prefix``).  Pages free
    only at refcount 0 — eviction of a leaf whose pages a live request
    still maps just drops the tree's hold.
  * eviction is LRU-by-leaf.  With ``second_chance`` on, a shed leaf's
    pages move to the host swap tier instead of being dropped — the
    PR-5 tier doubling as a second-chance cache — and a later match
    faults them back bit-exactly (``fault_chunks``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import CacheConfig
from repro.core.errors import check
from repro.core.virtualizer import _SWAP_BASE, KVVirtualizer


@dataclass
class _Chunk:
    """One radix-tree node: a page-granular run of prompt tokens."""

    tokens: Tuple[int, ...]
    pages: List[int] = field(default_factory=list)   # [layer] id / swap-enc
    routes: Optional[np.ndarray] = None              # [n_tokens, L, k] int32
    children: Dict[Tuple[int, ...], "_Chunk"] = field(default_factory=dict)
    partials: List["_Chunk"] = field(default_factory=list)
    parent: Optional["_Chunk"] = None
    last_touch: int = 0

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)

    @property
    def swapped(self) -> bool:
        return bool(self.pages) and self.pages[0] <= _SWAP_BASE

    @property
    def is_leaf(self) -> bool:
        return not self.children and not self.partials


def _lcp(a: Sequence[int], b: Sequence[int]) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class PrefixCache:
    """The engine-owned prefix index; registers itself as the
    virtualizer's ``cache_provider`` so shrink-compaction and idle swap
    see tree-held pages."""

    def __init__(self, virt: KVVirtualizer, cfg: Optional[CacheConfig] = None,
                 models: Optional[Sequence[str]] = None):
        self.virt = virt
        self.cfg = cfg or CacheConfig()
        # cacheable = split-execution models only (their prompt KV lives
        # in pool pages); fallback families always miss
        self.models = set(models if models is not None else virt.views)
        self._roots: Dict[Tuple[str, int], _Chunk] = {}
        # device page ids the tree currently holds (kept in lockstep with
        # node.pages): the compaction provider view and the cap metric
        self._device_pages: set = set()
        self._clock = 0
        # stats (report + benchmark)
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.prompt_tokens_seen = 0
        self.inserted_chunks = 0
        self.evicted_pages = 0
        self.shed_pages = 0
        self.faulted_pages = 0
        # optional observability sink (core.hooks.CoreHooks)
        self.hooks = None
        virt.cache_provider = self

    # ------------------------------------------------------------------
    # provider protocol (KVVirtualizer.cache_provider)
    # ------------------------------------------------------------------
    def device_pages(self) -> List[int]:
        """Tree-held device page ids, deterministic order (compaction)."""
        return sorted(self._device_pages)

    def remap(self, mapping: Dict[int, int]) -> None:
        """Apply a shrink-compaction's old->new page renumbering."""
        for node in self._walk():
            node.pages = [mapping[p] if p >= 0 else p for p in node.pages]
        self._device_pages = {mapping[p] for p in self._device_pages}

    def shed(self, need: int) -> int:
        """Free ``need`` device pages by retiring refcount-0 LRU leaves
        first (then older interior runs): with ``second_chance`` their
        pages move to the host swap tier and the nodes stay matchable;
        otherwise they are evicted outright.  Returns pages freed."""
        freed = 0
        for node in self._lru_candidates():
            if freed >= need:
                break
            if node.swapped or not node.pages:
                continue
            if any(self.virt.page_refs(p) > 1 for p in node.pages):
                continue            # a live request still maps this chunk
            n = len(node.pages)
            if self.cfg.second_chance:
                self._device_pages.difference_update(node.pages)
                node.pages = self.virt.swap_pages_out(node.pages)
                self.shed_pages += n
            else:
                if not node.is_leaf:
                    continue
                self._drop_node(node)
                self.evicted_pages += n
            freed += n
            if self.hooks is not None:
                self.hooks.cache_evict(n)
        return freed

    # ------------------------------------------------------------------
    # match / fault / insert / evict
    # ------------------------------------------------------------------
    def match_prefix(self, model: str, bucket: int, ids: np.ndarray
                     ) -> Tuple[int, List[_Chunk]]:
        """Longest cached prefix of ``ids`` under ``(model, bucket)``:
        (matched token count, the chunk nodes covering it in order).
        The last chunk may cover the match only partially (its page
        becomes the consumer's copy-on-write source).  Does NOT fault
        swapped chunks — the caller decides after its budget check."""
        root = self._roots.get((model, bucket))
        if root is None or model not in self.models:
            return 0, []
        tpp = self.virt.views[model].tokens_per_page
        ids = [int(t) for t in np.asarray(ids).reshape(-1)]
        node, matched, out = root, 0, []
        while len(ids) - matched >= tpp:
            key = tuple(ids[matched:matched + tpp])
            child = node.children.get(key)
            if child is None:
                break
            out.append(child)
            matched += tpp
            self._touch(child)
            node = child
        # best partial continuation: an exact-prefix partial tail OR the
        # leading slots of a diverging full chunk (both CoW sources)
        rest = ids[matched:matched + tpp]
        best, best_node = 0, None
        for key, child in node.children.items():
            l = _lcp(key, rest)
            if l > best:
                best, best_node = l, child
        for p in node.partials:
            l = _lcp(p.tokens, rest)
            if l > best:
                best, best_node = l, p
        if best_node is not None:
            out.append(best_node)
            matched += best
            self._touch(best_node)
        return matched, out

    def fault_chunks(self, chunks: Sequence[_Chunk]) -> int:
        """Fault any swapped chunks' pages back onto the device (the
        second-chance hit path); returns pages faulted.  Atomic per
        chunk (one ``fault_pages_in`` each, which raises before mutating
        on page exhaustion)."""
        n = 0
        for node in chunks:
            if not node.swapped:
                continue
            node.pages = self.virt.fault_pages_in(node.pages)
            self._device_pages.update(node.pages)
            n += len(node.pages)
        if n:
            self.faulted_pages += n
            if self.hooks is not None:
                self.hooks.cache_fault(n)
        return n

    def record_admission(self, model: str, prompt_tokens: int,
                         cached_tokens: int) -> None:
        """Count one cache-eligible admission (fired AFTER registration
        succeeded, so queued-retry probes never double-count)."""
        self.prompt_tokens_seen += prompt_tokens
        if cached_tokens > 0:
            self.hits += 1
            self.hit_tokens += cached_tokens
            if self.hooks is not None:
                self.hooks.cache_hit(model, cached_tokens)
        else:
            self.misses += 1
            if self.hooks is not None:
                self.hooks.cache_miss(model)

    def insert(self, model: str, bucket: int, ids: np.ndarray,
               chunk_pages: Sequence[Sequence[int]],
               routes: Optional[np.ndarray] = None) -> int:
        """Index a committed prompt: walk/create full-chunk nodes over
        ``ids`` and retain the producing request's pages for every NEW
        node (the request keeps its own hold; pages free when the last
        holder lets go).  ``chunk_pages[c][layer]`` is the request's
        page-table entry for chunk ``c``; ``routes`` is the captured
        per-token MoE routing ``[len(ids), L, k]`` (None for dense).

        A sub-page tail becomes a partial leaf: it REPLACES an existing
        partial that is a strict prefix of it (superset wins), is
        skipped when an existing partial already covers it, and
        coexists with diverging partials.  Returns new chunks created.
        """
        if model not in self.models or len(ids) == 0:
            return 0
        tpp = self.virt.views[model].tokens_per_page
        ids = [int(t) for t in np.asarray(ids).reshape(-1)]
        root = self._roots.setdefault((model, bucket), _Chunk(tokens=()))
        n_full, rem = len(ids) // tpp, len(ids) % tpp
        node, created, path = root, 0, []
        for c in range(n_full):
            key = tuple(ids[c * tpp:(c + 1) * tpp])
            child = node.children.get(key)
            if child is None:
                child = _Chunk(
                    tokens=key, pages=list(chunk_pages[c]),
                    routes=None if routes is None
                    else np.asarray(routes[c * tpp:(c + 1) * tpp]),
                    parent=node)
                for p in child.pages:
                    self.virt.retain_page(p)
                self._device_pages.update(child.pages)
                node.children[key] = child
                created += 1
            self._touch(child)
            path.append(child)
            node = child
        if rem:
            tail = tuple(ids[n_full * tpp:])
            covered = None
            for p in node.partials:
                if p.n_tokens >= rem and p.tokens[:rem] == tail:
                    covered = p
                    break
            if covered is not None:
                self._touch(covered)
                path.append(covered)
            else:
                # superset wins: drop any existing partial this tail
                # strictly extends (its pages stay with live holders)
                for p in list(node.partials):
                    if p.n_tokens < rem and tail[:p.n_tokens] == p.tokens:
                        self._release_node_pages(p)
                        node.partials.remove(p)
                leaf = _Chunk(
                    tokens=tail, pages=list(chunk_pages[n_full]),
                    routes=None if routes is None
                    else np.asarray(routes[n_full * tpp:]),
                    parent=node)
                for p in leaf.pages:
                    self.virt.retain_page(p)
                self._device_pages.update(leaf.pages)
                node.partials.append(leaf)
                created += 1
                self._touch(leaf)
                path.append(leaf)
        self.inserted_chunks += created
        self._enforce_cap(protect=set(id(n) for n in path))
        return created

    def evict(self, need_pages: int, protect: Optional[set] = None) -> int:
        """Drop LRU leaves outright until ``need_pages`` device pages
        left the tree's hold (refcount-0 pages actually free; shared
        ones survive with their requests).  Returns pages released."""
        protect = protect or set()
        dropped = 0
        progress = True
        while dropped < need_pages and progress:
            progress = False
            for node in self._lru_candidates(leaves_only=True):
                if dropped >= need_pages:
                    break
                if id(node) in protect:
                    continue
                n_dev = sum(1 for p in node.pages if p >= 0)
                self._drop_node(node)
                dropped += n_dev
                self.evicted_pages += n_dev
                if n_dev and self.hooks is not None:
                    self.hooks.cache_evict(n_dev)
                progress = True
        return dropped

    # ------------------------------------------------------------------
    @property
    def device_pages_held(self) -> int:
        return len(self._device_pages)

    def snapshot(self) -> Dict[str, float]:
        return {
            "hits": float(self.hits),
            "misses": float(self.misses),
            "hit_tokens": float(self.hit_tokens),
            "prompt_tokens_seen": float(self.prompt_tokens_seen),
            "hit_token_fraction": (
                self.hit_tokens / self.prompt_tokens_seen
                if self.prompt_tokens_seen else 0.0),
            "inserted_chunks": float(self.inserted_chunks),
            "device_pages_held": float(self.device_pages_held),
            "evicted_pages": float(self.evicted_pages),
            "shed_pages": float(self.shed_pages),
            "faulted_pages": float(self.faulted_pages),
        }

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------
    def _touch(self, node: _Chunk) -> None:
        self._clock += 1
        node.last_touch = self._clock

    def _walk(self) -> List[_Chunk]:
        out: List[_Chunk] = []
        stack = list(self._roots.values())
        while stack:
            n = stack.pop()
            if n.tokens:
                out.append(n)
            stack.extend(n.children.values())
            stack.extend(n.partials)
        return out

    def _lru_candidates(self, leaves_only: bool = False) -> List[_Chunk]:
        """Nodes in retirement order: LRU leaves first, then LRU interior
        nodes (an interior chunk is only shed after everything below it)."""
        nodes = self._walk()
        leaves = sorted((n for n in nodes if n.is_leaf),
                        key=lambda n: n.last_touch)
        if leaves_only:
            return leaves
        inner = sorted((n for n in nodes if not n.is_leaf),
                       key=lambda n: n.last_touch)
        return leaves + inner

    def _release_node_pages(self, node: _Chunk) -> None:
        for p in node.pages:
            if p >= 0:
                self._device_pages.discard(p)
            self.virt.release_cached_page(p)
        node.pages = []

    def _drop_node(self, node: _Chunk) -> None:
        """Remove a LEAF node from the tree, releasing its page holds."""
        check(node.is_leaf, "only leaves are evictable")
        self._release_node_pages(node)
        parent = node.parent
        if parent is not None:
            parent.children.pop(node.tokens, None)
            if node in parent.partials:
                parent.partials.remove(node)

    def _enforce_cap(self, protect: set) -> None:
        """Keep tree-held DEVICE pages under ``max_pages_fraction`` of the
        live page budget: shed (second-chance) or evict LRU leaves,
        never touching the path just inserted."""
        cap = int(self.cfg.max_pages_fraction * self.virt.page_budget)
        guard = 0
        while self.device_pages_held > cap and guard < 10_000:
            guard += 1
            before = self.device_pages_held
            for node in self._lru_candidates():
                if self.device_pages_held <= cap:
                    break
                if id(node) in protect or node.swapped or not node.pages:
                    continue
                if any(self.virt.page_refs(p) > 1 for p in node.pages):
                    continue
                n = len(node.pages)
                if self.cfg.second_chance:
                    self._device_pages.difference_update(node.pages)
                    node.pages = self.virt.swap_pages_out(node.pages)
                    self.shed_pages += n
                else:
                    if not node.is_leaf:
                        continue
                    self._drop_node(node)
                    self.evicted_pages += n
                if self.hooks is not None:
                    self.hooks.cache_evict(n)
            if self.device_pages_held == before:
                break               # everything left is shared or protected
