"""Quickstart: build any assigned architecture, run forward / prefill /
decode, take a few train steps, and stream tokens through the online
serving session — all on CPU at smoke scale.

  PYTHONPATH=src python examples/quickstart.py --arch qwen3-moe-235b-a22b
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.core.split_exec import supports_split
from repro.models import build_model
from repro.runtime.engine import CrossPoolEngine
from repro.runtime.request import Request
from repro.runtime.sampler import sample
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import AdamW
from repro.training.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-235b-a22b",
                    choices=list(ARCH_NAMES))
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={cfg.param_counts()['total'] / 1e6:.2f}M (smoke)")

    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # --- forward ----------------------------------------------------------
    kw = {}
    seq = 32
    if cfg.frontend == "vision_patches":
        kw["embeddings"] = jnp.asarray(
            rng.normal(size=(1, cfg.frontend_tokens, cfg.d_model)),
            jnp.float32)
        seq -= cfg.frontend_tokens
    if cfg.family == "audio":
        kw["encoder_frames"] = jnp.asarray(
            rng.normal(size=(1, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, seq)), jnp.int32)
    logits, aux = jax.jit(lambda p, t: model.forward(p, t, **kw))(params,
                                                                  tokens)
    print(f"forward: logits {logits.shape} aux_loss {float(aux):.4f}")

    # --- prefill + greedy decode ------------------------------------------
    cache = model.init_cache(1, seq + 16)
    step_logits, cache = model.prefill(params, tokens, cache, **kw)
    out = []
    tok = sample(step_logits)
    decode = jax.jit(lambda p, t, c, l: model.decode_step(p, t, c, l))
    for i in range(8):
        out.append(int(tok[0]))
        step_logits, cache = decode(params, tok, cache, jnp.int32(seq + i))
        tok = sample(step_logits)
    print(f"decoded 8 tokens: {out}")

    # --- a few train steps ---------------------------------------------------
    optimizer = AdamW(lr=3e-3, warmup_steps=5)
    state = init_train_state(model, optimizer, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(model, optimizer, remat=False,
                                   extra_inputs=(lambda b: kw) if kw else None))
    data = SyntheticLM(DataConfig(cfg.vocab_size, seq, 8))
    for i, batch in zip(range(args.steps), data.batches()):
        state, metrics = step(state, {"tokens": jnp.asarray(batch["tokens"]),
                                      **{k: jnp.broadcast_to(v, (8,) + v.shape[1:])
                                         for k, v in kw.items()}})
        if i % 5 == 0 or i == args.steps - 1:
            print(f"train step {i:3d} loss {float(metrics['loss']):.4f}")

    # --- online serving session: submit / step / stream ------------------
    if supports_split(cfg):
        engine = CrossPoolEngine({cfg.name: cfg}, page_budget=512,
                                 page_bytes=4096, slab_bytes=4096,
                                 max_batch=2, max_ctx=64)
        streamed = []
        handle = engine.submit(Request(0, cfg.name, 8, 4, 0.0),
                               on_token=lambda e: streamed.append(e.token))
        while not handle.done:
            engine.step()
        print(f"session streamed {streamed} "
              f"(admission={handle.admission}, state={handle.state.value})")
    print("quickstart OK")


if __name__ == "__main__":
    main()
