"""Serving runtime tests: engine end-to-end, traces, simulator behaviour."""
import numpy as np

from repro.configs import PAPER_COLOC_SET, get_config, get_smoke_config
from repro.runtime import observe as trace_mod
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime.observe import percentile
from repro.runtime.simulator import (DecodeSimulator, decode_step_time,
                                     max_rps_for_context, paper_placements)


def _coloc_smoke():
    return {n: get_smoke_config(n).replace(dtype="float32")
            for n in PAPER_COLOC_SET}


def _coloc_full():
    return {n: get_config(n) for n in PAPER_COLOC_SET}


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

class TestTraces:
    def test_sharegpt_stats(self):
        rng = np.random.default_rng(0)
        t = trace_mod.sharegpt_like(5000, rng)
        assert 100 < np.median(t.prompt_tokens) < 500
        assert np.percentile(t.prompt_tokens, 99) > 1000

    def test_longalign_heavy_tail(self):
        rng = np.random.default_rng(0)
        t = trace_mod.longalign_like(5000, rng)
        assert np.percentile(t.prompt_tokens, 90) > 8000
        assert t.prompt_tokens.max() <= 65536

    def test_poisson_rate(self):
        rng = np.random.default_rng(0)
        arr = trace_mod.poisson_arrivals(0.5, 10_000, rng)
        assert abs(len(arr) / 10_000 - 0.5) < 0.05

    def test_request_stream_sorted(self):
        reqs = trace_mod.make_requests(
            list(PAPER_COLOC_SET), rps_per_model=0.5, horizon_s=100,
            seed=1)
        times = [r.arrival_time for r in reqs]
        assert times == sorted(times)
        assert len({r.model for r in reqs}) == 3


# ---------------------------------------------------------------------------
# engine (real compute, smoke models)
# ---------------------------------------------------------------------------

class TestEngine:
    def _run(self, mode, n_req=6, seed=3):
        models = _coloc_smoke()
        engine = CrossPoolEngine(models, page_budget=4096, page_bytes=4096,
                                 max_batch=2, max_ctx=64, mode=mode,
                                 seed=seed)
        reqs = trace_mod.make_requests(
            list(models), rps_per_model=2.0, horizon_s=n_req / 2,
            kind="sharegpt", seed=seed, scale_tokens=0.05, max_new_cap=6)
        reqs = reqs[:n_req]
        for r in reqs:
            r.prompt_tokens = max(min(r.prompt_tokens, 24), 4)
        stats = engine.run(reqs)
        return engine, reqs, stats

    def test_serves_all_requests(self):
        engine, reqs, stats = self._run(EngineMode(pipeline=True,
                                                   lowering=True))
        finished = [r for r in reqs if r.finish_time > 0]
        assert len(finished) >= 1
        assert stats.tokens_out > 0
        for r in finished:
            assert len(r.output_ids) == r.max_new_tokens

    def test_pages_released_after_completion(self):
        engine, reqs, stats = self._run(EngineMode(pipeline=False,
                                                   lowering=True))
        live = set(engine.virt.requests)
        unfinished = {r.request_id for r in reqs if r.finish_time == 0
                      and r.phase.value != "rejected"}
        assert live <= unfinished | set()
        # all finished requests' pages are back
        assert engine.virt.mapped_pages == sum(
            sum(len(t) for t in rp.tables) + len(rp.state_pages)
            for rp in engine.virt.requests.values())

    def test_tbt_recorded(self):
        engine, reqs, stats = self._run(EngineMode(pipeline=True,
                                                   lowering=True))
        assert len(stats.tbt) > 0
        assert all(t >= 0 for t in stats.tbt)
        p99 = percentile(stats.tbt, 99)
        assert np.isfinite(p99)


# ---------------------------------------------------------------------------
# simulator (paper-scale cost model)
# ---------------------------------------------------------------------------

class TestSimulator:
    def test_step_time_ordering(self):
        """Persistent+pipelined crosspool steps beat host-driven ones."""
        models = _coloc_full()
        lowered = paper_placements(models, "crosspool", pipelined=True,
                                   lowered=True)
        unlowered = paper_placements(models, "crosspool", pipelined=False,
                                     lowered=False)
        cfg = list(models.values())[0]
        t_fast = decode_step_time(cfg, 4, 4 * 1024, lowered)
        t_slow = decode_step_time(cfg, 4, 4 * 1024, unlowered)
        assert t_fast < t_slow

    def test_fig6_capacity_cliffs(self):
        """CrossPool keeps positive max-RPS into context bins where the
        baselines' per-replica visibility cliffs have already hit."""
        models = _coloc_full()
        ctxs = [8192, 65536, 262144, 1_048_576]

        def supported(system):
            pl = paper_placements(models, system)
            return [c for c in ctxs
                    if max_rps_for_context(models, pl, c) > 0]

        sup_static = supported("static")
        sup_kvc = supported("kvcached")
        sup_xp = supported("crosspool")
        assert max(sup_xp) >= max(sup_kvc)
        assert max(sup_xp) >= max(sup_static)
        # per-model cliff for the Type II (MLA) model specifically
        mla = {k: v for k, v in models.items() if v.attention == "mla"}
        pl_k = paper_placements(models, "kvcached")
        pl_x = paper_placements(models, "crosspool")
        name = next(iter(mla))
        assert pl_x.kv_visible[name] > 2 * pl_k.kv_visible[name]

    def test_fig7_tail_tbt_ordering(self):
        """At 0.8 RPS/model: kvcached P99 TBT >> crosspool P99 TBT (the
        paper's headline table), static remains lowest."""
        models = _coloc_full()
        reqs_proto = trace_mod.make_requests(
            list(models), rps_per_model=0.8, horizon_s=120, kind="sharegpt",
            seed=7)

        def run(system):
            import copy
            reqs = copy.deepcopy(reqs_proto)
            pl = paper_placements(models, system)
            sim = DecodeSimulator(models, pl)
            out = sim.run(reqs)
            return percentile(out["tbt"], 99)

        p99_static = run("static")
        p99_kvc = run("kvcached")
        p99_xp = run("crosspool")
        assert p99_xp < p99_kvc, (p99_xp, p99_kvc)
        assert p99_static <= p99_xp * 2.0   # static is the lower bound-ish

    def test_ablation_directionality(self):
        """Both mechanisms individually improve simulated throughput; both
        together improve it most (Table 3 shape)."""
        models = _coloc_full()
        reqs_proto = trace_mod.make_requests(
            list(models), rps_per_model=0.5, horizon_s=60, kind="sharegpt",
            seed=9)

        def tokens_per_s(pipelined, lowered):
            import copy
            reqs = copy.deepcopy(reqs_proto)
            pl = paper_placements(models, "crosspool", pipelined=pipelined,
                                  lowered=lowered)
            sim = DecodeSimulator(models, pl)
            out = sim.run(reqs)
            tok = sum(r.generated for r in reqs)
            span = max((r.finish_time for r in reqs if r.finish_time), default=1)
            return tok / span

        base = tokens_per_s(False, False)
        only_low = tokens_per_s(False, True)
        only_pipe = tokens_per_s(True, False)
        both = tokens_per_s(True, True)
        assert only_low > base
        assert only_pipe > base
        assert both > max(only_low, only_pipe)
