"""Sequence-sharded decode attention (flash-decoding style) via shard_map.

This is the TPU-native mechanism behind the paper's Fig. 2b: instead of DP
attention (where a request only sees one replica's KV capacity), the KV
cache of ONE request is sharded along the *sequence* axis across the KV-pool
devices.  Each shard computes a partial softmax (m_i, l_i, o_i) over its
slice and the partials are combined with a log-sum-exp reduction:

    m   = pmax_i m_i
    out = sum_i exp(m_i - m) * o_i  /  sum_i exp(m_i - m) * l_i

The collectives move O(B * H * D) bytes — independent of context length —
which is exactly the communication bound the paper engineers for (hidden
states, not KV tensors, cross the pool boundary).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 moved shard_map to the top level
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)

NEG_INF = -1e30


def _axis_sizes(mesh: Mesh, kv_axes: Tuple[str, ...]) -> Tuple[int, ...]:
    """Static mesh extents of the KV shard axes.

    Resolved from the mesh at trace time instead of ``lax.axis_size``
    (which some jax builds lack inside shard_map) — the sizes are static
    properties of the mesh, so baking them in changes nothing."""
    return tuple(int(mesh.shape[ax]) for ax in kv_axes)


def _shard_offset(kv_axes: Tuple[str, ...], sizes: Tuple[int, ...],
                  local_t: int) -> jax.Array:
    """Global token offset of this shard's KV slice (row-major over axes)."""
    idx = jnp.int32(0)
    for ax, size in zip(kv_axes, sizes):
        idx = idx * size + lax.axis_index(ax)
    return idx * local_t


def _combine(o_i, m_i, l_i, kv_axes):
    """LSE-combine partial attention across the kv shard axes."""
    m = lax.pmax(m_i, kv_axes)                       # [...,1] global max
    w = jnp.exp(m_i - m)
    num = lax.psum(o_i * w[..., None], kv_axes)
    den = lax.psum(l_i * w, kv_axes)
    return num / jnp.maximum(den, 1e-20)[..., None]


def make_seq_decode_attn(mesh: Mesh, kv_axes: Tuple[str, ...],
                         batch_axes: Optional[Tuple[str, ...]], scale: float):
    """GQA/MQA decode attention with KV sequence-sharded over ``kv_axes``.

    Returns fn(q [B,1,H,D], cache_k [B,T,KV,D], cache_v, lengths [B])
    -> out [B,1,H,D].  ``lengths`` counts valid tokens (incl. current).
    """
    bspec = batch_axes if batch_axes else None
    sizes = _axis_sizes(mesh, kv_axes)

    def local(q, k, v, lengths):
        Bl, _, H, D = q.shape
        Tl, KV = k.shape[1], k.shape[2]
        G = H // KV
        offset = _shard_offset(kv_axes, sizes, Tl)
        qg = q.reshape(Bl, KV, G, D).astype(jnp.float32)
        s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * scale
        pos = offset + jnp.arange(Tl)
        mask = pos[None, None, None, :] < lengths[:, None, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_i = jnp.max(s, axis=-1)                            # [B,KV,G]
        p = jnp.where(mask, jnp.exp(s - m_i[..., None]), 0.0)
        l_i = jnp.sum(p, axis=-1)
        o_i = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
        out = _combine(o_i, m_i, l_i, kv_axes)               # [B,KV,G,D]
        return out.reshape(Bl, 1, H, D).astype(q.dtype)

    return shard_map(
        local, mesh,
        in_specs=(P(bspec, None, None, None), P(bspec, kv_axes, None, None),
                  P(bspec, kv_axes, None, None), P(bspec)),
        out_specs=P(bspec, None, None, None),
    )


def make_seq_mla_decode_attn(mesh: Mesh, kv_axes: Tuple[str, ...],
                             batch_axes: Optional[Tuple[str, ...]],
                             scale: float):
    """MLA (absorbed-form) decode attention, latent cache sequence-sharded.

    fn(q_lat [B,1,H,r], q_rope [B,1,H,p], cache_latent [B,T,r],
       cache_rope [B,T,p], lengths [B]) -> ctx_lat [B,1,H,r].
    The context is returned in latent space (r), so the collective payload
    is B*H*r — the Type II KV-head-limited case stays communication-light.
    """
    bspec = batch_axes if batch_axes else None
    sizes = _axis_sizes(mesh, kv_axes)

    def local_clean(q_lat, q_rope, latent, rope, lengths):
        Bl, _, H, R = q_lat.shape
        Tl = latent.shape[1]
        offset = _shard_offset(kv_axes, sizes, Tl)
        s = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                        latent.astype(jnp.float32))
             + jnp.einsum("bshp,btp->bhst", q_rope.astype(jnp.float32),
                          rope.astype(jnp.float32))) * scale   # [B,H,1,T]
        s = s[:, :, 0, :]                                      # [B,H,T]
        pos = offset + jnp.arange(Tl)
        mask = pos[None, None, :] < lengths[:, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_i = jnp.max(s, axis=-1)                              # [B,H]
        p = jnp.where(mask, jnp.exp(s - m_i[..., None]), 0.0)
        l_i = jnp.sum(p, axis=-1)
        o_i = jnp.einsum("bht,btr->bhr", p, latent.astype(jnp.float32))
        out = _combine(o_i, m_i, l_i, kv_axes)                 # [B,H,R]
        return out[:, None].astype(q_lat.dtype)                # [B,1,H,R]

    return shard_map(
        local_clean, mesh,
        in_specs=(P(bspec, None, None, None), P(bspec, None, None, None),
                  P(bspec, kv_axes, None), P(bspec, kv_axes, None), P(bspec)),
        out_specs=P(bspec, None, None, None),
    )
