"""llama3-405b — dense Llama-3.1 405B [arXiv:2407.21783; unverified].

Assigned config: 126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16_384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53_248,
    vocab_size=128_256,
    attention="gqa",
    rope_theta=500_000.0,
    max_position=131_072,
    source="arXiv:2407.21783; unverified",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8, d_ff=128,
    vocab_size=256, max_position=512,
)
