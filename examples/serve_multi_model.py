"""End-to-end CrossPool serving driver (the paper's scenario).

Pipeline: workload traces -> KV-cache planner (Eq. 1-2 Monte Carlo sizing)
-> shared pool + virtualizer -> admission control -> the CrossPool engine
colocating three cold MoE/MLA models -> decode with batched requests ->
TBT / throughput / pool-utilization report.

  PYTHONPATH=src python examples/serve_multi_model.py --rps 1.0 --horizon 8

``--online`` drives the session API instead of the offline ``run()``
wrapper: requests are submitted one by one as their Poisson arrival time
comes due, tokens stream through per-request callbacks, and same-model
arrivals coalesce into [B, S] prefill passes between decode steps.
"""
import argparse

import numpy as np

from repro.configs import (ElasticConfig, EngineConfig, FlightRecorderConfig,
                           PAPER_COLOC_SET, SLObjective, SLOConfig,
                           get_smoke_config)
from repro.core.planner import (WorkloadSpec, plan_pool, split_device_budget,
                                worst_case_pages, worst_case_weight_bytes)
from repro.core.weight_pool import slabs_for_config
from repro.runtime import observe as trace_mod
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime.observe import EngineObserver, percentile


def serve_online(engine, reqs):
    """Drive the session API from the trace's arrival clock: submit each
    request when due, step between arrivals, stream tokens via callbacks.
    Returns (handles, finalized stats)."""
    first_events = []
    handles = []
    pending = sorted(reqs, key=lambda r: r.arrival_time)
    steps = 0
    while pending or engine.busy:
        if steps >= 10_000:
            break
        steps += 1
        # idle with future arrivals: advance the clock to the next one,
        # BEFORE submitting, so admission stamps the arrival time
        if not engine.busy and pending:
            engine.advance(pending[0].arrival_time)
        now = engine.now
        due = [r for r in pending if r.arrival_time <= now]
        pending = [r for r in pending if r.arrival_time > now]
        for r in due:
            handles.append(engine.submit(
                r, on_token=lambda e: first_events.append(e)
                if e.first else None))
        events = engine.step()
        if not events and not pending and not engine.busy:
            break          # only unserviceable queued requests remain
    for e in first_events[:3]:
        print(f"  stream: request {e.request_id} ({e.model}) first token "
              f"{e.token} at t={e.time:.3f}s")
    return handles, engine.finalize()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rps", type=float, default=1.0)
    ap.add_argument("--horizon", type=float, default=8.0)
    ap.add_argument("--quantile", type=float, default=0.99)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--online", action="store_true",
                    help="drive the submit/step session API from the "
                         "arrival trace instead of the offline run() wrapper")
    ap.add_argument("--elastic", action="store_true",
                    help="enable the online KV<->weights boundary "
                         "rebalancer (windowed re-plan + host KV swap "
                         "tier; DESIGN.md §8)")
    ap.add_argument("--slo-demo", default=None, metavar="RECORD_PATH",
                    help="postmortem demo (DESIGN.md §13): attach "
                         "deliberately unmeetable latency SLOs so the "
                         "burn-rate monitor breaches mid-run, auto-dumping "
                         "a flight record here; replay it with "
                         "`python -m repro.launch.replay RECORD_PATH`")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write Prometheus-text metrics here after the run")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON here after the "
                         "run (open in Perfetto / chrome://tracing)")
    args = ap.parse_args()
    observer = (EngineObserver()
                if args.metrics_out or args.trace_out else None)

    models = {n: get_smoke_config(n) for n in PAPER_COLOC_SET}

    # --- 1. offline: plan the shared KV pool from workload samples --------
    rng = np.random.default_rng(0)
    specs = []
    for i, (name, cfg) in enumerate(models.items()):
        r = np.random.default_rng(i)
        specs.append(WorkloadSpec(
            model=cfg, arrival_rate=args.rps,
            prompt_tokens=r.integers(4, 48, 300),
            output_tokens=r.integers(2, args.max_new + 1, 300),
            decode_time=r.uniform(0.05, 1.0, 300)))
    plan = plan_pool(specs, page_bytes=4096, quantile=args.quantile,
                     horizon_s=120.0, n_trials=3)
    worst = worst_case_pages(specs, 4096, horizon_s=120.0)
    print("=== planner ===")
    print(plan.summary())
    print(f"static worst-case would need {worst} pages "
          f"({worst / max(plan.pool_page_budget, 1):.1f}x the pooled budget)")

    # split one device-byte budget between the KV pool and the weights
    # arena from the arrival rates; at these smoke rates every model is
    # expected resident, so the arena sizes to the full colocation set.
    # coresident=2 floors the arena at the two largest models together:
    # with prefill ALSO through the arena, a cold model's prompt phase can
    # then always map alongside the model currently decoding.
    slab_bytes = 1 << 16
    all_resident = sum(slabs_for_config(c, slab_bytes)
                       for c in models.values()) * slab_bytes
    total = int(1.25 * (plan.pool_bytes + all_resident))
    dev_plan = split_device_budget(specs, total, page_bytes=4096,
                                   slab_bytes=slab_bytes, horizon_s=120.0,
                                   n_trials=3, coresident=2)
    print(dev_plan.summary())
    print(f"per-model-static weights baseline: "
          f"{worst_case_weight_bytes(specs) / 2 ** 20:.1f} MiB device FFN")

    # --- 2. online: serve through the planned budgets ---------------------
    page_budget = max(dev_plan.page_budget, 512)   # smoke-scale floor
    print(f"engine budgets: {page_budget} pages, "
          f"{dev_plan.slot_budget} slabs")
    # --slo-demo: objectives no smoke run can meet (sub-microsecond TTFT /
    # TBT) so the multi-rate burn monitor breaches within the first window
    # and the flight recorder auto-dumps a postmortem record
    slo = (SLOConfig(objectives={n: SLObjective(ttft_ms=1e-3, tbt_p99_ms=1e-3)
                                 for n in models},
                     window_s=4.0, short_window_s=0.5)
           if args.slo_demo else None)
    flightrec = (FlightRecorderConfig(dump_path=args.slo_demo,
                                      snapshot_interval_steps=2)
                 if args.slo_demo else None)
    engine = CrossPoolEngine(
        models, page_budget=page_budget,
        page_bytes=4096, slot_budget=dev_plan.slot_budget,
        slab_bytes=slab_bytes, max_batch=4, max_ctx=64,
        config=EngineConfig(
            mode=EngineMode(pipeline=True, lowering=True),
            elastic=ElasticConfig(window_s=max(args.horizon, 4.0))
            if args.elastic else None,
            slo=slo, flightrec=flightrec),
        observer=observer)
    reqs = trace_mod.make_requests(
        list(models), rps_per_model=args.rps, horizon_s=args.horizon,
        kind="sharegpt", scale_tokens=0.05, max_new_cap=args.max_new)
    for r in reqs:
        r.prompt_tokens = max(min(r.prompt_tokens, 48), 2)
    print(f"\n=== serving {len(reqs)} requests over {len(models)} cold "
          f"models ({'online submit/step' if args.online else 'batch run()'})"
          f" ===")
    if args.online:
        handles, stats = serve_online(engine, reqs)
        by_state = {}
        for h in handles:
            by_state[h.state.value] = by_state.get(h.state.value, 0) + 1
        coalesced = [b for b in stats.prefill_batch_sizes if b > 1]
        print(f"handles: {by_state}; prefill passes "
              f"{len(stats.prefill_batch_sizes)} "
              f"({len(coalesced)} coalesced, max B = "
              f"{max(stats.prefill_batch_sizes, default=0)})")
        if stats.elastic:
            print(f"elastic: kv occupancy EWMA "
                  f"{stats.elastic['kv_occupancy_ewma']:.3f}, slab "
                  f"{stats.elastic['slab_occupancy_ewma']:.3f}, "
                  f"{int(stats.elastic.get('rebalances', 0))} rebalances, "
                  f"swap {engine.virt.swap_out_pages} out / "
                  f"{engine.virt.swap_in_pages} in")
    else:
        stats = engine.run(reqs)

    finished = [r for r in reqs if r.finish_time > 0]
    print(f"finished {len(finished)}/{len(reqs)}  tokens {stats.tokens_out}  "
          f"throughput {stats.throughput:.1f} tok/s")
    print(f"TBT p50/p95/p99 = {percentile(stats.tbt, 50) * 1e3:.1f} / "
          f"{percentile(stats.tbt, 95) * 1e3:.1f} / "
          f"{percentile(stats.tbt, 99) * 1e3:.1f} ms")
    print(f"TTFT p95 = {percentile(stats.ttft, 95) * 1e3:.1f} ms")
    print("=== engine report ===")
    print(engine.report())
    # prefill-phase device FFN bytes come from the ARENA (no full-tree
    # column left): every paged runner serves prompt AND decode through
    # (arena, slot_table), so device FFN bytes are phase-invariant
    w = engine.arena.utilization()
    print(f"device FFN bytes, prefill phase = decode phase = "
          f"{w['device_bytes'] / 2 ** 20:.1f} MiB "
          f"(slot_budget {w['slot_budget']} x {slab_bytes} B slabs)")
    assert all(r.params is None for r in engine.runners.values() if r.paged), \
        "a paged runner still holds a full param tree"
    assert stats.tokens_out > 0
    if args.slo_demo:
        rec = engine.recorder
        assert engine.slo.breach_count() > 0, \
            "SLO demo thresholds should be unmeetable"
        assert rec.dumps > 0, "breach should have auto-dumped a flight record"
        print(f"SLO breaches: {engine.slo.breach_count()} "
              f"({engine.slo.report_line(engine.now)})")
        print(f"flight record auto-dumped on first breach -> "
              f"{args.slo_demo} ({len(rec.ring)} events, "
              f"{len(rec.snapshots)} snapshots)")
        print(f"postmortem: python -m repro.launch.replay {args.slo_demo}")
    if observer is not None:
        if args.metrics_out:
            observer.metrics.write(args.metrics_out)
            print(f"metrics -> {args.metrics_out}")
        if args.trace_out:
            observer.tracer.write(args.trace_out)
            print(f"trace -> {args.trace_out} "
                  f"({len(observer.tracer.events)} events)")
    print("serve_multi_model OK")


if __name__ == "__main__":
    main()
