"""Serving launcher: the CrossPool engine over colocated cold models.

  python -m repro.launch.serve --rps 0.5 --horizon 20 --pipeline --lowering
  python -m repro.launch.serve --arch qwen3-14b --shape decode_32k --dry-run

Host-scale runs colocate the paper's model trio at smoke scale and report
decode TBT percentiles + pool statistics; --dry-run lowers the production
serve_step for an (arch x shape) cell instead.  ``--metrics-out`` /
``--trace-out`` attach an :class:`~repro.runtime.observe.EngineObserver`
and write Prometheus metrics / a Perfetto-loadable Chrome trace
(DESIGN.md §10) — CI's observability smoke step runs exactly that.
"""
from __future__ import annotations

import argparse
from typing import Optional

import numpy as np


def parse_slo_specs(specs: list, model_names: list):
    """``"model:ttft_ms=200,tbt_p99_ms=50"`` (repeatable) -> SLOConfig.
    Model ``*`` expands to every colocated model; later specs override."""
    from repro.configs.base import SLObjective, SLOConfig
    objectives = {}
    for spec in specs:
        model, _, body = spec.partition(":")
        if not body:
            raise SystemExit(f"--slo {spec!r}: expected model:k=v[,k=v...]")
        kwargs = {}
        for item in body.split(","):
            key, _, val = item.partition("=")
            try:
                kwargs[key.strip()] = float(val)
            except ValueError:
                raise SystemExit(f"--slo {spec!r}: bad value {item!r}")
        try:
            obj = SLObjective(**kwargs)
        except TypeError as err:
            raise SystemExit(f"--slo {spec!r}: {err}")
        for name in (model_names if model.strip() == "*" else [model.strip()]):
            if name not in model_names:
                raise SystemExit(f"--slo {spec!r}: unknown model {name!r} "
                                 f"(colocated: {model_names})")
            objectives[name] = obj
    return SLOConfig(objectives=objectives)


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="dry-run arch")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--strategy", default="crosspool",
                    choices=["crosspool", "monolithic"])
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    # engine options
    ap.add_argument("--rps", type=float, default=0.5)
    ap.add_argument("--horizon", type=float, default=10.0)
    ap.add_argument("--pipeline", action="store_true", default=True)
    ap.add_argument("--no-pipeline", dest="pipeline", action="store_false")
    ap.add_argument("--lowering", action="store_true", default=True)
    ap.add_argument("--no-lowering", dest="lowering", action="store_false")
    ap.add_argument("--page-budget", type=int, default=8192)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="K tokens committed per fused decode dispatch "
                         "(DESIGN.md §9; host-driven lowering clamps to 1)")
    ap.add_argument("--cache", action="store_true",
                    help="enable radix-tree prefix caching over the KV "
                         "pool (DESIGN.md §11): trace requests get real "
                         "prompt ids sharing a per-model system prefix, "
                         "and the cache snapshot is reported")
    ap.add_argument("--elastic", action="store_true",
                    help="enable the online KV<->weights rebalancer "
                         "(DESIGN.md §8)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write Prometheus-text metrics here after serving "
                         "(DESIGN.md §10)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write Chrome trace-event JSON here after serving "
                         "(open in Perfetto / chrome://tracing)")
    ap.add_argument("--slo", action="append", default=None, metavar="SPEC",
                    help='per-model latency objective, repeatable: '
                         '"model:ttft_ms=200,tbt_p99_ms=50,'
                         'queue_wait_ms=500,target=0.99" — model "*" '
                         'applies to every colocated model (DESIGN.md §13)')
    ap.add_argument("--flight-record-out", default=None, metavar="PATH",
                    help="dump the flight record (full causal input "
                         "stream + pool snapshots) here after serving; "
                         "replay with `python -m repro.launch.replay PATH`")
    args = ap.parse_args(argv)

    if args.dry_run:
        from repro.launch import dryrun
        assert args.arch, "--arch required with --dry-run"
        rec = dryrun.run_cell(args.arch, args.shape,
                              multi_pod=args.multi_pod,
                              strategy_name=args.strategy)
        raise SystemExit(0 if rec.get("ok") else 1)

    from repro.configs import PAPER_COLOC_SET, get_smoke_config
    from repro.configs.base import (CacheConfig, ElasticConfig, EngineConfig,
                                    FlightRecorderConfig)
    from repro.runtime import observe as trace_mod
    from repro.runtime.engine import CrossPoolEngine, EngineMode
    from repro.runtime.observe import EngineObserver, percentile

    observer = (EngineObserver()
                if args.metrics_out or args.trace_out else None)
    models = {n: get_smoke_config(n) for n in PAPER_COLOC_SET}
    slo_cfg = parse_slo_specs(args.slo, list(models)) if args.slo else None
    rec_cfg = (FlightRecorderConfig(dump_path=args.flight_record_out)
               if args.flight_record_out else None)
    engine = CrossPoolEngine(
        models, page_budget=args.page_budget, max_batch=4, max_ctx=128,
        config=EngineConfig(
            mode=EngineMode(pipeline=args.pipeline, lowering=args.lowering,
                            decode_steps_per_dispatch=args.decode_steps),
            elastic=ElasticConfig() if args.elastic else None,
            cache=CacheConfig(enabled=args.cache),
            slo=slo_cfg, flightrec=rec_cfg),
        observer=observer)
    reqs = trace_mod.make_requests(
        list(models), rps_per_model=args.rps, horizon_s=args.horizon,
        kind="sharegpt", scale_tokens=0.1, max_new_cap=args.max_new)
    if args.cache:
        # synthetic trace counts are cache-ineligible by design; give each
        # request REAL ids whose head is a per-model "system prompt" so
        # same-bucket requests share a cacheable prefix
        rng = np.random.default_rng(0)
        system = {n: rng.integers(0, models[n].vocab_size, 64)
                  .astype(np.int32) for n in models}
        for r in reqs:
            n = r.prompt_tokens
            ids = np.concatenate([system[r.model][:n], rng.integers(
                0, models[r.model].vocab_size, max(0, n - 64))])
            r.prompt_ids = ids[:n].astype(np.int32)
    print(f"serving {len(reqs)} requests across {len(models)} cold models "
          f"(pipeline={args.pipeline}, lowering={args.lowering}, "
          f"decode_steps={args.decode_steps})")
    stats = engine.run(reqs)
    print(f"tokens out: {stats.tokens_out}  virtual wall: {stats.wall_s:.2f}s "
          f"throughput: {stats.throughput:.1f} tok/s")
    print(f"TBT p50/p95/p99: {percentile(stats.tbt, 50) * 1e3:.1f} / "
          f"{percentile(stats.tbt, 95) * 1e3:.1f} / "
          f"{percentile(stats.tbt, 99) * 1e3:.1f} ms")
    print(f"admission: {engine.admission.stats}")
    print(f"pool: {engine.virt.utilization()}")
    if engine.cache is not None:
        print(f"prefix cache: {engine.cache.snapshot()}")
    print(f"straggler steps flagged: {stats.slow_steps}")
    if engine.slo is not None:
        print(engine.slo.report_line(engine.now))
    if args.flight_record_out:
        engine.recorder.dump(args.flight_record_out)
        print(f"flight record -> {args.flight_record_out} "
              f"({len(engine.recorder.ring)} events, "
              f"{len(engine.recorder.snapshots)} snapshots); replay with "
              f"`python -m repro.launch.replay {args.flight_record_out}`")
    if observer is not None:
        if args.metrics_out:
            observer.metrics.write(args.metrics_out)
            print(f"metrics -> {args.metrics_out}")
        if args.trace_out:
            observer.tracer.write(args.trace_out)
            print(f"trace -> {args.trace_out} "
                  f"({len(observer.tracer.events)} events)")


if __name__ == "__main__":
    main()
