"""Structural HLO analysis with loop-aware execution counts.

XLA's ``cost_analysis()`` counts every computation body ONCE — a
scan-over-layers while body is tallied as a single layer (verified
empirically on this backend; see EXPERIMENTS.md §Methodology).  This module
re-derives per-module totals by parsing the post-partitioning HLO text:

  * builds a symbol table of result shapes per computation,
  * attributes dot FLOPs (2 * |result| * contraction) per computation,
  * finds while ops and their body computations, assigns each body an
    execution count = parent count x trip count, where trip counts come
    from the KNOWN program structure (scan lengths: layers, microbatches,
    groups) supplied by the caller as a per-depth list,
  * sums collective payload bytes with the same counts.

Elementwise/reduce FLOPs are ignored (matmul-dominated workloads) and
fusion-internal dots are attributed to the computation containing the
fusion — both noted as methodology caveats.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_CALLEE_RE = re.compile(r"(?:body|to_apply|branch_computations|"
                        r"called_computations|condition)=\{?(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_dims(tok: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.match(tok)
    if not m:
        return "f32", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


def _first_shape(text: str) -> Optional[str]:
    m = _SHAPE_RE.search(text)
    return m.group(0) if m else None


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    shapes: Dict[str, Tuple[str, List[int]]] = field(default_factory=dict)
    dot_flops: float = 0.0
    collectives: Dict[str, int] = field(default_factory=dict)
    coll_count: int = 0
    # (body comp name, known trip count or None)
    whiles: List[Tuple[str, Optional[int]]] = field(default_factory=list)
    calls: List[str] = field(default_factory=list)      # fusions/calls
    conds: List[str] = field(default_factory=list)      # while conditions
    root_rhs: str = ""                                  # ROOT line's rhs
    host_transfers: int = 0    # outfeed/infeed/send/recv ops in this comp


_COMMENT_RE = re.compile(r"/\*.*?\*/")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# Ops that move bytes between device and host mid-program.  Entry
# parameters/results are the ONLY other device<->host surface, and those
# are covered separately by entry_output_shapes().
_HOST_TRANSFER_RE = re.compile(
    r"\b(outfeed|infeed|send|send-done|recv|recv-done)\(")


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        raw = _COMMENT_RE.sub("", raw)          # strip /*index=N*/ comments
        mc = _COMP_RE.match(raw)
        if mc and "=" not in raw.split("{")[0]:
            cur = Computation(mc.group(2), is_entry=bool(mc.group(1)))
            comps[cur.name] = cur
            continue
        if raw.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        md = _DEF_RE.match(raw)
        if not md:
            continue
        name, rhs = md.groups()
        if raw.lstrip().startswith("ROOT"):
            cur.root_rhs = rhs
        shape_tok = _first_shape(rhs)
        if shape_tok:
            cur.shapes[name] = _shape_dims(shape_tok)

        if _HOST_TRANSFER_RE.search(rhs):
            cur.host_transfers += 1
        if " dot(" in rhs or rhs.startswith("dot("):
            cur.dot_flops += _dot_flops(rhs, cur.shapes)
        for kind in _COLLECTIVES:
            if f" {kind}(" in rhs:
                dt, dims = _shape_dims(shape_tok or "f32[]")
                nbytes = _DTYPE_BYTES.get(dt, 4) * math.prod(dims or [0])
                cur.collectives[kind] = cur.collectives.get(kind, 0) + nbytes
                cur.coll_count += 1
                break
        mw = _BODY_RE.search(rhs)
        if mw and " while(" in rhs:
            mt = _TRIP_RE.search(rhs)
            cur.whiles.append((mw.group(1),
                               int(mt.group(1)) if mt else None))
        mcall = _CALLS_RE.search(rhs)
        if mcall:
            cur.calls.append(mcall.group(1))
        for m in re.finditer(r"to_apply=(%[\w.\-]+)", rhs):
            cur.calls.append(m.group(1))
        for m in re.finditer(r"condition=(%[\w.\-]+)", rhs):
            cur.conds.append(m.group(1))
    return comps


_OPERAND_NAME_RE = re.compile(r"%[\w.\-]+")


def _dot_flops(rhs: str, shapes: Dict[str, Tuple[str, List[int]]]) -> float:
    """2 * |result| * K for one dot line."""
    shape_tok = _first_shape(rhs)
    if not shape_tok:
        return 0.0
    _, result_dims = _shape_dims(shape_tok)
    # operands: HLO prints each as "<shape>{layout} %name" — the first
    # %name in the argument list is the lhs (a lookup keyed on the whole
    # token would miss the symbol table and silently drop K)
    args = re.findall(r"dot\(([^)]*)\)", rhs)
    if not args:
        return 0.0
    m = _OPERAND_NAME_RE.search(args[0])
    lhs = shapes.get(m.group(0)) if m else None
    if lhs is None:
        # contraction operand shape from the operand token itself
        # (pre-layout HLO sometimes omits the symbol-table entry)
        st = _SHAPE_RE.search(args[0])
        lhs = _shape_dims(st.group(0)) if st else None
    mcon = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    k = 1
    if lhs and mcon:
        for d in mcon.group(1).split(","):
            if d and int(d) < len(lhs[1]):
                k *= lhs[1][int(d)]
    return 2.0 * math.prod(result_dims or [1]) * k


@dataclass
class ModuleStats:
    flops: float
    collective_bytes: Dict[str, int]
    collective_total: int
    coll_count: int


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    Older jaxlibs return one properties dict; newer ones return a
    per-module LIST of dicts.  Every caller of the backend numbers (the
    EXPERIMENTS methodology scripts and the analyzer's own tests) wants
    the entry module's dict, so resolve the difference here once.
    """
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        return c[0] if c else {}
    return c


def _entry_computation(
        comps: Dict[str, Computation]) -> Optional[Computation]:
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        # scheduled SPMD modules print no ENTRY prefix: the entry is the
        # computation no other computation references
        referenced = set()
        for c in comps.values():
            referenced.update(b for b, _ in c.whiles)
            referenced.update(c.calls)
            referenced.update(c.conds)
        roots = [c for c in comps.values() if c.name not in referenced]
        entry = max(roots, key=lambda c: len(c.shapes), default=None)
    return entry


def _root_type(rhs: str) -> str:
    """The result-type prefix of a ROOT line's rhs.

    Either a parenthesized tuple type ``(f32[..]{..}, s32[..])`` or a
    single shape token before the opcode.
    """
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rhs[:i + 1]
    return rhs.split(" ", 1)[0]


def entry_output_shapes(hlo: str) -> List[Tuple[str, List[int]]]:
    """(dtype, dims) of every tensor the program returns to the host.

    This is the full device->host transfer surface of a dispatch (plus any
    mid-program transfer ops, which ``host_transfer_count`` covers): a
    multi-step decode program must NOT return per-step logits here — only
    sampled token ids and the carried KV pool.
    """
    entry = _entry_computation(parse_module(hlo))
    if entry is None or not entry.root_rhs:
        return []
    ty = _root_type(entry.root_rhs)
    return [_shape_dims(m.group(0)) for m in _SHAPE_RE.finditer(ty)]


def host_transfer_count(hlo: str) -> int:
    """Mid-program device<->host transfer ops reachable from ENTRY."""
    comps = parse_module(hlo)
    entry = _entry_computation(comps)
    if entry is None:
        return 0
    seen: set = set()
    total = 0

    def visit(comp: Computation):
        nonlocal total
        if comp.name in seen:
            return
        seen.add(comp.name)
        total += comp.host_transfers
        for body, _ in comp.whiles:
            if body in comps:
                visit(comps[body])
        for callee in comp.calls + comp.conds:
            if callee in comps:
                visit(comps[callee])

    visit(entry)
    return total


_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\(\s*(\d+)\s*,\s*\{([\d,\s]*)\}")


def input_output_aliases(hlo: str) -> List[Tuple[Tuple[int, ...], int,
                                                 Tuple[int, ...]]]:
    """Donation aliases from the ``HloModule`` header line.

    Each entry is ``(output_index, param_number, param_index)`` — the
    compiled proof that a ``donate_argnums`` buffer is actually reused
    in place (XLA drops the alias silently when shapes/layouts prevent
    it, so "we passed donate_argnums" is a claim, THIS is the fact).
    Indices are shape-tree paths: ``()`` for the whole (non-tuple)
    value, ``(i,)`` for tuple element i.
    """
    header = next((ln for ln in hlo.splitlines()
                   if ln.startswith("HloModule")), "")
    start = header.find("input_output_alias={")
    if start < 0:
        return []
    # balanced-brace scan: the block nests ``{0}: (0, {}, may-alias)``
    # entries, so a non-greedy regex would stop at the first inner ``}``
    i = start + len("input_output_alias=")
    depth, end = 0, i
    for end in range(i, len(header)):
        if header[end] == "{":
            depth += 1
        elif header[end] == "}":
            depth -= 1
            if depth == 0:
                break
    block = header[i + 1:end]
    out = []
    for om, param, pm in _ALIAS_ENTRY_RE.findall(block):
        o_idx = tuple(int(d) for d in om.split(",") if d.strip())
        p_idx = tuple(int(d) for d in pm.split(",") if d.strip())
        out.append((o_idx, int(param), p_idx))
    return out


def donated_params(hlo: str) -> List[int]:
    """Entry-parameter numbers that alias some output (sorted, unique)."""
    return sorted({param for _, param, _ in input_output_aliases(hlo)})


def while_trip_structure(hlo: str) -> List[Tuple[int, Optional[int]]]:
    """(nesting depth, known trip count) for every while under ENTRY.

    Depth 0 = whiles issued directly by the entry computation (or by
    fusions/calls it makes).  A K-step fused decode program shows exactly
    one depth-0 while with trip count K wrapping the depth-1 layer scan —
    the structural proof that K tokens cost one dispatch.
    """
    comps = parse_module(hlo)
    entry = _entry_computation(comps)
    if entry is None:
        return []
    out: List[Tuple[int, Optional[int]]] = []

    def visit(comp: Computation, depth: int):
        for body, trips in comp.whiles:
            out.append((depth, trips))
            if body in comps:
                visit(comps[body], depth + 1)
        for callee in comp.calls:
            if callee in comps:
                visit(comps[callee], depth)

    visit(entry, 0)
    return out


def analyze(hlo: str, depth_trips: List[int]) -> ModuleStats:
    """Walk from ENTRY, assigning execution counts.

    ``depth_trips[d]`` = trip count of while loops at nesting depth d
    (depth 0 = whiles in ENTRY).  Deeper loops than provided reuse the last
    entry.  Fusions/calls inherit their caller's count.
    """
    comps = parse_module(hlo)
    entry = _entry_computation(comps)
    if entry is None:
        return ModuleStats(0.0, {}, 0, 0)

    counts: Dict[str, float] = {}

    def visit(comp: Computation, count: float, depth: int):
        counts[comp.name] = counts.get(comp.name, 0.0) + count
        for body, known_trips in comp.whiles:
            if known_trips is not None:
                trips = known_trips           # exact, from backend_config
            elif depth_trips:
                trips = depth_trips[min(depth, len(depth_trips) - 1)]
            else:
                trips = 1
            if body in comps:
                visit(comps[body], count * trips, depth + 1)
        for callee in comp.calls:
            if callee in comps:
                visit(comps[callee], count, depth)

    visit(entry, 1.0, 0)

    flops = 0.0
    coll: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    n_coll = 0
    for name, comp in comps.items():
        c = counts.get(name, 0.0)
        if c == 0.0:
            continue
        flops += comp.dot_flops * c
        for kind, b in comp.collectives.items():
            coll[kind] += int(b * c)
        n_coll += int(comp.coll_count * c)
    return ModuleStats(flops, coll, sum(coll.values()), n_coll)


def depth_trips_for(cfg, shape, microbatches: int = 1) -> List[int]:
    """Per-depth while trip counts from the KNOWN program structure."""
    fam = cfg.family
    if fam == "hybrid":
        inner = [max(cfg.hybrid_groups + (1 if cfg.tail_ssm_layers else 0), 1),
                 max(cfg.ssm_per_group, 1)]
    elif cfg.swa_pattern > 0:
        inner = [max(cfg.n_layers // cfg.swa_pattern, 1),
                 max(cfg.swa_pattern - 1, 1)]
    elif fam == "audio":
        # encoder + decoder scans sit at the same depth; average trip
        inner = [max((cfg.n_encoder_layers + cfg.n_layers) // 2, 1)]
    else:
        inner = [max(cfg.n_layers, 1)]
    # SSD chunked scan adds one more while level on full-sequence paths
    if fam in ("ssm", "hybrid") and shape.kind in ("train", "prefill"):
        seq = shape.seq_len
        chunk = next((c for c in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                      if c <= seq and seq % c == 0), 1)
        inner = inner + [max(seq // chunk, 1)]
    if shape.kind == "train" and microbatches > 1:
        return [microbatches] + inner
    return inner
