import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimb driver: baseline + named variants for one cell.

Each variant re-lowers the cell with different Strategy/PerfOpts knobs and
reports the three roofline terms + per-device memory, appending JSONL for
EXPERIMENTS.md §Perf.

  python -m repro.launch.perf --cell llama3-405b:train_4k \
      --variants baseline,seq_parallel,mb32,mb32+sp,compress
"""
import argparse
import json
from typing import Dict, Optional

from repro.launch import dryrun
from repro.sharding.strategies import PerfOpts

VARIANTS: Dict[str, Dict] = {
    "baseline": {},
    "seq_parallel": {"perf": PerfOpts(seq_parallel=True)},
    "compress": {"perf": PerfOpts(compress_grads=True)},
    "sp+compress": {"perf": PerfOpts(seq_parallel=True, compress_grads=True)},
    "mb16": {"perf": PerfOpts(microbatches=16)},
    "mb8": {"perf": PerfOpts(microbatches=8)},
    "mb4": {"perf": PerfOpts(microbatches=4)},
    "mb32": {"perf": PerfOpts(microbatches=32)},
    "mb64": {"perf": PerfOpts(microbatches=64)},
    "mb32+sp": {"perf": PerfOpts(microbatches=32, seq_parallel=True)},
    "mb64+sp": {"perf": PerfOpts(microbatches=64, seq_parallel=True)},
    "mb64+sp+compress": {"perf": PerfOpts(microbatches=64, seq_parallel=True,
                                          compress_grads=True)},
    "monolithic": {"strategy_name": "monolithic"},
    "kv_model": {"perf": PerfOpts(kv_seq_override=("model",))},
    "kv_data_model": {"perf": PerfOpts(kv_seq_override=("data", "model"))},
    "moe_a2a": {"perf": PerfOpts(moe_a2a=True)},
    "kv_f8": {"perf": PerfOpts(kv_dtype="f8")},
    "moe_a2a+kv_f8": {"perf": PerfOpts(moe_a2a=True, kv_dtype="f8")},
    "a2a+mb16": {"perf": PerfOpts(moe_a2a=True, microbatches=16)},
    "a2a+mb16+f8d": {"perf": PerfOpts(moe_a2a=True, microbatches=16,
                                      f8_dispatch=True)},
    "a2a+f8d+kv_f8": {"perf": PerfOpts(moe_a2a=True, f8_dispatch=True,
                                       kv_dtype="f8")},
    "a2a+sp": {"perf": PerfOpts(moe_a2a=True, seq_parallel=True)},
    "a2a+sp+compress": {"perf": PerfOpts(moe_a2a=True, seq_parallel=True,
                                         compress_grads=True)},
}


def run_variant(arch: str, shape: str, name: str, *, multi_pod: bool,
                out: Optional[str]) -> Dict:
    kw = dict(VARIANTS[name])
    rec = dryrun.run_cell(arch, shape, multi_pod=multi_pod,
                          strategy_name=kw.pop("strategy_name", "auto"),
                          verbose=False, **kw)
    rec["variant"] = name
    if rec.get("ok"):
        r = rec["roofline"]
        m = rec["memory"]
        fit = (m["argument_bytes"] or 0) + (m["temp_bytes"] or 0)
        print(f"{arch} x {shape} [{name:>18}]: "
              f"compute {r['t_compute']:.3e}  memory {r['t_memory']:.3e}  "
              f"collective {r['t_collective']:.3e}  -> {r['dominant']:>10} | "
              f"hbm {fit / 2 ** 30:5.1f} GiB/dev | "
              f"frac {r['roofline_fraction'] * 100:5.1f}%")
    else:
        print(f"{arch} x {shape} [{name:>18}]: FAIL "
              f"{rec.get('error', rec.get('reason', ''))[:100]}")
    if out:
        with open(out, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="reports/perf.jsonl")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    for name in args.variants.split(","):
        run_variant(arch, shape, name.strip(), multi_pod=args.multi_pod,
                    out=args.out)


if __name__ == "__main__":
    main()
