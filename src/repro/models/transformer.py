"""Decoder stacks for every assigned family, as scan-over-layers programs.

Families:
  dense / vlm   — [attn + SwiGLU] x L (vlm prepends stub patch embeddings)
  moe           — [attn + routed-expert FFN] x L
  dense + SWA   — gemma3 5:1 local:global pattern, ring-buffer local caches
  ssm           — [Mamba2 SSD] x L (attention-free)
  hybrid        — zamba2: groups of (k SSD layers + one SHARED attn block)
  audio         — whisper enc-dec: encoder stack + [self + cross + MLP] x L

Everything is ``lax.scan`` over stacked layer params so the 94-126 layer
full configs lower to a compact HLO (one layer body + loop), which keeps the
multi-pod dry-run compile tractable and matches how a production framework
would ship these models.

The CrossPool pool boundary is marked by ``hooks.boundary_in/out`` around
every FFN/MoE call: under the crosspool sharding strategy these become the
hidden-state re-layout (attention layout -> weights-pool layout) that the
paper transfers over NVLink/NVSHMEM and we lower to ICI collectives.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers, moe as moe_mod, ssm as ssm_mod
from repro.models.hooks import Hooks, IDENTITY_HOOKS


def _stack_init(key, n: int, init_fn):
    """vmap an init function over n layer keys -> stacked params pytree."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Per-layer param initializers
# ---------------------------------------------------------------------------

def _init_attn_params(key, cfg: ModelConfig, dtype) -> Dict:
    if cfg.attention == "mla":
        return attn.init_mla(key, cfg, dtype)
    return attn.init_gqa(key, cfg, dtype)


def _init_dense_layer(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": _init_attn_params(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype),
    }


def _init_moe_layer(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": _init_attn_params(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "moe": moe_mod.init_moe(k2, cfg, dtype),
    }


def _init_ssm_layer(key, cfg: ModelConfig, dtype) -> Dict:
    return {
        "ln": jnp.zeros((cfg.d_model,), dtype),
        "ssm": ssm_mod.init_ssm(key, cfg, dtype),
    }


def _init_enc_layer(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attn.init_gqa(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": layers.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype),
    }


def _init_encdec_layer(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "self": attn.init_gqa(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "cross": attn.init_gqa(k2, cfg, dtype),
        "ln3": jnp.zeros((cfg.d_model,), dtype),
        "mlp": layers.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype),
    }


# ---------------------------------------------------------------------------
# Parameter init for the whole stack
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Dict:
    dtype = _dtype(cfg)
    k_embed, k_layers, k_extra = jax.random.split(key, 3)
    p: Dict = {
        "embed": layers.init_embed(k_embed, cfg.vocab_size, cfg.d_model,
                                   dtype, cfg.tie_embeddings),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["layers"] = _stack_init(
            k_layers, cfg.n_layers, lambda k: _init_dense_layer(k, cfg, dtype))
    elif fam == "moe":
        p["layers"] = _stack_init(
            k_layers, cfg.n_layers, lambda k: _init_moe_layer(k, cfg, dtype))
    elif fam == "ssm":
        p["layers"] = _stack_init(
            k_layers, cfg.n_layers, lambda k: _init_ssm_layer(k, cfg, dtype))
    elif fam == "hybrid":
        n_ssm = cfg.hybrid_groups * cfg.ssm_per_group
        p["layers"] = _stack_init(
            k_layers, n_ssm, lambda k: _init_ssm_layer(k, cfg, dtype)
        ) if n_ssm else {}
        if cfg.tail_ssm_layers:
            p["tail"] = _stack_init(
                k_extra, cfg.tail_ssm_layers,
                lambda k: _init_ssm_layer(k, cfg, dtype))
        # the zamba2 hallmark: ONE shared attention+MLP block reused per group
        p["shared_block"] = _init_dense_layer(
            jax.random.fold_in(k_extra, 1), cfg, dtype)
    elif fam == "audio":
        p["enc_layers"] = _stack_init(
            k_extra, cfg.n_encoder_layers,
            lambda k: _init_enc_layer(k, cfg, dtype))
        p["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
        p["layers"] = _stack_init(
            k_layers, cfg.n_layers, lambda k: _init_encdec_layer(k, cfg, dtype))
    else:
        raise ValueError(f"unknown family {fam}")
    return p


# ---------------------------------------------------------------------------
# Embedding helpers (modality frontends are stubs: precomputed embeddings)
# ---------------------------------------------------------------------------

def embed_inputs(params: Dict, cfg: ModelConfig, tokens: jax.Array,
                 embeddings: Optional[jax.Array] = None,
                 positions: Optional[jax.Array] = None) -> jax.Array:
    """tokens [B,S_txt] (+ optional stub embeddings [B,S_emb,D] prefix)."""
    x = layers.embed_tokens(params["embed"], tokens)
    if embeddings is not None:
        x = jnp.concatenate([embeddings.astype(x.dtype), x], axis=1)
    if cfg.rope_theta == 0 and positions is not None:
        # whisper-style absolute sinusoidal positions
        x = x + layers.sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    return x


def _logits(params: Dict, cfg: ModelConfig, x: jax.Array,
            hooks: Hooks) -> jax.Array:
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return hooks.logits(layers.unembed(params["embed"], x))


# ---------------------------------------------------------------------------
# Attention sub-block dispatch (full-sequence)
# ---------------------------------------------------------------------------

def _attn_full(p_l: Dict, cfg: ModelConfig, x: jax.Array, positions,
               window: int, hooks: Hooks, impl: str):
    h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
    if cfg.attention == "mla":
        out, kv = attn.mla_full(p_l["attn"], cfg, h, positions, hooks=hooks)
    else:
        out, kv = attn.gqa_full(p_l["attn"], cfg, h, positions,
                                window=window, hooks=hooks, impl=impl)
    return x + hooks.act(out), kv


def _ffn_full(p_l: Dict, cfg: ModelConfig, x: jax.Array, hooks: Hooks,
              moe_path: str = "capacity"):
    h = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
    h = hooks.boundary_in(h)
    if cfg.is_moe:
        if hooks.moe_apply is not None:       # explicit a2a dispatch
            f, aux = hooks.moe_apply(p_l["moe"], h)
        else:
            fn = (moe_mod.apply_moe if moe_path == "capacity"
                  else moe_mod.apply_moe_grouped)
            f, aux = fn(p_l["moe"], h, cfg, hooks=hooks)
    else:
        f = layers.apply_mlp(p_l["mlp"], h, cfg.mlp_kind, hook=hooks.ffn_hidden)
        aux = jnp.zeros((), jnp.float32)
    return x + hooks.act(hooks.boundary_out(f)), aux


# ---------------------------------------------------------------------------
# FULL-SEQUENCE forward (train / prefill without cache seeding)
# ---------------------------------------------------------------------------

def forward(params: Dict, cfg: ModelConfig, tokens: jax.Array, *,
            embeddings: Optional[jax.Array] = None,
            encoder_frames: Optional[jax.Array] = None,
            hooks: Hooks = IDENTITY_HOOKS, impl: str = "xla",
            moe_path: str = "capacity", remat: bool = False,
            ) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits [B,S,V], aux_loss scalar).

    ``remat=True`` checkpoints each scan body (activation rematerialization):
    only the per-layer carries are saved, everything else is recomputed in
    the backward pass — the standard memory/compute trade for 100B+ training.
    """
    fam = cfg.family
    B = tokens.shape[0]
    S_total = tokens.shape[1] + (embeddings.shape[1] if embeddings is not None else 0)
    positions = jnp.broadcast_to(jnp.arange(S_total)[None, :], (B, S_total))
    x = embed_inputs(params, cfg, tokens, embeddings, positions)

    aux0 = jnp.zeros((), jnp.float32)

    def _maybe_remat(body):
        return jax.checkpoint(body) if remat else body

    if fam in ("dense", "vlm", "moe"):
        is_global = _swa_global_flags(cfg)

        def body(carry, layer_in):
            xc, aux = carry
            p_l, glob = layer_in
            window = 0 if cfg.sliding_window == 0 else cfg.sliding_window
            if cfg.sliding_window:
                # traced per-layer flag: global layers use window=0 semantics
                # encoded in the mask, local layers bound to the window.
                xc, _ = _attn_full_swa(p_l, cfg, xc, positions, glob, hooks, impl)
            else:
                xc, _ = _attn_full(p_l, cfg, xc, positions, 0, hooks, impl)
            xc, a = _ffn_full(p_l, cfg, xc, hooks, moe_path)
            return (xc, aux + a), None

        xs = (params["layers"], is_global)
        (x, aux), _ = jax.lax.scan(_maybe_remat(body), (x, aux0), xs)
        return _logits(params, cfg, x, hooks), aux / max(cfg.n_layers, 1)

    if fam == "ssm":
        def body(xc, p_l):
            h = layers.rms_norm(xc, p_l["ln"], cfg.norm_eps)
            out, _ = ssm_mod.ssm_full(p_l["ssm"], cfg, h, hooks=hooks)
            return xc + hooks.act(out), None
        x, _ = jax.lax.scan(_maybe_remat(body), x, params["layers"])
        return _logits(params, cfg, x, hooks), aux0

    if fam == "hybrid":
        def ssm_body(xc, p_l):
            h = layers.rms_norm(xc, p_l["ln"], cfg.norm_eps)
            out, _ = ssm_mod.ssm_full(p_l["ssm"], cfg, h, hooks=hooks)
            return xc + hooks.act(out), None

        def group_body(xc, group_params):
            xc, _ = jax.lax.scan(ssm_body, xc, group_params)
            xc, _ = _attn_full(params["shared_block"], cfg, xc, positions,
                               0, hooks, impl)
            xc, _ = _ffn_full(params["shared_block"], cfg, xc, hooks)
            return xc, None

        grouped = jax.tree.map(
            lambda a: a.reshape(cfg.hybrid_groups, cfg.ssm_per_group, *a.shape[1:]),
            params["layers"])
        x, _ = jax.lax.scan(_maybe_remat(group_body), x, grouped)
        if cfg.tail_ssm_layers:
            x, _ = jax.lax.scan(ssm_body, x, params["tail"])
        return _logits(params, cfg, x, hooks), aux0

    if fam == "audio":
        enc_out = encode(params, cfg, encoder_frames, hooks=hooks)

        def body(xc, p_l):
            h = layers.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
            out, _ = attn.gqa_full(p_l["self"], cfg, h, positions, hooks=hooks,
                                   impl=impl)
            xc = xc + hooks.act(out)
            h = layers.rms_norm(xc, p_l["ln2"], cfg.norm_eps)
            out, _ = attn.gqa_full(p_l["cross"], cfg, h, positions,
                                   kv_override=_cross_kv(p_l["cross"], cfg, enc_out),
                                   causal=False, hooks=hooks)
            xc = xc + hooks.act(out)
            h = layers.rms_norm(xc, p_l["ln3"], cfg.norm_eps)
            h = hooks.boundary_in(h)
            f = layers.apply_mlp(p_l["mlp"], h, cfg.mlp_kind, hook=hooks.ffn_hidden)
            return xc + hooks.act(hooks.boundary_out(f)), None

        x, _ = jax.lax.scan(_maybe_remat(body), x, params["layers"])
        return _logits(params, cfg, x, hooks), aux0

    raise ValueError(f"unknown family {fam}")


def _cross_kv(p_attn: Dict, cfg: ModelConfig, enc_out: jax.Array):
    B, T, _ = enc_out.shape
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p_attn["wk"]).reshape(B, T, KV, hd)
    v = (enc_out @ p_attn["wv"]).reshape(B, T, KV, hd)
    return k, v


def encode(params: Dict, cfg: ModelConfig, frames: jax.Array, *,
           hooks: Hooks = IDENTITY_HOOKS) -> jax.Array:
    """Whisper encoder over stub frame embeddings [B,T_enc,D]."""
    B, T, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    x = frames + layers.sinusoidal_positions(pos, cfg.d_model).astype(frames.dtype)

    def body(xc, p_l):
        h = layers.rms_norm(xc, p_l["ln1"], cfg.norm_eps)
        out, _ = attn.gqa_full(p_l["attn"], cfg, h, pos, causal=False,
                               hooks=hooks)
        xc = xc + hooks.act(out)
        h = layers.rms_norm(xc, p_l["ln2"], cfg.norm_eps)
        f = layers.apply_mlp(p_l["mlp"], h, cfg.mlp_kind, hook=hooks.ffn_hidden)
        return xc + hooks.act(f), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layers.rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Sliding-window variants (gemma3): per-layer traced global/local flag
# ---------------------------------------------------------------------------

def _swa_global_flags(cfg: ModelConfig) -> jax.Array:
    """[L] bool — True where the layer uses global attention."""
    if cfg.swa_pattern == 0:
        return jnp.ones((max(cfg.n_layers, 1),), bool)
    idx = jnp.arange(cfg.n_layers)
    return (idx + 1) % cfg.swa_pattern == 0


def _attn_full_swa(p_l, cfg, x, positions, is_global, hooks, impl):
    """Full-seq attention where locality is a *traced* per-layer flag.

    mask = causal AND (is_global OR within window) — this keeps one scan body
    for all 48 gemma3 layers instead of unrolling.
    """
    h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
    q, k, v = attn._project_qkv(p_l["attn"], cfg, h)
    if cfg.rope_theta > 0:
        sin, cos = layers.rope_sin_cos(positions, cfg.head_dim, cfg.rope_theta)
        q = layers.apply_rope(q, sin, cos)
        k = layers.apply_rope(k, sin, cos)
    q = hooks.attn_q(q)
    k, v = hooks.kv(k), hooks.kv(v)
    causal = positions[..., :, None] >= positions[..., None, :]
    local = (positions[..., :, None] - positions[..., None, :]
             ) < cfg.sliding_window
    mask = (causal & (is_global | local))[:, None, None, :, :]
    out = attn.attention_core(q, k, v, mask, cfg.head_dim ** -0.5)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return x + hooks.act(hooks.attn_out(out @ p_l["attn"]["wo"])), (k, v)
