"""Admission control: per-model queues enforcing the planner's budgets.

Paper §3.1: "if the pool page budget is exhausted, admission control queues
or rejects new requests instead of interrupting active decode requests."
Active pages are never revoked; shedding happens only at admission.

Since prefill runs through the weights arena too, admission is
ARENA-AWARE: a request for a cold model implies ``total_slabs`` of upload
traffic (``weight_pool.slabs_for_config`` of it, computed from the packed
view), and admitting it would evict resident models LRU.  ``try_admit``
therefore also checks that the cold model's slabs are reachable WITHOUT
revoking a model that is pinned or has controller-tracked in-flight
requests — a burst of cold-model arrivals queues at the front door instead
of thrashing the arena's LRU between models that both still have work.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, Dict, List

from repro.core.virtualizer import KVVirtualizer
from repro.core.weight_pool import OutOfSlabsError


@dataclass
class PendingRequest:
    request_id: int
    model: str
    prompt_tokens: int
    expected_output: int
    arrival_time: float
    enqueue_time: float = 0.0


@dataclass
class ModelAdmissionStats:
    """Per-model admitted/queued/rejected counters."""

    admitted: int = 0
    queued: int = 0
    rejected: int = 0


@dataclass
class AdmissionStats:
    admitted: int = 0
    queued: int = 0
    rejected: int = 0
    queue_wait_total: float = 0.0
    # admissions deferred purely by weights-arena pressure (cold-model burst)
    weight_pressure_queued: int = 0
    # admissions deferred by KV-page pressure (the rebalancer's grow signal)
    page_pressure_queued: int = 0
    per_model: Dict[str, ModelAdmissionStats] = field(default_factory=dict)

    def bump(self, model: str, outcome: str) -> None:
        """Count one admission outcome globally AND for ``model``."""
        setattr(self, outcome, getattr(self, outcome) + 1)
        m = self.per_model.setdefault(model, ModelAdmissionStats())
        setattr(m, outcome, getattr(m, outcome) + 1)


class AdmissionController:
    """Queue-or-reject front door for the shared KV pool + weights arena."""

    def __init__(self, virtualizer: KVVirtualizer, *, arena=None,
                 max_queue_per_model: int = 64,
                 reserve_output_tokens: bool = True):
        self.virt = virtualizer
        self.arena = arena              # WeightArena or None (KV-only mode)
        self.max_queue = max_queue_per_model
        self.reserve_output = reserve_output_tokens
        self.queues: Dict[str, Deque[PendingRequest]] = collections.defaultdict(
            collections.deque)
        # admitted-but-unfinished request count per model: the controller's
        # view of which models still have work in flight (the engine calls
        # ``finish`` as requests complete).  Admission also takes the
        # arena PIN for the request (released by ``finish``), so the LRU
        # eviction planner can never pick a model whose weights an
        # admitted request still needs — the capacity check below and the
        # victim selection in ``WeightArena._plan_evictions`` enforce the
        # same protected set.
        self.inflight: Dict[str, int] = collections.defaultdict(int)
        self._last_block: str = ""      # "pages" | "weights" | "" (admitted)
        # the elastic rebalancer's pressure signal: free pages held back
        # from admission (swap-tier fault-in headroom / pending-shrink
        # reservation).  Verdicts always read the LIVE budgets — the pool
        # objects are resized in place — and this reserve on top of them.
        self.reserve_pages: int = 0
        self.stats = AdmissionStats()
        # optional observability sink (core.hooks.CoreHooks); hook calls
        # mirror the ``stats.bump`` sites one-for-one, so the exported
        # admission counters can never disagree with AdmissionStats
        self.hooks = None

    def offer(self, req: PendingRequest, now: float) -> str:
        """Returns 'admitted' | 'queued' | 'rejected'."""
        if self.try_admit(req):
            self.stats.bump(req.model, "admitted")
            if self.hooks is not None:
                self.hooks.admission(req.model, "admitted", "")
            return "admitted"
        if len(self.queues[req.model]) < self.max_queue:
            req.enqueue_time = now
            self.queues[req.model].append(req)
            self.stats.bump(req.model, "queued")
            if self._last_block == "weights":
                # counted ONCE per deferred request, here — not on drain
                # retries and not for rejections
                self.stats.weight_pressure_queued += 1
            elif self._last_block == "pages":
                self.stats.page_pressure_queued += 1
            if self.hooks is not None:
                self.hooks.admission(req.model, "queued", self._last_block)
            return "queued"
        self.stats.bump(req.model, "rejected")
        if self.hooks is not None:
            self.hooks.admission(req.model, "rejected", "")
        return "rejected"

    # ------------------------------------------------------------------
    def _weights_pressure_ok(self, model: str) -> bool:
        """Whether admitting a request for ``model`` fits the arena without
        revoking weights another admitted request still needs.

        Reachable slabs = free + resident models that are neither pinned
        nor tracked in flight by this controller.  A resident or
        arena-less (fused fallback) model always passes.
        """
        arena = self.arena
        if arena is None or model not in arena.views:
            return True
        if arena.is_resident(model):
            return True
        need = arena.views[model].total_slabs
        if need > arena.slot_budget:
            # a budget error, not pressure: NO admission can ever serve
            # this model — fail loudly instead of queueing forever
            raise OutOfSlabsError(
                f"model {model!r} needs {need} slabs but the arena budget "
                f"is {arena.slot_budget}; raise slot_budget or drop the "
                f"model from the colocation set")
        reachable = arena.free_slabs + sum(
            arena.views[name].total_slabs
            for name in arena.residency
            if name not in arena.pins and not self.inflight.get(name))
        # slabs already promised to OTHER admitted cold models that have
        # not activated yet (their upload lands between now and prefill)
        promised = sum(
            arena.views[name].total_slabs
            for name, count in self.inflight.items()
            if count and name != model and name in arena.views
            and not arena.is_resident(name))
        return need <= reachable - promised

    def try_admit(self, req: PendingRequest) -> bool:
        """Admit iff BOTH budgets hold: KV pages for prompt (+ reserved
        output) AND weights-arena reachability for a cold model.

        Admission takes the request's arena PIN (released by ``finish``),
        so from this moment the model's weights can never be picked as an
        LRU eviction victim — including the window between admission and
        the prefill that makes the model resident."""
        expect = req.expected_output if self.reserve_output else 0
        if not self.virt.can_admit(req.model, req.prompt_tokens, expect,
                                   reserve=self.reserve_pages):
            self._last_block = "pages"
            return False
        if not self._weights_pressure_ok(req.model):
            self._last_block = "weights"
            return False
        self._last_block = ""
        self.virt.register_request(req.request_id, req.model,
                                   req.prompt_tokens)
        self.inflight[req.model] += 1
        if self.arena is not None and req.model in self.arena.views:
            self.arena.pin(req.model)
        return True

    def finish(self, model: str) -> None:
        """One of ``model``'s admitted requests completed (or was aborted):
        its pin drops and its weights become reachable for cold
        activations again once the in-flight count reaches zero."""
        n = self.inflight.get(model, 0) - 1
        if n <= 0:
            self.inflight.pop(model, None)
        else:
            self.inflight[model] = n
        if self.arena is not None and model in self.arena.views:
            self.arena.unpin(model)

    def cancel_queued(self, request_id: int) -> bool:
        """Remove a still-queued request from its model's front-door queue.

        Queued requests hold NO resources (``try_admit`` failed before any
        page/pin was taken), so cancellation is pure bookkeeping; admitted
        requests are cancelled through the engine, which releases pages and
        calls :meth:`finish` instead.
        """
        for q in self.queues.values():
            for pending in q:
                if pending.request_id == request_id:
                    q.remove(pending)
                    return True
        return False

    def drain(self, now: float) -> List[PendingRequest]:
        """Admit queued requests that now fit (FIFO per model, round-robin
        across models so one model cannot starve the others)."""
        admitted: List[PendingRequest] = []
        progress = True
        while progress:
            progress = False
            for model in list(self.queues):
                q = self.queues[model]
                if not q:
                    continue
                head = q[0]
                if self.try_admit(head):
                    q.popleft()
                    self.stats.queue_wait_total += now - head.enqueue_time
                    self.stats.bump(model, "admitted")
                    if self.hooks is not None:
                        self.hooks.admission(model, "admitted", "")
                        self.hooks.admission_wait(
                            model, now - head.enqueue_time)
                    admitted.append(head)
                    progress = True
        return admitted

    def queued_count(self) -> int:
        return sum(len(q) for q in self.queues.values())
