"""KV-cache virtualizer: paged virtualization of one shared physical pool.

TPU adaptation of the paper's CUDA-VMM design (DESIGN.md §2): XLA has no
virtual-memory API, so the pool is ONE pre-allocated device array of
fixed-size pages, and "mapping" is page-table bookkeeping on the host —
identical bytes, identical slow-path/fast-path split:

  * fast path (per token, on device): attention kernels read K/V through a
    page table (``repro.kernels.paged_attention``), writes go to
    (page, slot) coordinates — no allocation on the critical path;
  * slow path (per ~page, on host): ``map_pages`` / ``unmap_pages`` update
    the free list and per-request page tables against the planner's budget.

Heterogeneity (C1): the pool is untyped (flat bf16 elements).  Each model
views a page as ``tokens_per_page(M)`` tokens of ONE layer's K+V (or MLA
latent+rope, or SSM state), so models with different KV layouts share the
same physical pages.  ``tokens_per_page`` = page_elems // per-token-elems,
with the remainder as internal fragmentation — as in any real pager.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class OutOfPagesError(RuntimeError):
    pass


@dataclass
class ModelView:
    """How one model interprets physical pages."""

    name: str
    per_token_elems: int          # one layer's K+V (or latent) elems per token
    tokens_per_page: int
    n_kv_layers: int
    kv_shape: Tuple[int, ...]     # per-token per-layer logical shape

    def pages_for(self, tokens: int) -> int:
        """Physical pages to hold ``tokens`` across all KV layers."""
        if self.tokens_per_page == 0:
            return 0
        per_layer = math.ceil(tokens / self.tokens_per_page)
        return per_layer * self.n_kv_layers


def make_view(cfg: ModelConfig, page_elems: int) -> ModelView:
    if cfg.attn_free:
        return ModelView(cfg.name, 0, 0, 0, ())
    if cfg.attention == "mla":
        m = cfg.mla
        per_tok = m.kv_lora_rank + m.qk_rope_head_dim
        shape = (per_tok,)
    else:
        per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
        shape = (2, cfg.n_kv_heads, cfg.head_dim)
    tpp = page_elems // per_tok
    if tpp == 0:
        raise ValueError(
            f"{cfg.name}: per-token KV ({per_tok} elems) exceeds page size "
            f"({page_elems} elems); increase page_bytes")
    return ModelView(cfg.name, per_tok, tpp, cfg.n_decoder_attn_layers, shape)


@dataclass
class RequestPages:
    """Per-request mapping: page_table[layer][chunk] -> physical page id."""

    request_id: int
    model: str
    tokens: int = 0
    tables: List[List[int]] = field(default_factory=list)   # [layer][chunk]
    state_pages: List[int] = field(default_factory=list)    # SSM constant state


class KVVirtualizer:
    """Host-side pager over one device-resident physical pool."""

    def __init__(self, models: Dict[str, ModelConfig], *,
                 page_budget: int, page_bytes: int = 16 * 1024,
                 dtype=jnp.bfloat16, allocate_device_pool: bool = True):
        self.page_bytes = page_bytes
        self.page_elems = page_bytes // 2          # bf16
        self.page_budget = page_budget
        self.views = {n: make_view(c, self.page_elems)
                      for n, c in models.items()}
        self.configs = dict(models)
        self.free_list: List[int] = list(range(page_budget - 1, -1, -1))
        self.requests: Dict[int, RequestPages] = {}
        self.pool: Optional[jax.Array] = None
        if allocate_device_pool:
            self.pool = jnp.zeros((page_budget, self.page_elems), dtype)
        # stats
        self.peak_mapped = 0
        self.map_events = 0
        self.unmap_events = 0

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    @property
    def mapped_pages(self) -> int:
        return self.page_budget - len(self.free_list)

    @property
    def free_pages(self) -> int:
        return len(self.free_list)

    def can_admit(self, model: str, prompt_tokens: int,
                  expected_output: int = 0) -> bool:
        view = self.views[model]
        cfg = self.configs[model]
        need = view.pages_for(prompt_tokens + expected_output) if view.n_kv_layers \
            else 0
        need += math.ceil(cfg.state_bytes_per_request() / self.page_bytes)
        return need <= self.free_pages

    # ------------------------------------------------------------------
    # slow path: map / unmap
    # ------------------------------------------------------------------
    def _take(self, n: int) -> List[int]:
        if n > len(self.free_list):
            raise OutOfPagesError(
                f"need {n} pages, {len(self.free_list)} free "
                f"(budget {self.page_budget})")
        pages = [self.free_list.pop() for _ in range(n)]
        self.map_events += n
        self.peak_mapped = max(self.peak_mapped, self.mapped_pages)
        return pages

    def register_request(self, request_id: int, model: str,
                         prompt_tokens: int) -> RequestPages:
        """Map pages for a request's prompt KV (+ SSM state)."""
        view = self.views[model]
        cfg = self.configs[model]
        req = RequestPages(request_id, model)
        if view.n_kv_layers:
            chunks = math.ceil(max(prompt_tokens, 1) / view.tokens_per_page)
            for _ in range(view.n_kv_layers):
                req.tables.append(self._take(chunks))
        state_pages = math.ceil(cfg.state_bytes_per_request() / self.page_bytes)
        if state_pages:
            req.state_pages = self._take(state_pages)
        req.tokens = prompt_tokens
        self.requests[request_id] = req
        return req

    def extend_request(self, request_id: int, new_tokens: int = 1) -> None:
        """Grow a request by ``new_tokens`` (decode); maps pages on demand."""
        req = self.requests[request_id]
        view = self.views[req.model]
        if view.n_kv_layers:
            have = len(req.tables[0]) * view.tokens_per_page
            need_tokens = req.tokens + new_tokens
            while have < need_tokens:
                for t in req.tables:
                    t.extend(self._take(1))
                have += view.tokens_per_page
        req.tokens += new_tokens

    def release_request(self, request_id: int) -> None:
        req = self.requests.pop(request_id)
        n = 0
        for t in req.tables:
            self.free_list.extend(t)
            n += len(t)
        self.free_list.extend(req.state_pages)
        n += len(req.state_pages)
        self.unmap_events += n

    # ------------------------------------------------------------------
    # fast path: device views
    # ------------------------------------------------------------------
    def page_table_array(self, request_ids: List[int], layer: int,
                         max_pages: int) -> jax.Array:
        """[B, max_pages] int32 physical ids (-1 = unmapped) for one layer."""
        out = np.full((len(request_ids), max_pages), -1, np.int32)
        for i, rid in enumerate(request_ids):
            tab = self.requests[rid].tables[layer]
            out[i, : min(len(tab), max_pages)] = tab[: max_pages]
        return jnp.asarray(out)

    def typed_pages(self, model: str) -> jax.Array:
        """The pool viewed as ``[n_pages, tokens_per_page, *kv_shape]``.

        Zero-copy reshape of the shared flat pool; the slack elements at the
        end of each page are invisible to the kernel.
        """
        view = self.views[model]
        used = view.tokens_per_page * view.per_token_elems
        return self.pool[:, :used].reshape(
            (self.page_budget, view.tokens_per_page) + view.kv_shape)

    def write_tokens(self, model: str, layer: int, request_id: int,
                     start_token: int, kv: jax.Array) -> None:
        """Write ``kv [n_new, *kv_shape]`` at token offset ``start_token``.

        Slow-ish host-coordinated scatter (engine path; per-layer per-step).
        """
        view = self.views[model]
        req = self.requests[request_id]
        flat = kv.reshape(kv.shape[0], view.per_token_elems).astype(
            self.pool.dtype)
        for j in range(kv.shape[0]):
            tok = start_token + j
            page = req.tables[layer][tok // view.tokens_per_page]
            off = (tok % view.tokens_per_page) * view.per_token_elems
            self.pool = jax.lax.dynamic_update_slice(
                self.pool, flat[j][None, :], (page, off))

    # ------------------------------------------------------------------
    def utilization(self) -> Dict[str, float]:
        frag = 0.0
        for rid, req in self.requests.items():
            view = self.views[req.model]
            if not view.n_kv_layers:
                continue
            used = req.tokens * view.per_token_elems * view.n_kv_layers
            held = sum(len(t) for t in req.tables) * self.page_elems
            frag += held - used
        return {
            "mapped_pages": self.mapped_pages,
            "free_pages": self.free_pages,
            "peak_mapped": self.peak_mapped,
            "internal_frag_bytes": frag * 2,
        }
