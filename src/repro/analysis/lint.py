"""Invariant lint: AST rules codifying the DESIGN.md pool contracts.

Eight PRs of pool disaggregation left correctness rules living as prose
(DESIGN.md §§2-11) — "hooks fire one-for-one adjacent to counters",
"sampling only in ``runtime/sampler.py``", "EngineConfig is the only
constructor surface" — exactly the contracts a reviewer forgets first.
This module turns them into machine-checked rules over the repo's own
source tree (no third-party linter: the container ships no extra
binaries, and the rules are repo-SPECIFIC anyway):

  CP001  no host synchronization (``jax.device_get`` / ``np.asarray`` /
         ``np.array`` / ``.block_until_ready``) inside a jitted or
         traced function body — a host sync in a traced body either
         fails at trace time or, worse, silently bakes a stale constant
         into the compiled program (the jaxpr audit's CPA01 twin).
  CP002  no ``jnp.argmax`` / ``jax.random.categorical`` sampling
         outside ``runtime/sampler.py`` — one sampling surface keeps
         greedy/temperature semantics and dtype conventions identical
         across the engine, the dry-run harness and the benchmarks.
  CP003  every pool-accounting mutation fires its ``core.hooks``
         call in the same function (counter/hook one-for-one adjacency,
         DESIGN.md §10) — an unpaired counter silently desynchronizes
         the exported metrics from pool truth.
  CP004  no deprecated loose-kwarg ``CrossPoolEngine(mode=..., ...)``
         construction — ``config=EngineConfig(...)`` is the one surface.
  CP005  no ad-hoc percentile math outside ``benchmarks/_stats.py`` /
         ``runtime/observe.py`` — one quantile definition keeps P99s
         comparable across benchmarks and the metrics registry.
  CP006  no wall-clock reads (``time.time``/``perf_counter``/...) in
         engine latency paths (``runtime/engine.py``, ``runtime/
         session.py``, ``core/``) — engine time is VIRTUAL (``now``);
         the few legitimate dispatch-duration sites carry pragmas.
  CP007  no bare ``assert`` in pool-accounting modules — asserts vanish
         under ``python -O``; use ``core.errors.check`` /
         ``PoolAccountingError`` (they survive).

A finding is silenced ONLY by an explicit pragma on the offending line
or the line above it::

    t0 = time.perf_counter()   # cp: allow(CP006) dispatch wall-duration

CLI: ``python -m repro.analysis.lint [paths...]`` — defaults to the
repo's ``src/repro``, ``benchmarks`` and ``examples`` trees (tests are
exempt: they legitimately use argmax for expected values, wall clocks
for timeouts, and asserts everywhere), exits non-zero on any finding.
"""
from __future__ import annotations

import ast
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "CP001": "host sync inside a jitted/traced function body",
    "CP002": "sampling primitive outside runtime/sampler.py",
    "CP003": "pool-accounting mutation without its adjacent hook call",
    "CP004": "deprecated loose-kwarg engine construction",
    "CP005": "ad-hoc percentile outside the canonical quantile modules",
    "CP006": "wall-clock read in an engine latency path",
    "CP007": "bare assert in a pool-accounting module",
}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> str:
    """'jax.random.categorical' for an Attribute/Name chain ('' if other)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _pragma_allows(lines: Sequence[str], lineno: int, rule: str) -> bool:
    """True when the line carries ``cp: allow(<rule>)``, or the line above
    is a standalone ``# cp: allow(...)`` comment (a trailing pragma only
    covers its own line — it must not leak onto the next one)."""
    def has(text: str) -> bool:
        return f"cp: allow({rule})" in text or "cp: allow(all)" in text

    if 1 <= lineno <= len(lines) and has(lines[lineno - 1]):
        return True
    if lineno >= 2:
        above = lines[lineno - 2]
        if above.lstrip().startswith("#") and has(above):
            return True
    return False


def _walk_funcs(tree: ast.AST):
    """Yield every function/lambda definition node in the module."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            yield node


# ---------------------------------------------------------------------------
# CP001 — host sync inside jitted bodies
# ---------------------------------------------------------------------------

_HOST_SYNC_CALLS = {
    "jax.device_get", "np.asarray", "np.array", "numpy.asarray",
    "numpy.array",
}

_JIT_CALLS = {"jax.jit", "jit", "partial"}  # partial(jax.jit, ...) pattern


def _jitted_names(tree: ast.AST) -> Set[str]:
    """Names of module-local functions that end up traced: passed to
    ``jax.jit``, used as a ``lax.scan`` body, decorated ``@jax.jit``, or
    collected into a ``StageFns(...)`` bundle (split-execution stage fns
    are jitted downstream by ``HostDrivenStep``/``PagedFusedStep``)."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            callee = _dotted(node.func)
            first = node.args[0] if node.args else None
            if callee in ("jax.jit", "jit") and isinstance(first, ast.Name):
                names.add(first.id)
            if callee == "partial" and first is not None \
                    and _dotted(first) in ("jax.jit", "jit"):
                for a in node.args[1:]:
                    if isinstance(a, ast.Name):
                        names.add(a.id)
            if callee.endswith("lax.scan") and isinstance(first, ast.Name):
                names.add(first.id)
            if callee == "StageFns":
                names.update(a.id for a in node.args
                             if isinstance(a, ast.Name))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                d = dec.func if isinstance(dec, ast.Call) else dec
                if _dotted(d) in ("jax.jit", "jit"):
                    names.add(node.name)
    return names


def _check_host_sync(tree: ast.AST, path: str, lines: Sequence[str]
                     ) -> List[Finding]:
    jitted = _jitted_names(tree)
    out: List[Finding] = []
    # jitted defs by name + lambdas passed directly to jax.jit/lax.scan
    bodies: List[ast.AST] = [
        f for f in _walk_funcs(tree)
        if isinstance(f, (ast.FunctionDef, ast.AsyncFunctionDef))
        and f.name in jitted]
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and node.args \
                and isinstance(node.args[0], ast.Lambda):
            if _dotted(node.func) in ("jax.jit", "jit") \
                    or _dotted(node.func).endswith("lax.scan"):
                bodies.append(node.args[0])
    seen: Set[int] = set()
    for body in bodies:
        for node in ast.walk(body):
            if not isinstance(node, ast.Call) or node.lineno in seen:
                continue
            callee = _dotted(node.func)
            hit = callee in _HOST_SYNC_CALLS or (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "block_until_ready")
            if hit and not _pragma_allows(lines, node.lineno, "CP001"):
                seen.add(node.lineno)
                label = callee or ".block_until_ready"
                out.append(Finding(
                    "CP001", path, node.lineno,
                    f"host sync `{label}` inside jitted/traced body — it "
                    f"bakes a host constant (or fails) at trace time"))
    return out


# ---------------------------------------------------------------------------
# CP002 — sampling outside runtime/sampler.py
# ---------------------------------------------------------------------------

_SAMPLING_CALLS = {"jnp.argmax", "jax.numpy.argmax", "jax.random.categorical"}


def _check_sampling(tree: ast.AST, path: str, lines: Sequence[str]
                    ) -> List[Finding]:
    if path.replace("\\", "/").endswith("runtime/sampler.py"):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) \
                and _dotted(node.func) in _SAMPLING_CALLS \
                and not _pragma_allows(lines, node.lineno, "CP002"):
            out.append(Finding(
                "CP002", path, node.lineno,
                f"`{_dotted(node.func)}` outside runtime/sampler.py — "
                f"route token selection through runtime.sampler.sample()"))
    return out


# ---------------------------------------------------------------------------
# CP003 — counter mutations must sit next to their hook call
# ---------------------------------------------------------------------------

#: per accounting module: self.<counter> mutation -> required hook name
_COUNTER_HOOKS: Dict[str, Dict[str, str]] = {
    "core/virtualizer.py": {
        "swap_out_pages": "kv_swap_out",
        "swap_in_pages": "kv_swap_in",
        "resizes": "kv_resize",
    },
    "core/weight_pool.py": {
        "activations": "arena_activate",
        "evictions": "arena_evict",
        "layer_uploads": "arena_upload",
        "resizes": "arena_resize",
    },
    "core/prefix_cache.py": {
        "evicted_pages": "cache_evict",
        "shed_pages": "cache_evict",
        "faulted_pages": "cache_fault",
        "hits": "cache_hit",
        "misses": "cache_miss",
    },
}

#: method-call mutations (not counter attributes) -> required hook name
_CALL_HOOKS: Dict[str, Dict[str, str]] = {
    "core/admission.py": {"stats.bump": "admission"},
    "core/elastic.py": {"events.append": "rebalance"},
}


def _self_attr_target(node: ast.AST) -> str:
    """'stats.bump' for ``self.stats.bump`` / 'resizes' for
    ``self.resizes`` ('' when the chain is not rooted at ``self``)."""
    dotted = _dotted(node)
    if dotted.startswith("self."):
        return dotted[len("self."):]
    return ""


def _check_hook_adjacency(tree: ast.AST, path: str, lines: Sequence[str]
                          ) -> List[Finding]:
    norm = path.replace("\\", "/")
    counter_map = next((m for suffix, m in _COUNTER_HOOKS.items()
                        if norm.endswith(suffix)), None)
    call_map = next((m for suffix, m in _CALL_HOOKS.items()
                     if norm.endswith(suffix)), None)
    if counter_map is None and call_map is None:
        return []
    out: List[Finding] = []
    for fn in _walk_funcs(tree):
        if isinstance(fn, ast.Lambda):
            continue
        hooks_called: Set[str] = set()
        mutations: List[Tuple[int, str, str]] = []   # (line, what, hook)
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                callee = _dotted(node.func)
                if ".hooks." in callee or callee.startswith("hooks."):
                    hooks_called.add(callee.rsplit(".", 1)[-1])
                if call_map is not None:
                    tgt = _self_attr_target(node.func)
                    if tgt in call_map:
                        mutations.append(
                            (node.lineno, f"self.{tgt}(...)", call_map[tgt]))
            if counter_map is not None and isinstance(node, ast.AugAssign):
                tgt = _self_attr_target(node.target)
                # only increments count as "the event happened" —
                # decrements are bookkeeping inside another event
                if tgt in counter_map and isinstance(node.op, ast.Add):
                    mutations.append(
                        (node.lineno, f"self.{tgt} +=", counter_map[tgt]))
        for lineno, what, hook in mutations:
            if hook in hooks_called:
                continue
            if _pragma_allows(lines, lineno, "CP003"):
                continue
            out.append(Finding(
                "CP003", path, lineno,
                f"`{what}` without an adjacent `hooks.{hook}(...)` call in "
                f"the same function (counter/hook one-for-one, "
                f"DESIGN.md §10)"))
    return out


# ---------------------------------------------------------------------------
# CP004 — deprecated loose-kwarg engine construction
# ---------------------------------------------------------------------------

_ENGINE_NAMES = {"CrossPoolEngine", "ServingSession"}
_LOOSE_KWARGS = {"mode", "elastic"}


def _check_engine_ctor(tree: ast.AST, path: str, lines: Sequence[str]
                       ) -> List[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func).rsplit(".", 1)[-1]
        if name not in _ENGINE_NAMES:
            continue
        loose = sorted(k.arg for k in node.keywords
                       if k.arg in _LOOSE_KWARGS)
        if loose and not _pragma_allows(lines, node.lineno, "CP004"):
            out.append(Finding(
                "CP004", path, node.lineno,
                f"{name}({', '.join(k + '=...' for k in loose)}) is the "
                f"deprecated loose-kwarg surface — pass "
                f"config=EngineConfig(...)"))
    return out


# ---------------------------------------------------------------------------
# CP005 — ad-hoc percentiles
# ---------------------------------------------------------------------------

_PERCENTILE_CALLS = {"np.percentile", "np.quantile", "numpy.percentile",
                     "numpy.quantile", "jnp.percentile", "jnp.quantile",
                     "statistics.quantiles"}
_PERCENTILE_EXEMPT = ("benchmarks/_stats.py", "runtime/observe.py")


def _check_percentile(tree: ast.AST, path: str, lines: Sequence[str]
                      ) -> List[Finding]:
    norm = path.replace("\\", "/")
    if any(norm.endswith(s) for s in _PERCENTILE_EXEMPT):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) \
                and _dotted(node.func) in _PERCENTILE_CALLS \
                and not _pragma_allows(lines, node.lineno, "CP005"):
            out.append(Finding(
                "CP005", path, node.lineno,
                f"`{_dotted(node.func)}` outside the canonical quantile "
                f"modules — use runtime.observe.percentile (or "
                f"benchmarks._stats)"))
    return out


# ---------------------------------------------------------------------------
# CP006 — wall clock in engine latency paths
# ---------------------------------------------------------------------------

_WALL_CLOCK_CALLS = {"time.time", "time.perf_counter", "time.monotonic",
                     "time.process_time", "datetime.now", "datetime.utcnow"}
_CLOCK_SCOPED = ("runtime/engine.py", "runtime/session.py")


def _clock_in_scope(norm: str) -> bool:
    return any(norm.endswith(s) for s in _CLOCK_SCOPED) \
        or "/core/" in norm or norm.startswith("core/")


def _check_wall_clock(tree: ast.AST, path: str, lines: Sequence[str]
                      ) -> List[Finding]:
    norm = path.replace("\\", "/")
    if not _clock_in_scope(norm):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) \
                and _dotted(node.func) in _WALL_CLOCK_CALLS \
                and not _pragma_allows(lines, node.lineno, "CP006"):
            out.append(Finding(
                "CP006", path, node.lineno,
                f"`{_dotted(node.func)}()` in an engine latency path — "
                f"engine time is virtual (`now`); pragma real "
                f"dispatch-duration sites explicitly"))
    return out


# ---------------------------------------------------------------------------
# CP007 — bare asserts in pool-accounting modules
# ---------------------------------------------------------------------------

_ASSERT_SCOPED = ("core/virtualizer.py", "core/weight_pool.py",
                  "core/prefix_cache.py")


def _check_bare_assert(tree: ast.AST, path: str, lines: Sequence[str]
                       ) -> List[Finding]:
    norm = path.replace("\\", "/")
    if not any(norm.endswith(s) for s in _ASSERT_SCOPED):
        return []
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert) \
                and not _pragma_allows(lines, node.lineno, "CP007"):
            out.append(Finding(
                "CP007", path, node.lineno,
                "bare `assert` in a pool-accounting module vanishes under "
                "`python -O` — raise core.errors.PoolAccountingError "
                "(via core.errors.check)"))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

_CHECKS = (_check_host_sync, _check_sampling, _check_hook_adjacency,
           _check_engine_ctor, _check_percentile, _check_wall_clock,
           _check_bare_assert)


def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    """Lint one source string as if it lived at ``path`` (rules are
    path-scoped, so tests pass repo-shaped fake paths)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("CP000", path, e.lineno or 0,
                        f"syntax error: {e.msg}")]
    lines = source.splitlines()
    out: List[Finding] = []
    for chk in _CHECKS:
        out.extend(chk(tree, path, lines))
    return sorted(out, key=lambda f: (f.path, f.line, f.rule))


def lint_file(path: Path, root: Optional[Path] = None) -> List[Finding]:
    rel = str(path.relative_to(root)) if root else str(path)
    return lint_source(path.read_text(encoding="utf-8"), rel)


def _iter_py(paths: Iterable[Path]):
    for p in paths:
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def lint_paths(paths: Sequence[Path], root: Optional[Path] = None
               ) -> List[Finding]:
    out: List[Finding] = []
    for f in _iter_py(paths):
        out.extend(lint_file(f, root))
    return out


def default_roots(repo) -> List[Path]:
    """The gated trees: library + benchmarks + examples (NOT tests)."""
    repo = Path(repo)
    return [p for p in (repo / "src" / "repro", repo / "benchmarks",
                        repo / "examples") if p.exists()]


def _find_repo_root() -> Path:
    here = Path(__file__).resolve()
    for parent in here.parents:
        if (parent / "src" / "repro").is_dir():
            return parent
    return Path.cwd()


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="CrossPool invariant lint (rules CP001..CP007)")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files/dirs to lint (default: src/repro, "
                         "benchmarks, examples)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rid, desc in sorted(RULES.items()):
            print(f"{rid}  {desc}")
        return 0
    repo = _find_repo_root()
    paths = args.paths or default_roots(repo)
    findings = lint_paths(paths, root=repo if not args.paths else None)
    for f in findings:
        print(f)
    n = len(findings)
    print(f"repro.analysis.lint: {n} finding{'s' if n != 1 else ''} "
          f"across {len(list(_iter_py(paths)))} files")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
