"""CrossPool serving engine: colocated multi-model decode over the pools.

End-to-end path (paper §3/§4, decode-side):

  arrivals -> AdmissionController (planner budget, queue-or-reject)
           -> prefill (bucketed); prompt KV is scattered into the SHARED
              paged pool pages mapped by the admission-time
              ``register_request``
           -> decode loop, reading/writing KV through the pool:
                lowering=fused : one compiled paged step per model per
                                 token ("persistent kernel" analogue,
                                 ``PagedFusedStep``)
                lowering=host  : per-layer attention/FFN dispatches across
                                 the disaggregated pools
                pipeline=True  : two models' batches kept in flight so
                                 attention and FFN overlap (paper Fig. 4)
           -> sampling, TBT bookkeeping
           -> release slot + pages, drain admission queue.

The virtualizer's device page pool is the SINGLE source of KV truth for
every dense/moe/vlm model: total device KV bytes are fixed by
``page_budget`` alone, independent of how many models are colocated.
Families outside split execution (SSM/hybrid/enc-dec/SWA) fall back to a
fused dense-cache path; their pool pages are accounting-only.

Since PR 2 the weights side is symmetric: FFN/MoE weights live in ONE
shared slab arena (``repro.core.weight_pool.WeightArena``) whose device
bytes are fixed by ``slot_budget`` alone.  A cold model is ACTIVATED into
the arena when its first request reaches a batch slot (evicting idle
models LRU under pressure), pinned while it has in-flight requests, and
unpinned as they finish.

PREFILL runs through the arena too (PR 3): there is no per-model
device-resident param tree at all — ``ModelRunner`` keeps only batch-slot
state, prompt-phase FFN gathers the same ``(arena, slot_table)`` slabs as
decode (``control.StreamingPrefill``), and activation maps slots WITHOUT
uploading: each layer's slabs stream in behind the previous layer's
prefill attention, so a cold model's first token overlaps its own weight
upload in BOTH lowering modes.  In host-driven pipeline mode, concurrent
cold prefills additionally interleave through the layer-wise scheduler.
Admission is arena-aware: a cold-model request whose slabs are not
reachable without revoking another admitted model's weights queues at the
front door instead of thrashing the LRU.

Engine-scale model set = the paper's colocation trio at smoke scale; the
production-mesh behaviour of the same code paths is proven by the dry-run.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.admission import (AdmissionController, AdmissionStats,
                                  PendingRequest)
from repro.core.control import (HostDrivenStep, PagedFusedStep,
                                StreamingPrefill)
from repro.core.pipeline import InflightBatch, LayerPipelineScheduler
from repro.core import split_exec
from repro.core.pools import build_pools
from repro.core.virtualizer import (DEFAULT_PAGE_BYTES, KVVirtualizer,
                                    OutOfPagesError)
from repro.core.weight_pool import DEFAULT_SLAB_BYTES, OutOfSlabsError
from repro.models import build_model
from repro.runtime.request import Phase, Request
from repro.runtime.sampler import sample

_BUCKETS = (16, 32, 64, 128, 256, 512)


def _bucket(n: int, max_ctx: int) -> int:
    for b in _BUCKETS:
        if n <= b and b <= max_ctx:
            return b
    return max_ctx


@dataclass
class EngineMode:
    pipeline: bool = True
    lowering: bool = True          # fused step vs host-driven per-layer


@dataclass
class EngineStats:
    tokens_out: int = 0
    wall_s: float = 0.0
    tbt: List[float] = field(default_factory=list)
    ttft: List[float] = field(default_factory=list)
    step_times: Dict[str, List[float]] = field(default_factory=dict)
    slow_steps: int = 0            # straggler-mitigation counter
    # live view of the admission controller's counters (global + per model)
    admission: Optional[AdmissionStats] = None
    # weights-arena counters (activations/evictions/uploads), set by run()
    weights_pool: Dict[str, float] = field(default_factory=dict)

    @property
    def throughput(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s else 0.0


class ModelRunner:
    """Per-model batch slots + compiled prefill/decode programs.

    ``paged=True`` (dense/moe/vlm): NO per-model KV allocation AND no
    per-model param tree — prefill streams prompt KV into the
    virtualizer's pool pages layer by layer while FFN weights are gathered
    from the shared arena (``prefill_step``); decode steps read and write
    through page tables.  ``params`` must be ``None``: the only full
    copies are the pooled kv_params (non-FFN) and the arena's packed host
    masters.  ``paged=False`` (fused fallback families): a contiguous
    per-model cache and a device-resident ``params`` tree as before.
    """

    def __init__(self, name: str, cfg: ModelConfig, params,
                 virt: KVVirtualizer, *, max_batch: int, max_ctx: int,
                 mode: EngineMode, pooled=None,
                 prefill_step: Optional[StreamingPrefill] = None):
        self.name = name
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_ctx = max_ctx
        self.mode = mode
        self.virt = virt
        self.pooled = pooled
        self.paged = pooled is not None and pooled.stage_fns is not None
        self.lengths = np.zeros(max_batch, np.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.next_tokens = np.zeros(max_batch, np.int32)

        if self.paged:
            assert params is None, \
                f"{name}: paged models must not hold a full param tree"
            assert prefill_step is not None
            self.params = None
            self.prefill_step = prefill_step
            self.view = virt.views[name]
            self.max_pages = max(
                1, math.ceil(max_ctx / self.view.tokens_per_page))
            self.fused: Optional[PagedFusedStep] = (
                PagedFusedStep(pooled, postprocess=sample)
                if mode.lowering else None)
        else:
            self.params = params
            mdl = build_model(cfg)
            self.cache = mdl.init_cache(max_batch, max_ctx)

            def _prefill_dense(params, tokens, cache, slot, true_len):
                one = jax.tree.map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
                    cache)
                logits, one = mdl.prefill(params, tokens, one,
                                          logit_index=true_len - 1)
                cache = jax.tree.map(
                    lambda c, o: jax.lax.dynamic_update_slice_in_dim(
                        c, o.astype(c.dtype), slot, axis=1),
                    cache, one)
                return logits, cache

            self._prefill = jax.jit(_prefill_dense)

            def _decode(params, tokens, cache, lengths):
                logits, cache = mdl.decode_step(params, tokens, cache, lengths)
                return sample(logits), cache

            self._decode = jax.jit(_decode, donate_argnums=(2,))

    # ------------------------------------------------------------------
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    @property
    def active(self) -> bool:
        return any(s is not None for s in self.slots)

    def _active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _prompt_ids_and_writer(self, req: Request, rng: np.random.Generator):
        """(prompt ids [bucket], write length, per-layer pool writer).

        Prompts longer than the bucket are truncated to it, exactly as the
        dense prefill's fixed-width cache slice did."""
        b = _bucket(req.prompt_tokens, self.max_ctx)
        ids = rng.integers(0, self.cfg.vocab_size, b).astype(np.int32)
        n_write = min(req.prompt_tokens, b)

        def writer(layer, layer_kv, pool):
            return self.virt.write_prompt_layer(
                pool, self.name, req.request_id, layer, layer_kv, n_write)

        return ids, n_write, writer

    def _commit_prefill(self, req: Request, logits: jax.Array) -> int:
        slot = self.free_slot()
        assert slot is not None
        tok = int(jnp.argmax(logits[0]))
        self.slots[slot] = req
        self.lengths[slot] = req.prompt_tokens
        self.next_tokens[slot] = tok
        req.phase = Phase.DECODE
        req.output_ids.append(tok)       # the prefill-sampled first token
        return slot

    def prefill_request(self, req: Request, rng: np.random.Generator) -> int:
        # check BEFORE any device work: a full batch must fail here, not
        # after the prompt KV has already been scattered into the pool
        assert self.free_slot() is not None
        if self.paged:
            ids, n_write, writer = self._prompt_ids_and_writer(req, rng)
            # streaming prompt phase: per-layer attention with the next
            # layer's arena slabs uploading behind it; prompt KV is
            # scattered into pool pages as each layer completes
            logits, self.virt.pool = self.prefill_step(
                jnp.asarray(ids[None, :]), n_write, self.virt.pool, writer)
        else:
            slot = self.free_slot()
            assert slot is not None
            b = _bucket(req.prompt_tokens, self.max_ctx)
            ids = rng.integers(0, self.cfg.vocab_size, b).astype(np.int32)
            logits, self.cache = self._prefill(
                self.params, jnp.asarray(ids[None, :]), self.cache,
                jnp.int32(slot), jnp.int32(req.prompt_tokens))
        return self._commit_prefill(req, logits)

    def make_prefill_batch(self, req: Request, rng: np.random.Generator,
                           batch_id: int) -> InflightBatch:
        """Package one request's prompt phase for the layer-wise scheduler
        (interleaves with other models' prefill/decode stages)."""
        ids, n_write, writer = self._prompt_ids_and_writer(req, rng)
        return InflightBatch(
            batch_id=batch_id, model=self.name,
            tokens=jnp.asarray(ids[None, :]), prefill=True,
            true_len=n_write, kv_writer=writer)

    def apply_prefill_result(self, batch: InflightBatch, req: Request) -> int:
        return self._commit_prefill(req, batch.logits)

    # ------------------------------------------------------------------
    # decode: issue (non-blocking dispatch) / commit (block + bookkeeping)
    # ------------------------------------------------------------------
    def _map_next_token(self) -> List[int]:
        """Extend every active request's mapping to cover the token this
        step writes (paged models map BEFORE the step).

        Atomic across the batch: the total page need is checked up front,
        so a pool exhausted mid-serve raises with NO per-request token
        drift (active pages are never revoked — paper §3.1; with the
        admission controller's output reservation this is unreachable
        unless the budget is under-planned).
        """
        act = self._active_slots()
        need = sum(self.virt.pages_needed_for_extend(
            self.slots[i].request_id, 1) for i in act)
        if need > self.virt.free_pages:
            raise OutOfPagesError(
                f"{self.name}: decode step needs {need} pages, "
                f"{self.virt.free_pages} free — raise page_budget or plan "
                f"with a higher quantile")
        for i in act:
            self.virt.extend_request(self.slots[i].request_id, 1)
        return act

    def prepare_step(self) -> Tuple[jax.Array, jax.Array, jax.Array, List[int]]:
        """(tokens, page_tables [L,B,P], lengths, active slots)."""
        act = self._map_next_token()
        rids = [s.request_id if s is not None else None for s in self.slots]
        tables = self.virt.batch_tables(self.name, rids, self.max_pages)
        return (jnp.asarray(self.next_tokens), tables,
                jnp.asarray(self.lengths), act)

    def issue_decode(self, host_step: Optional[HostDrivenStep] = None
                     ) -> Tuple[jax.Array, List[int]]:
        """Dispatch one decode step for all slots; returns (tokens, act)
        with the token array still lazy (not blocked on)."""
        if self.paged:
            tokens, tables, lengths, act = self.prepare_step()
            if host_step is not None:
                logits, pool = host_step(tokens, self.virt.pool, tables,
                                         lengths)
                toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                toks, pool = self.fused(tokens, self.virt.pool, tables,
                                        lengths)
            self.virt.pool = pool
            return toks, act
        act = self._active_slots()
        toks, self.cache = self._decode(
            self.params, jnp.asarray(self.next_tokens), self.cache,
            jnp.asarray(self.lengths))
        return toks, act

    def commit_decode(self, pending: Tuple[jax.Array, List[int]]
                      ) -> Tuple[np.ndarray, List[int]]:
        toks_dev, act = pending
        toks = np.asarray(jax.block_until_ready(toks_dev))
        for i in act:
            self.lengths[i] += 1
            self.next_tokens[i] = toks[i]
            if not self.paged:
                # fallback families: page accounting AFTER the step (their
                # KV lives in the dense cache; pages track budget only)
                self.virt.extend_request(self.slots[i].request_id, 1)
        return toks, act

    def decode_once(self, host_step: Optional[HostDrivenStep] = None
                    ) -> Tuple[np.ndarray, List[int]]:
        """One decode step for all active slots; returns (tokens, slots)."""
        return self.commit_decode(self.issue_decode(host_step))

    # ------------------------------------------------------------------
    def make_inflight_batch(self, batch_id: int) -> Tuple[InflightBatch, List[int]]:
        """Package this model's slots for the layer-wise scheduler."""
        tokens, tables, lengths, act = self.prepare_step()
        return InflightBatch(
            batch_id=batch_id, model=self.name, tokens=tokens,
            page_tables=tables, lengths=lengths), act

    def apply_pipeline_result(self, batch: InflightBatch, act: List[int]
                              ) -> Tuple[np.ndarray, List[int]]:
        """Write back an InflightBatch completed by the scheduler (KV is
        already in the pool; only token/length state lives here)."""
        toks = np.asarray(jnp.argmax(batch.logits, axis=-1).astype(jnp.int32))
        for i in act:
            self.lengths[i] += 1
            self.next_tokens[i] = toks[i]
        return toks, act

    def release(self, slot: int) -> Request:
        req = self.slots[slot]
        self.slots[slot] = None
        return req


class CrossPoolEngine:
    def __init__(self, models: Dict[str, ModelConfig], *,
                 page_budget: int, page_bytes: int = DEFAULT_PAGE_BYTES,
                 slot_budget: Optional[int] = None,
                 slab_bytes: int = DEFAULT_SLAB_BYTES,
                 max_batch: int = 4, max_ctx: int = 256,
                 mode: Optional[EngineMode] = None, seed: int = 0,
                 slow_step_factor: float = 4.0):
        self.models = models
        self.mode = mode or EngineMode()
        self.rng = np.random.default_rng(seed)
        devs = jax.devices()
        self.kv_device, self.w_device = devs[0], devs[-1]

        params = {n: build_model(c).init(jax.random.PRNGKey(i))
                  for i, (n, c) in enumerate(models.items())}
        # the pool dtype is the lowest common denominator of the colocated
        # models (heterogeneous models reinterpret the same untyped pages)
        pool_dtype = (jnp.float32
                      if any(c.dtype == "float32" for c in models.values())
                      else jnp.bfloat16)
        # a live device pool is only needed when some model decodes through
        # it; an all-fallback engine keeps host-side page accounting only
        any_split = any(split_exec.supports_split(c) for c in models.values())
        self.kv_pool, self.w_pool, self.pooled = build_pools(
            models, params, kv_device=self.kv_device, w_device=self.w_device,
            page_budget=page_budget, page_bytes=page_bytes,
            pool_dtype=pool_dtype, allocate_device_pool=any_split,
            slot_budget=slot_budget, slab_bytes=slab_bytes,
            # the fused step is ONE program with a single placement, so the
            # arena must be colocated with the KV pool when lowering is on;
            # host-driven mode keeps it in the weights pool, where FFN runs
            arena_device=(self.kv_device if self.mode.lowering
                          else self.w_device),
            # engine-managed activation: models become resident when their
            # first request reaches a batch slot (cold-model activation)
            activate_resident=False)
        self.virt = self.kv_pool.virtualizer
        self.arena = self.w_pool.arena if any_split else None
        # arena-aware admission: cold-model bursts queue at the front door
        # instead of thrashing the arena LRU between admitted models
        self.admission = AdmissionController(self.virt, arena=self.arena)

        self.host_steps = None
        self.scheduler = None
        if not self.mode.lowering:
            self.host_steps = {
                n: HostDrivenStep(self.pooled[n], self.kv_device,
                                  self.w_device)
                for n in models if self.pooled[n].stage_fns is not None
            }
            self.scheduler = LayerPipelineScheduler(
                self.pooled, self.kv_device, self.w_device,
                steps=self.host_steps)
        # streaming prompt-phase executors (per-layer transfers follow the
        # arena's placement: colocated with the KV pool under lowering=ON);
        # in host mode they SHARE the HostDrivenStep's jitted stage
        # programs — one trace/compile cache per model
        prefill_steps = {
            n: StreamingPrefill(
                self.pooled[n], kv_device=self.kv_device,
                w_device=self.w_pool.arena.device,
                share=None if self.host_steps is None
                else self.host_steps.get(n))
            for n in models if self.pooled[n].stage_fns is not None
        }
        # paged models hold NO full param tree: the init-time tree is split
        # into pooled kv_params + the arena's packed host masters, and the
        # full copy is dropped here (fallback families keep theirs)
        self.runners = {
            n: ModelRunner(
                n, c,
                None if n in prefill_steps else params[n], self.virt,
                max_batch=max_batch, max_ctx=max_ctx,
                mode=self.mode, pooled=self.pooled[n],
                prefill_step=prefill_steps.get(n))
            for n, c in models.items()
        }
        self.stats = EngineStats(step_times={n: [] for n in models},
                                 admission=self.admission.stats)

    # ------------------------------------------------------------------
    def _activate_model(self, name: str) -> None:
        """Map a cold model's slabs before its first prefill — WITHOUT
        uploading: the streaming prompt phase prefetches layer L+1's slabs
        behind layer L's attention in BOTH lowering modes, so by the first
        decode step every layer is resident and the fused step's
        ``acquire`` has zero upload work left.  The per-request PIN was
        already taken at ADMISSION (``AdmissionController.try_admit``) and
        is released by ``admission.finish`` — so LRU eviction (triggered
        by some OTHER model's activation under slab pressure) can never
        revoke weights an admitted request still needs, even in the
        window before this activation makes the model resident.
        """
        if self.arena is None or not self.runners[name].paged:
            return
        self.arena.activate(name, upload=False)

    # ------------------------------------------------------------------
    def _admit(self, req: Request, now: float) -> str:
        pending = PendingRequest(req.request_id, req.model,
                                 req.prompt_tokens, req.max_new_tokens, now)
        outcome = self.admission.offer(pending, now)
        if outcome == "rejected":
            req.phase = Phase.REJECTED
        return outcome

    def _finish(self, req: Request, now: float) -> None:
        req.phase = Phase.FINISHED
        req.finish_time = now
        self.virt.release_request(req.request_id)
        # drops the admission-time pin too: idle models become evictable
        self.admission.finish(req.model)

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *,
            max_steps: int = 10_000) -> EngineStats:
        """Serve a pre-generated trace to completion (or max_steps)."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        waiting: List[Request] = []       # admitted by controller, no slot yet
        by_id = {r.request_id: r for r in requests}
        now = 0.0
        steps = 0

        def admit_arrivals():
            nonlocal pending
            due = [r for r in pending if r.arrival_time <= now]
            pending = [r for r in pending if r.arrival_time > now]
            for r in due:
                if self._admit(r, now) == "admitted":
                    r.admit_time = now
                    waiting.append(r)
            for p in self.admission.drain(now):
                r = by_id[p.request_id]
                r.admit_time = now
                waiting.append(r)

        while (pending or waiting or self.admission.queued_count() or
               any(r.active for r in self.runners.values())):
            if steps >= max_steps:
                break
            steps += 1
            # jump virtual time to the next arrival if idle
            if not waiting and not any(r.active for r in self.runners.values()) \
                    and pending:
                now = max(now, pending[0].arrival_time)
            admit_arrivals()
            if (not waiting and not pending and
                    not any(r.active for r in self.runners.values())):
                # only queued requests remain and the pools are at rest:
                # nothing in flight can free pages/slabs, so drain() can
                # never make progress — exit instead of spinning to
                # max_steps (the queued requests stay unserved)
                break

            # --- prefill admitted requests into free slots ----------------
            still, ready = [], []
            for req in waiting:
                runner = self.runners[req.model]
                if runner.free_slot() is None or \
                        sum(1 for r in ready if r.model == req.model) >= \
                        sum(1 for s in runner.slots if s is None):
                    still.append(req)
                    continue
                try:
                    self._activate_model(req.model)
                except OutOfSlabsError:
                    # every resident model is pinned by in-flight
                    # requests; those pins drop as they finish, so the
                    # request stays waiting — UNLESS the model can
                    # never fit even an empty arena (budget error)
                    if self.arena.views[req.model].total_slabs \
                            > self.arena.slot_budget:
                        raise
                    still.append(req)
                    continue
                ready.append(req)
            waiting = still
            if ready:
                now = self._prefill_ready(ready, now)

            # --- decode: one step per active model ------------------------
            active = [n for n, r in self.runners.items() if r.active]
            if self.mode.pipeline and len(active) >= 2:
                now = self._decode_pipelined(active, now)
            else:
                for n in active:
                    now = self._decode_model(n, now)

            # --- completions ---------------------------------------------
            for n, runner in self.runners.items():
                for slot, req in enumerate(runner.slots):
                    if req is not None and req.done:
                        runner.release(slot)
                        self._finish(req, now)
        self.stats.wall_s = now
        for r in requests:
            self.stats.tbt.extend(r.tbt_samples())
        if self.arena is not None:
            self.stats.weights_pool = self.arena.utilization()
        return self.stats

    # ------------------------------------------------------------------
    def report(self) -> str:
        """Human-readable serving report: throughput, per-model admission
        outcomes, KV-pool and weights-arena utilization."""
        s = self.stats
        lines = [f"tokens={s.tokens_out} wall={s.wall_s:.2f}s "
                 f"throughput={s.throughput:.1f} tok/s "
                 f"slow_steps={s.slow_steps}"]
        adm = self.admission.stats
        lines.append(f"admission: admitted={adm.admitted} "
                     f"queued={adm.queued} rejected={adm.rejected}")
        for name in self.models:
            m = adm.per_model.get(name)
            if m is not None:
                lines.append(f"  {name}: admitted={m.admitted} "
                             f"queued={m.queued} rejected={m.rejected}")
        u = self.virt.utilization()
        lines.append(f"kv pool: peak {u['peak_mapped']}/"
                     f"{self.virt.page_budget} pages, "
                     f"frag {u['internal_frag_bytes'] / 1024:.1f} KiB")
        if self.arena is not None:
            w = self.arena.utilization()
            lines.append(
                f"weights arena: {w['resident_slabs']}/{w['slot_budget']} "
                f"slabs resident ({w['resident_models']} models), "
                f"{w['activations']} activations, {w['evictions']} "
                f"evictions, {w['layer_uploads']} layer uploads")
            lines.append(
                f"  device FFN bytes (prefill AND decode): "
                f"{w['device_bytes'] / 2 ** 20:.1f} MiB — slot_budget x "
                f"slab_bytes, no full-tree phase remains")
        return "\n".join(lines)

    # ------------------------------------------------------------------
    def _record_step(self, name: str, dt: float) -> None:
        log = self.stats.step_times[name]
        if len(log) > 8 and dt > np.median(log) * 4.0:
            self.stats.slow_steps += 1     # straggler flag
        log.append(dt)

    def _host_step(self, name: str) -> Optional[HostDrivenStep]:
        if self.host_steps is None:
            return None
        return self.host_steps.get(name)

    def _book_tokens(self, runner: ModelRunner, toks: np.ndarray,
                     act: List[int], now: float) -> None:
        for i in act:
            req = runner.slots[i]
            req.generated += 1
            req.output_ids.append(int(toks[i]))
            req.token_times.append(now)
            self.stats.tokens_out += 1

    def _book_first_token(self, req: Request, now: float) -> None:
        req.first_token_time = now
        req.token_times.append(now)
        req.generated += 1
        self.stats.tokens_out += 1
        self.stats.ttft.append(now - req.arrival_time)

    def _prefill_ready(self, ready: List[Request], now: float) -> float:
        """Prefill activated requests.  In host-driven pipeline mode,
        distinct models' prompt phases interleave through the layer-wise
        scheduler (model A's layer-L attention overlaps model B's FFN and
        each model's own layer-L+1 slab upload); everything else runs the
        sequential streaming path."""
        if self.scheduler is not None and self.mode.pipeline:
            group: Dict[str, Request] = {}
            rest: List[Request] = []
            for req in ready:
                if self.runners[req.model].paged and req.model not in group:
                    group[req.model] = req
                else:
                    rest.append(req)
            if len(group) >= 2:
                now = self._prefill_pipelined(list(group.values()), now)
                ready = rest
        for req in ready:
            runner = self.runners[req.model]
            t0 = time.perf_counter()
            runner.prefill_request(req, self.rng)
            now += time.perf_counter() - t0
            self._book_first_token(req, now)
        return now

    def _prefill_pipelined(self, reqs: List[Request], now: float) -> float:
        """Concurrent cold-model prompt phases through the scheduler."""
        t0 = time.perf_counter()
        batches = [self.runners[r.model].make_prefill_batch(r, self.rng, i)
                   for i, r in enumerate(reqs)]
        done, pool = self.scheduler.run(batches, self.virt.pool,
                                        max_inflight=2)
        self.virt.pool = pool
        now += time.perf_counter() - t0
        by_model = {r.model: r for r in reqs}
        for b in done:
            req = by_model[b.model]
            self.runners[b.model].apply_prefill_result(b, req)
            self._book_first_token(req, now)
        return now

    def _decode_model(self, name: str, now: float) -> float:
        runner = self.runners[name]
        t0 = time.perf_counter()
        toks, act = runner.decode_once(self._host_step(name))
        dt = time.perf_counter() - t0
        self._record_step(name, dt)
        now += dt
        self._book_tokens(runner, toks, act, now)
        return now

    def _decode_pipelined(self, active: List[str], now: float) -> float:
        """Two (or more) models stepped with overlapping execution.

        lowering=ON : every model's fused paged step is ISSUED before any
        is blocked on — async dispatch overlaps the programs (the shared
        pool buffer is threaded through the dispatch chain).
        lowering=OFF: the layer-wise pipeline scheduler interleaves the
        models' attention/FFN stages across the two pools (paper Fig. 4)."""
        if not self.mode.lowering:
            return self._decode_pipelined_host(active, now)
        t0 = time.perf_counter()
        issued = [(n, self.runners[n].issue_decode(None)) for n in active]
        dt_all = 0.0
        for n, pending in issued:
            runner = self.runners[n]
            toks, act = runner.commit_decode(pending)
            dt_all = time.perf_counter() - t0
            self._book_tokens(runner, toks, act, now + dt_all)
        for n in active:
            self._record_step(n, dt_all / len(active))
        return now + dt_all

    def _decode_pipelined_host(self, active: List[str], now: float) -> float:
        """Layer-wise two-batch pipeline over the disaggregated pools."""
        t0 = time.perf_counter()
        paged = [n for n in active if self.runners[n].paged]
        fallback = [n for n in active if not self.runners[n].paged]
        batches, acts = [], {}
        for i, n in enumerate(paged):
            batch, act = self.runners[n].make_inflight_batch(i)
            batches.append(batch)
            acts[n] = act
        done, pool = self.scheduler.run(batches, self.virt.pool,
                                        max_inflight=2)
        self.virt.pool = pool
        dt_all = time.perf_counter() - t0
        for b in done:
            runner = self.runners[b.model]
            toks, act = runner.apply_pipeline_result(b, acts[b.model])
            self._book_tokens(runner, toks, act, now + dt_all)
            self._record_step(b.model, dt_all / max(len(paged), 1))
        now += dt_all
        for n in fallback:          # families outside split execution
            now = self._decode_model(n, now)
        return now
