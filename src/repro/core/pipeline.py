"""Layer-wise pipeline scheduler: two in-flight batches across two pools.

Paper §3.2 / Fig. 4: the scheduler holds up to two in-flight batches, each
with its own model id, layer cursor and completion state.  While batch B1
runs attention for a layer in the KV-cache pool, B2's previous-layer hidden
states are processed by FFN in the weights pool.  There is NO global layer
barrier: batches may come from different models with different layer
counts; when one finishes, its tokens are published, its slot is released
and refilled from the request queues (early exit + refill).

All batches read and write KV through ONE shared paged pool (the
virtualizer's device array): each :class:`InflightBatch` carries only its
page tables and lengths, and the scheduler threads the pool buffer through
every attention stage — batches touch disjoint pages, so interleaving
order cannot corrupt KV state.  FFN weights come from the ONE shared
weights arena the same way (models own disjoint slabs), and the scheduler
extends the paper's transfer hiding from hidden states to weights: while
batch B's layer-L attention is in flight in the KV pool, layer L+1's
weight slabs are prefetched into the arena (``WeightArena
.prefetch_layer``), so cold-model upload traffic hides behind compute.

Since the prefill-through-arena change the scheduler also takes PREFILL
batches (``InflightBatch(prefill=True)``): full-sequence attention per
layer, each layer's prompt KV scattered into the shared pool via the
batch's ``kv_writer``, FFN through the same arena gather — so a cold
model's prompt phase interleaves with other models' decode stages and its
own streaming weight uploads (DESIGN.md §6).

The scheduler always advances ONE token per decode batch pass — the
multi-step K-tokens-per-dispatch path (DESIGN.md §9) lives in the fused
lowering (``control.MultiStepFusedStep``), which replaces per-layer
interleaving with a single device-resident program; the two are
alternative lowerings of the same engine step, never composed.

Execution is asynchronous: every stage issue returns a lazy jax value, so
stages bound to the two pool devices genuinely overlap; the scheduler's job
is to *issue* stages in an order that keeps both pools busy.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import jax

from repro.core.control import HostDrivenStep, logit_index
from repro.core.pools import PooledModel, transfer


@dataclass
class InflightBatch:
    """One batch's layer-granular execution state (the paper's state machine:
    model id, layer cursor, completion).  KV lives in the shared pool; the
    batch owns only its page-table view of it.

    ``prefill=True`` runs the batch through the prompt-phase stage programs
    instead: ``tokens`` is ``[B, S]`` prompt ids, ``page_tables``/``lengths``
    are unused (full-sequence attention attends over the prompt itself),
    each layer's KV is handed to ``kv_writer(layer, layer_kv, pool) ->
    pool`` for scattering into the shared pool, and ``logits`` is the
    unpadded last position (``true_len - 1``).  Prefill and decode batches
    interleave freely: a cold model's prefill attention overlaps another
    model's FFN AND its own next layer's slab upload."""

    batch_id: int
    model: str
    tokens: jax.Array                 # [B] next-token ids ([B,S] prefill)
    page_tables: Optional[jax.Array] = None   # [L, B, max_pages] int32
    lengths: Optional[jax.Array] = None       # [B] current context lengths
    layer: int = 0                    # layer cursor
    phase: str = "embed"              # embed -> attn -> ffn -> combine -> done
    x: Optional[jax.Array] = None     # residual stream
    ffn_in: Optional[jax.Array] = None
    ffn_out: Optional[jax.Array] = None
    logits: Optional[jax.Array] = None
    # prompt-phase extras
    prefill: bool = False
    # unpadded prompt length: host int, or a length-B sequence when the
    # batch coalesces several same-model prompts into one [B,S] pass
    true_len: object = 0
    kv_writer: Optional[Callable] = None

    @property
    def done(self) -> bool:
        return self.phase == "done"


class LayerPipelineScheduler:
    """Interleaves attention and FFN stages of two in-flight batches."""

    def __init__(self, pooled: Dict[str, PooledModel], kv_device, w_device,
                 steps: Optional[Dict[str, HostDrivenStep]] = None):
        self.pooled = pooled
        self.kv_device = kv_device
        self.w_device = w_device
        self.steps: Dict[str, HostDrivenStep] = steps or {
            name: HostDrivenStep(pm, kv_device, w_device)
            for name, pm in pooled.items()
            if pm.stage_fns is not None
        }
        # the ONE shared weights arena (every pooled model carries the
        # same object); None only for accounting-only pool builds
        self.arena = next(
            (pm.arena for pm in pooled.values() if pm.arena is not None),
            None)
        self.stage_log: List[Tuple[int, str, str, int]] = []  # (batch,model,stage,layer)

    # ------------------------------------------------------------------
    def _advance(self, b: InflightBatch, pool: jax.Array) -> jax.Array:
        """Issue exactly one stage of one batch (non-blocking).

        Returns the (possibly updated) shared pool."""
        step = self.steps[b.model]
        fns = self.pooled[b.model].stage_fns
        p_kv = self.pooled[b.model].kv_params
        arena = self.arena
        if b.phase == "embed":
            # map the model's slabs (upload streams in layer by layer);
            # layer 0 is pulled eagerly so the first FFN never stalls
            arena.activate(b.model, upload=False)
            arena.prefetch_layer(b.model, 0)
            b.x = (step._pembed if b.prefill else step._embed)(p_kv, b.tokens)
            b.phase = "attn"
        elif b.phase == "attn":
            if b.prefill:
                b.x, ffn_in, layer_kv = step._pattn(p_kv, b.x, b.layer)
                if b.kv_writer is not None:     # prompt KV -> shared pool
                    pool = b.kv_writer(b.layer, layer_kv, pool)
            else:
                b.x, ffn_in, pool = step._attn(
                    p_kv, b.x, pool, b.page_tables, b.lengths, b.layer)
            # transfer hiding, weights edition: issue layer L+1's slab
            # upload while layer L's attention is in flight
            arena.prefetch_layer(b.model, b.layer + 1)
            b.ffn_in = transfer(ffn_in, self.w_device)       # A-to-F
            self.stage_log.append((b.batch_id, b.model, "attn", b.layer))
            b.phase = "ffn"
        elif b.phase == "ffn":
            arena.prefetch_layer(b.model, b.layer)   # no-op once prefetched
            out = step._ffn(arena.arena, arena.slot_table(b.model),
                            b.ffn_in, b.layer)
            b.ffn_out = transfer(out, self.kv_device)        # F-to-A
            self.stage_log.append((b.batch_id, b.model, "ffn", b.layer))
            b.phase = "combine"
        elif b.phase == "combine":
            b.x = step._combine(b.x, b.ffn_out)
            b.layer += 1
            if b.layer >= fns.n_layers:
                b.logits = (step._plogits(p_kv, b.x,
                                          logit_index(b.true_len))
                            if b.prefill else step._logits(p_kv, b.x))
                b.phase = "done"                              # early exit
            else:
                b.phase = "attn"
        return pool

    # ------------------------------------------------------------------
    def run(self, batches: List[InflightBatch], pool: jax.Array, *,
            refill: Optional[Callable[[], Optional[InflightBatch]]] = None,
            max_inflight: int = 2
            ) -> Tuple[List[InflightBatch], jax.Array]:
        """Drive batches to completion, keeping ``max_inflight`` slots busy.

        ``pool`` is the shared physical KV pool; it is threaded through
        every attention stage and the final buffer is returned alongside
        the completed batches.  ``refill`` is polled whenever a slot frees
        (the paper's fetch from the per-model request queue).  Returns
        (completed batches in completion order, updated pool).
        """
        queue = list(batches)
        slots: List[Optional[InflightBatch]] = [None] * max_inflight
        finished: List[InflightBatch] = []

        def fill(i):
            if queue:
                slots[i] = queue.pop(0)
            elif refill is not None:
                slots[i] = refill()
            else:
                slots[i] = None

        for i in range(max_inflight):
            fill(i)

        # round-robin issue: one stage per live slot per cycle, so batch A's
        # FFN (weights pool) is issued right after batch B's attention
        # (KV pool) — the two devices' queues stay jointly populated.
        while any(s is not None for s in slots):
            for i, s in enumerate(slots):
                if s is None:
                    continue
                pool = self._advance(s, pool)
                if s.done:
                    finished.append(s)
                    fill(i)
        return finished, pool

    # ------------------------------------------------------------------
    def run_serial(self, batches: List[InflightBatch], pool: jax.Array
                   ) -> Tuple[List[InflightBatch], jax.Array]:
        """Pipeline OFF baseline: one batch at a time, stages still split
        across the two pools (transfers exposed)."""
        return self.run(batches, pool, max_inflight=1)

    def overlap_fraction(self) -> float:
        """Fraction of adjacent issued stages that alternate pools — a
        proxy for how much attention/FFN overlap the schedule exposes."""
        if len(self.stage_log) < 2:
            return 0.0
        alt = sum(1 for a, b in zip(self.stage_log, self.stage_log[1:])
                  if a[2] != b[2])
        return alt / (len(self.stage_log) - 1)
