"""Pallas TPU Mamba2 SSD chunked scan.

Implements the state-space-duality chunk decomposition (arXiv:2405.21060 §6)
with the chunk dimension as the innermost sequential grid axis, carrying the
recurrent state ``h [bh, P, N]`` in f32 VMEM scratch across chunks:

  intra:  Y[t] += sum_{s<=t} (C_t.B_s) exp(La_t - La_s) dt_s x_s   (quadratic
          within the chunk -> MXU matmuls)
  state:  h <- exp(La_L) h + sum_s exp(La_L - La_s) dt_s (B_s (x) x_s)
  inter:  Y[t] += C_t . (exp(La_t) h_prev)

Grid ``(batch, head_blocks, chunks)``.  B/C group projections are expanded
to per-head upstream in the wrapper (cheap: N is small) so the kernel blocks
stay rectangular.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, h0_ref,
                y_ref, hout_ref, h_ref, *, chunk: int):
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)           # [bh,P,N]

    x = x_ref[0].astype(jnp.float32)                         # [L,bh,P]
    dt = dt_ref[0].astype(jnp.float32)                       # [L,bh]
    A = A_ref[...].astype(jnp.float32)                       # [bh]
    Bm = B_ref[0].astype(jnp.float32)                        # [L,bh,N]
    Cm = C_ref[0].astype(jnp.float32)                        # [L,bh,N]

    a = dt * A[None, :]                                      # [L,bh] log decay
    La = jnp.cumsum(a, axis=0)
    La_tot = La[-1]                                          # [bh]

    # --- intra-chunk (quadratic in L) -----------------------------------
    diff = La[:, None, :] - La[None, :, :]                   # [L,S,bh]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    decay = jnp.where(tri[..., None], jnp.exp(diff), 0.0)    # [L,S,bh]
    scores = jnp.einsum("lhn,shn->lsh", Cm, Bm) * decay
    y = jnp.einsum("lsh,sh,shp->lhp", scores, dt, x)

    # --- inter-chunk from carried state ----------------------------------
    h = h_ref[...]                                           # [bh,P,N]
    y += jnp.einsum("lhn,hpn->lhp", Cm * jnp.exp(La)[..., None], h)
    y_ref[0] = y.astype(y_ref.dtype)

    # --- state update ------------------------------------------------------
    decay_to_end = jnp.exp(La_tot[None, :] - La)             # [L,bh]
    S_c = jnp.einsum("sh,shn,shp->hpn", dt * decay_to_end, Bm, x)
    h_ref[...] = h * jnp.exp(La_tot)[:, None, None] + S_c

    @pl.when(c == nc - 1)
    def _emit():
        hout_ref[0] = h_ref[...]


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
             C_: jax.Array, *, chunk: int = 64,
             h0: Optional[jax.Array] = None, block_h: int = 8,
             interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Same contract as ``ref.ssd_scan``.

    x: [B,S,H,P]; dt: [B,S,H]; A: [H]; B_/C_: [B,S,G,N]; h0: [B,H,P,N].
    S must be divisible by ``chunk``; H by ``block_h`` (or block_h clamps).
    """
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    assert S % chunk == 0, f"seq {S} % chunk {chunk} != 0"
    nc = S // chunk
    block_h = min(block_h, H)
    while H % block_h:
        block_h -= 1
    nh = H // block_h

    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=2)                         # [B,S,H,N]
    Ch = jnp.repeat(C_, rep, axis=2)
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(Bb, nh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_h, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, block_h), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((block_h,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, block_h, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, block_h, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, block_h, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_h, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, block_h, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_h, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bh, Ch, h0)
    return y, h_final
