"""CrossPool serving engine: colocated multi-model decode over the pools.

End-to-end path (paper §3/§4, decode-side):

  arrivals -> AdmissionController (planner budget, queue-or-reject)
           -> prefill into a batch slot (bucketed, KV pages mapped)
           -> decode loop:
                lowering=fused : one compiled step per model per token
                                 ("persistent kernel" analogue)
                lowering=host  : per-layer attention/FFN dispatches across
                                 the disaggregated pools
                pipeline=True  : two models' batches kept in flight so
                                 attention and FFN overlap (paper Fig. 4)
           -> sampling, virtualizer page extension, TBT bookkeeping
           -> release slot + pages, drain admission queue.

Engine-scale model set = the paper's colocation trio at smoke scale; the
production-mesh behaviour of the same code paths is proven by the dry-run.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.admission import AdmissionController, PendingRequest
from repro.core.control import FusedStep, HostDrivenStep
from repro.core.pipeline import InflightBatch, LayerPipelineScheduler
from repro.core.pools import build_pools
from repro.core.virtualizer import KVVirtualizer, OutOfPagesError
from repro.models import build_model
from repro.runtime.request import Phase, Request
from repro.runtime.sampler import sample

_BUCKETS = (16, 32, 64, 128, 256, 512)


def _bucket(n: int, max_ctx: int) -> int:
    for b in _BUCKETS:
        if n <= b and b <= max_ctx:
            return b
    return max_ctx


@dataclass
class EngineMode:
    pipeline: bool = True
    lowering: bool = True          # fused step vs host-driven per-layer


@dataclass
class EngineStats:
    tokens_out: int = 0
    wall_s: float = 0.0
    tbt: List[float] = field(default_factory=list)
    ttft: List[float] = field(default_factory=list)
    step_times: Dict[str, List[float]] = field(default_factory=dict)
    slow_steps: int = 0            # straggler-mitigation counter

    @property
    def throughput(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s else 0.0


class ModelRunner:
    """Per-model batch slots + compiled prefill/decode programs."""

    def __init__(self, name: str, cfg: ModelConfig, params,
                 kv_device, w_device, *, max_batch: int, max_ctx: int,
                 mode: EngineMode, pooled=None):
        self.name = name
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_batch = max_batch
        self.max_ctx = max_ctx
        self.mode = mode
        self.params = params
        self.cache = self.model.init_cache(max_batch, max_ctx)
        self.lengths = np.zeros(max_batch, np.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.next_tokens = np.zeros(max_batch, np.int32)
        self.pooled = pooled

        mdl = self.model

        def _prefill(params, tokens, cache, slot, true_len):
            one = jax.tree.map(
                lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
                cache)
            logits, one = mdl.prefill(params, tokens, one,
                                      logit_index=true_len - 1)
            cache = jax.tree.map(
                lambda c, o: jax.lax.dynamic_update_slice_in_dim(
                    c, o.astype(c.dtype), slot, axis=1),
                cache, one)
            return logits, cache

        self._prefill = jax.jit(_prefill)

        def _decode(params, tokens, cache, lengths):
            logits, cache = mdl.decode_step(params, tokens, cache, lengths)
            return sample(logits), cache

        self._decode = jax.jit(_decode, donate_argnums=(2,))

    # ------------------------------------------------------------------
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    @property
    def active(self) -> bool:
        return any(s is not None for s in self.slots)

    def prefill_request(self, req: Request, rng: np.random.Generator) -> int:
        slot = self.free_slot()
        assert slot is not None
        b = _bucket(req.prompt_tokens, self.max_ctx)
        ids = rng.integers(0, self.cfg.vocab_size, b).astype(np.int32)
        logits, self.cache = self._prefill(
            self.params, jnp.asarray(ids[None, :]), self.cache,
            jnp.int32(slot), jnp.int32(req.prompt_tokens))
        tok = int(jnp.argmax(logits[0]))
        self.slots[slot] = req
        self.lengths[slot] = req.prompt_tokens
        self.next_tokens[slot] = tok
        req.phase = Phase.DECODE
        req.output_ids.append(tok)       # the prefill-sampled first token
        return slot

    def cache_keys(self) -> Tuple[str, str]:
        return ("k", "v") if "k" in self.cache else ("latent", "rope")

    def decode_once(self, host_step=None) -> Tuple[np.ndarray, List[int]]:
        """One decode step for all active slots; returns (tokens, slots).

        ``host_step``: optional HostDrivenStep — the lowering-OFF path with
        per-layer dispatches across the disaggregated pools."""
        if host_step is None:
            toks, self.cache = self._decode(
                self.params, jnp.asarray(self.next_tokens), self.cache,
                jnp.asarray(self.lengths))
        else:
            ka, kb = self.cache_keys()
            logits, ck, cv = host_step(jnp.asarray(self.next_tokens),
                                       self.cache[ka], self.cache[kb],
                                       jnp.asarray(self.lengths))
            self.cache[ka], self.cache[kb] = ck, cv
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks = np.asarray(jax.block_until_ready(toks))
        act = [i for i, s in enumerate(self.slots) if s is not None]
        for i in act:
            self.lengths[i] += 1
            self.next_tokens[i] = toks[i]
        return toks, act

    def apply_pipeline_result(self, batch) -> Tuple[np.ndarray, List[int]]:
        """Write back an InflightBatch completed by the scheduler."""
        ka, kb = self.cache_keys()
        self.cache[ka], self.cache[kb] = batch.cache_k, batch.cache_v
        toks = np.asarray(jnp.argmax(batch.logits, axis=-1).astype(jnp.int32))
        act = [i for i, s in enumerate(self.slots) if s is not None]
        for i in act:
            self.lengths[i] += 1
            self.next_tokens[i] = toks[i]
        return toks, act

    def release(self, slot: int) -> Request:
        req = self.slots[slot]
        self.slots[slot] = None
        return req


class CrossPoolEngine:
    def __init__(self, models: Dict[str, ModelConfig], *,
                 page_budget: int, page_bytes: int = 4096,
                 max_batch: int = 4, max_ctx: int = 256,
                 mode: Optional[EngineMode] = None, seed: int = 0,
                 slow_step_factor: float = 4.0):
        self.models = models
        self.mode = mode or EngineMode()
        self.rng = np.random.default_rng(seed)
        devs = jax.devices()
        self.kv_device, self.w_device = devs[0], devs[-1]

        params = {n: build_model(c).init(jax.random.PRNGKey(i))
                  for i, (n, c) in enumerate(models.items())}
        self.kv_pool, self.w_pool, self.pooled = build_pools(
            models, params, kv_device=self.kv_device, w_device=self.w_device,
            page_budget=page_budget, page_bytes=page_bytes,
            allocate_device_pool=False)
        self.virt = self.kv_pool.virtualizer
        self.admission = AdmissionController(self.virt)

        self.runners = {
            n: ModelRunner(n, c, params[n], self.kv_device, self.w_device,
                           max_batch=max_batch, max_ctx=max_ctx,
                           mode=self.mode, pooled=self.pooled[n])
            for n, c in models.items()
        }
        self.host_steps = None
        self.scheduler = None
        if not self.mode.lowering:
            self.host_steps = {
                n: HostDrivenStep(self.pooled[n], self.kv_device,
                                  self.w_device)
                for n in models
            }
            self.scheduler = LayerPipelineScheduler(
                self.pooled, self.kv_device, self.w_device,
                steps=self.host_steps)
        self.stats = EngineStats(step_times={n: [] for n in models})

    # ------------------------------------------------------------------
    def _admit(self, req: Request, now: float) -> str:
        pending = PendingRequest(req.request_id, req.model,
                                 req.prompt_tokens, req.max_new_tokens, now)
        outcome = self.admission.offer(pending, now)
        if outcome == "rejected":
            req.phase = Phase.REJECTED
        return outcome

    def _finish(self, req: Request, now: float) -> None:
        req.phase = Phase.FINISHED
        req.finish_time = now
        self.virt.release_request(req.request_id)

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *,
            max_steps: int = 10_000) -> EngineStats:
        """Serve a pre-generated trace to completion (or max_steps)."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        waiting: List[Request] = []       # admitted by controller, no slot yet
        by_id = {r.request_id: r for r in requests}
        now = 0.0
        steps = 0

        def admit_arrivals():
            nonlocal pending
            due = [r for r in pending if r.arrival_time <= now]
            pending = [r for r in pending if r.arrival_time > now]
            for r in due:
                if self._admit(r, now) == "admitted":
                    r.admit_time = now
                    waiting.append(r)
            for p in self.admission.drain(now):
                r = by_id[p.request_id]
                r.admit_time = now
                waiting.append(r)

        while (pending or waiting or
               any(r.active for r in self.runners.values())):
            if steps >= max_steps:
                break
            steps += 1
            # jump virtual time to the next arrival if idle
            if not waiting and not any(r.active for r in self.runners.values()) \
                    and pending:
                now = max(now, pending[0].arrival_time)
            admit_arrivals()

            # --- prefill admitted requests into free slots ----------------
            still = []
            for req in waiting:
                runner = self.runners[req.model]
                if runner.free_slot() is not None:
                    t0 = time.perf_counter()
                    runner.prefill_request(req, self.rng)
                    dt = time.perf_counter() - t0
                    now += dt
                    req.first_token_time = now
                    req.token_times.append(now)
                    req.generated += 1
                    self.stats.tokens_out += 1
                    self.stats.ttft.append(now - req.arrival_time)
                else:
                    still.append(req)
            waiting = still

            # --- decode: one step per active model ------------------------
            active = [n for n, r in self.runners.items() if r.active]
            if self.mode.pipeline and len(active) >= 2:
                now = self._decode_pipelined(active, now)
            else:
                for n in active:
                    now = self._decode_model(n, now)

            # --- completions ---------------------------------------------
            for n, runner in self.runners.items():
                for slot, req in enumerate(runner.slots):
                    if req is not None and req.done:
                        runner.release(slot)
                        self._finish(req, now)
        self.stats.wall_s = now
        for r in requests:
            self.stats.tbt.extend(r.tbt_samples())
        return self.stats

    # ------------------------------------------------------------------
    def _record_step(self, name: str, dt: float) -> None:
        log = self.stats.step_times[name]
        if len(log) > 8 and dt > np.median(log) * 4.0:
            self.stats.slow_steps += 1     # straggler flag
        log.append(dt)

    def _decode_model(self, name: str, now: float) -> float:
        runner = self.runners[name]
        t0 = time.perf_counter()
        host = self.host_steps[name] if self.host_steps else None
        toks, act = runner.decode_once(host)
        dt = time.perf_counter() - t0
        self._record_step(name, dt)
        now += dt
        for i in act:
            req = runner.slots[i]
            req.generated += 1
            req.output_ids.append(int(toks[i]))
            req.token_times.append(now)
            self.stats.tokens_out += 1
            self.virt.extend_request(req.request_id, 1)
        return now

    def _decode_pipelined(self, active: List[str], now: float) -> float:
        """Two (or more) models stepped with overlapping execution.

        lowering=ON : every model's fused step is ISSUED before any is
        blocked on — async dispatch overlaps the programs.
        lowering=OFF: the layer-wise pipeline scheduler interleaves the
        models' attention/FFN stages across the two pools (paper Fig. 4)."""
        if not self.mode.lowering:
            return self._decode_pipelined_host(active, now)
        t0 = time.perf_counter()
        issued = []
        for n in active:
            runner = self.runners[n]
            toks_dev, runner.cache = runner._decode(
                runner.params, jnp.asarray(runner.next_tokens), runner.cache,
                jnp.asarray(runner.lengths))
            issued.append((n, toks_dev))
        for n, toks_dev in issued:
            runner = self.runners[n]
            toks = np.asarray(jax.block_until_ready(toks_dev))
            act = [i for i, s in enumerate(runner.slots) if s is not None]
            dt = time.perf_counter() - t0
            now_model = now + dt
            for i in act:
                runner.lengths[i] += 1
                runner.next_tokens[i] = toks[i]
                req = runner.slots[i]
                req.generated += 1
                req.output_ids.append(int(toks[i]))
                req.token_times.append(now_model)
                self.stats.tokens_out += 1
                self.virt.extend_request(req.request_id, 1)
        dt_all = time.perf_counter() - t0
        for n in active:
            self._record_step(n, dt_all / len(active))
        return now + dt_all

    def _decode_pipelined_host(self, active: List[str], now: float) -> float:
        """Layer-wise two-batch pipeline over the disaggregated pools."""
        t0 = time.perf_counter()
        batches = []
        for i, n in enumerate(active):
            runner = self.runners[n]
            ka, kb = runner.cache_keys()
            batches.append(InflightBatch(
                batch_id=i, model=n,
                tokens=jnp.asarray(runner.next_tokens),
                cache_k=runner.cache[ka], cache_v=runner.cache[kb],
                lengths=jnp.asarray(runner.lengths)))
        done = self.scheduler.run(batches, max_inflight=2)
        dt_all = time.perf_counter() - t0
        for b in done:
            runner = self.runners[b.model]
            toks, act = runner.apply_pipeline_result(b)
            now_model = now + dt_all
            for i in act:
                req = runner.slots[i]
                req.generated += 1
                req.output_ids.append(int(toks[i]))
                req.token_times.append(now_model)
                self.stats.tokens_out += 1
                self.virt.extend_request(req.request_id, 1)
            self._record_step(b.model, dt_all / len(active))
        return now + dt_all
