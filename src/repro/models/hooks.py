"""Sharding hooks: the seam between the model zoo and the distribution layer.

Models are written sharding-agnostic; a :class:`Hooks` instance injects
``with_sharding_constraint`` at the logical points that matter for the
paper's disaggregation:

* ``boundary_in`` / ``boundary_out`` — the CrossPool *pool boundary*: hidden
  states leaving the KV-cache pool (attention layout) for the weights pool
  (FFN layout) and back.  Under the crosspool strategy these re-layouts are
  where XLA emits the hidden-state transfer collectives (paper §3, C2).
* ``kv`` — KV-cache placement (sequence-sharded under crosspool, batch- or
  head-sharded under monolithic).
* ``ffn_hidden`` / ``moe_*`` — weights-pool internal layouts.

Everything defaults to identity so models run standalone on one device.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

Array = object


def _identity(x):
    return x


@dataclass(frozen=True)
class Hooks:
    act: Callable = _identity          # residual stream [B,S,D]
    attn_q: Callable = _identity       # query tensor [B,S,H,hd]
    attn_out: Callable = _identity     # attention output [B,S,D]
    kv: Callable = _identity           # KV-cache tensors (any per-layer layout)
    kv_state: Callable = _identity     # SSM recurrent state
    boundary_in: Callable = _identity  # hidden entering the weights pool
    boundary_out: Callable = _identity # hidden returning to the KV-cache pool
    ffn_hidden: Callable = _identity   # dense MLP hidden [B,S,F]
    moe_inputs: Callable = _identity   # dispatched expert inputs [E,G,C,D]
    moe_hidden: Callable = _identity   # expert hidden [E,G,C,F]
    logits: Callable = _identity       # LM head output [B,S,V]
    # --- algorithm overrides (crosspool sequence-sharded decode) -----------
    # fn(q [B,1,H,D], cache_k, cache_v, lengths_incl [B]) -> out [B,1,H,D]
    decode_attn: Optional[Callable] = None
    # fn(q_lat, q_rope, cache_latent, cache_rope, lengths_incl) -> ctx_lat
    decode_attn_mla: Optional[Callable] = None
    # fn(moe_params, x [B,S,D]) -> (out, aux): explicit all-to-all dispatch
    moe_apply: Optional[Callable] = None


IDENTITY_HOOKS = Hooks()
