"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig7]

Each benchmark prints ``name,key,value`` CSV rows and asserts its paper
claim; a failing claim fails the harness.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (fig1b_kv_accumulation, fig2_kv_availability,
                        fig6_context_scalability, fig7_tbt, kernels_bench,
                        table1_weight_breakdown, table3_ablation)

BENCHES = {
    "fig1b": fig1b_kv_accumulation.run,
    "fig2": fig2_kv_availability.run,
    "table1": table1_weight_breakdown.run,
    "fig6": fig6_context_scalability.run,
    "fig7": fig7_tbt.run,
    "table3": table3_ablation.run,
    "kernels": kernels_bench.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    args = ap.parse_args()
    todo = {args.only: BENCHES[args.only]} if args.only else BENCHES
    failures = 0
    for name, fn in todo.items():
        print(f"\n# === {name} ===")
        t0 = time.time()
        try:
            fn()
            print(f"# {name}: OK ({time.time() - t0:.1f}s)")
        except Exception:
            failures += 1
            print(f"# {name}: FAILED\n{traceback.format_exc()}")
    print(f"\n# benchmarks: {len(todo) - failures}/{len(todo)} passed")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
