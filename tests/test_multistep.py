"""Persistent multi-step decode: the K-tokens-per-dispatch invariants.

* parity: token streams are bit-exact for K in {1, 2, 4} against the K=1
  seed fixture (the multi-step program is a ``lax.scan`` over the SAME
  per-step body), and the host-driven lowering clamps to K=1 so both
  lowering modes keep gating the pre-refactor streams;
* EOS mid-block: an EOS hit inside a K-block freezes the row on device
  (done-mask), the host commits only the valid prefix, and the unused
  reserved pages return at commit;
* cancel at a dispatch boundary: cancels stay at step boundaries
  (DESIGN.md §9) and restore pool/arena accounting exactly;
* forced elastic shrink between dispatches: the swap-out -> shrink ->
  grow cycle against live K=4 requests is invisible in the streams
  (``ensure_resident`` faults pages back BEFORE the next block's tables
  are built);
* property: ``reserve_decode_block``/``commit_decode_block`` sequences
  never leak or alias pages, and commit trims the table to exactly
  ``ceil(tokens / page_tokens)`` entries;
* HLO proof: K decode tokens cost exactly ONE dispatch — the compiled
  program is a depth-0 while with trip count K wrapping the layer scan,
  with zero mid-program host transfers and no logits-shaped tensor in
  the entry outputs (sampling is fused on device).
"""
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import PAPER_COLOC_SET, get_smoke_config
from repro.core.control import MultiStepFusedStep, dispatch_count
from repro.core.pools import build_pools
from repro.core.virtualizer import KVVirtualizer, OutOfPagesError
from repro.launch import hlo_analysis as ha
from repro.models import build_model
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime.request import Request
from repro.runtime.session import HandleState

MOE, MLA, MOON = "qwen3-moe-235b-a22b", "minicpm3-4b", "moonshot-v1-16b-a3b"
FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "pre_refactor_token_streams.json")


def _models(names=PAPER_COLOC_SET):
    return {n: get_smoke_config(n).replace(dtype="float32") for n in names}


def _engine(names=PAPER_COLOC_SET, lowering=True, decode_steps=1, **kw):
    kw.setdefault("page_budget", 2048)
    kw.setdefault("page_bytes", 4096)
    kw.setdefault("slab_bytes", 4096)
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_ctx", 64)
    kw.setdefault("seed", 0)
    return CrossPoolEngine(
        _models(names),
        mode=EngineMode(pipeline=True, lowering=lowering,
                        decode_steps_per_dispatch=decode_steps), **kw)


def _trace_fused():
    return [Request(0, MOE, 6, 3, 0.0), Request(1, MOE, 7, 3, 0.0),
            Request(2, MOE, 9, 4, 0.0), Request(3, MLA, 5, 3, 0.0),
            Request(4, MLA, 6, 2, 0.0), Request(5, MOON, 20, 3, 0.0)]


def _trace_host():
    return [Request(0, MOE, 6, 3, 0.0), Request(1, MLA, 5, 2, 0.0),
            Request(2, MOON, 20, 3, 0.0)]


def _streams(reqs):
    return {str(r.request_id): list(map(int, r.output_ids)) for r in reqs}


def _accounting(engine):
    return {
        "mapped_pages": engine.virt.mapped_pages,
        "live_requests": sorted(engine.virt.requests),
        "pins": dict(engine.arena.pins) if engine.arena is not None else {},
        "inflight": dict(engine.admission.inflight),
        "queued": engine.admission.queued_count(),
    }


# ---------------------------------------------------------------------------
# bit-exact parity with the K=1 seed fixture
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 4])
def test_fused_streams_bit_exact_vs_k1_fixture(k):
    """The K-step scan runs the SAME per-step body as K=1, so the token
    streams captured from the seed driver must reproduce bit for bit —
    including requests whose max_new is not a multiple of K (done-mask
    freezes the tail rows) — and every reserved page must come back."""
    with open(FIXTURE) as f:
        want = json.load(f)["fused_pipeline"]
    engine = _engine(decode_steps=k)
    reqs = _trace_fused()
    stats = engine.run(reqs)
    assert _streams(reqs) == want["streams"]
    assert stats.tokens_out == want["tokens_out"]
    u = engine.virt.utilization()
    assert u["mapped_pages"] == 0
    assert engine.virt.free_pages == engine.virt.page_budget


def test_host_mode_clamps_to_k1_and_matches_fixture():
    """The host-driven lowering stays a per-layer K=1 dispatch train even
    with the knob set, so it keeps gating the pre-refactor streams."""
    with open(FIXTURE) as f:
        want = json.load(f)["host_pipeline"]
    engine = _engine(lowering=False, decode_steps=4)
    assert all(r.decode_steps == 1 for r in engine.runners.values())
    reqs = _trace_host()
    stats = engine.run(reqs)
    assert _streams(reqs) == want["streams"]
    assert stats.tokens_out == want["tokens_out"]


def test_streaming_callbacks_fan_out_per_token():
    """One K=4 dispatch commits a block, but the callback contract is
    per token: events fire in stream order with first/done marks and
    strictly increasing (interpolated) timestamps."""
    engine = _engine(names=(MOE, MLA), decode_steps=4)
    seen = []
    h = engine.submit(Request(0, MOE, 6, 6, 0.0),
                      on_token=lambda e: seen.append(e))
    steps = 0
    while not h.done:
        engine.step()
        steps += 1
        assert steps < 20
    assert [e.token for e in seen] == h.tokens and len(h.tokens) == 6
    assert [e.index for e in seen] == list(range(6))
    assert seen[0].first and not seen[0].done
    assert seen[-1].done and not seen[-1].first
    assert [e.time for e in seen] == h.request.token_times
    times = h.request.token_times
    assert all(b > a for a, b in zip(times, times[1:]))


# ---------------------------------------------------------------------------
# EOS mid-block
# ---------------------------------------------------------------------------

def test_eos_mid_block_freezes_row_and_returns_pages():
    """EOS hitting inside a K=4 block stops the stream at the EOS token
    (the device freezes the row; the host commits the valid prefix),
    identically to K=1, and all pages return at release."""
    probe = _engine(names=(MOE, MLA))
    hp = probe.submit(Request(0, MOE, 6, 8, 0.0))
    probe.drain()
    assert len(hp.tokens) == 8
    # an EOS value that first appears mid-stream (index >= 1): at K=4 it
    # lands inside the first decode block
    idx = next(i for i in range(1, 8) if hp.tokens[i] not in hp.tokens[:i])
    eos = hp.tokens[idx]

    streams = {}
    for k in (1, 4):
        engine = _engine(names=(MOE, MLA), decode_steps=k)
        baseline = _accounting(engine)
        h = engine.submit(Request(0, MOE, 6, 8, 0.0, eos_id=eos))
        engine.drain()
        assert h.request.eos_seen and h.request.done
        assert h.tokens == hp.tokens[:idx + 1]
        assert h.state is HandleState.FINISHED
        assert _accounting(engine) == baseline
        streams[k] = h.tokens
    assert streams[1] == streams[4]


# ---------------------------------------------------------------------------
# cancel at a dispatch boundary
# ---------------------------------------------------------------------------

def test_cancel_at_dispatch_boundary_restores_accounting():
    """Cancels stay at dispatch boundaries: after a K-block commits, a
    cancel tears down atomically (including the block's reserved pages)
    and the co-resident request keeps serving."""
    engine = _engine(names=(MOE, MLA), decode_steps=4)
    baseline = _accounting(engine)
    h1 = engine.submit(Request(1, MOE, 6, 50, 0.0))
    h2 = engine.submit(Request(2, MLA, 5, 3, 0.0))
    engine.step()
    engine.step()
    assert h1.state is HandleState.DECODING
    assert len(h1.tokens) >= 5            # prefill token + >= one K-block
    assert engine.cancel(h1)
    stats = engine.drain()
    assert h1.state is HandleState.CANCELLED
    assert h2.state is HandleState.FINISHED
    assert len(h2.tokens) == 3
    assert _accounting(engine) == baseline
    assert stats.cancelled == 1


# ---------------------------------------------------------------------------
# forced elastic shrink between dispatches
# ---------------------------------------------------------------------------

def test_forced_shrink_between_dispatches_bit_exact():
    """Mid-serve, force the full elastic cycle against the live K=4
    requests (swap out, shrink+compact, grow back).  The next dispatch's
    reserve path faults everything back in BEFORE building tables
    (DESIGN.md §9 ordering), so the streams must equal the unperturbed
    run bit for bit."""
    ref_engine = _engine(decode_steps=4)
    ref_reqs = _trace_fused()
    ref_engine.run(ref_reqs)

    engine = _engine(decode_steps=4)
    reqs = _trace_fused()
    handles = [engine.submit(r) for r in reqs]
    engine.step()                          # prefill + first decode blocks
    virt = engine.virt
    live = sorted(virt.requests)
    assert live, "nothing survived the first step to perturb"
    swapped = sum(virt.swap_out(rid) for rid in live)
    assert swapped > 0
    virt.resize(max(virt.mapped_pages + 2, 8))
    assert virt.page_budget < 2048
    virt.resize(2048)
    steps = 0
    while any(not h.done for h in handles):
        engine.step()
        steps += 1
        assert steps < 100
    assert _streams(reqs) == _streams(ref_reqs)
    assert engine.virt.free_pages == engine.virt.page_budget


# ---------------------------------------------------------------------------
# property: reserve/commit never leaks or aliases pages
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["register", "reserve", "commit", "release"]),
              st.sampled_from(list(PAPER_COLOC_SET)),
              st.integers(1, 8)),
    min_size=1, max_size=40))
def test_property_reserve_commit_no_leak_no_alias(ops):
    """Random register/reserve/commit/release interleavings (including
    OutOfPagesError mid-sequence): no page leaks, no double mapping, and
    a commit always trims the table to ceil(tokens / page_tokens)."""
    budget = 64
    virt = KVVirtualizer({n: get_smoke_config(n) for n in PAPER_COLOC_SET},
                         page_budget=budget, page_bytes=4096,
                         allocate_device_pool=False)
    reserved = {}                          # rid -> outstanding reserve k
    next_id = 0
    for op, model, arg in ops:
        try:
            if op == "register" or not reserved:
                virt.register_request(next_id, model, arg)
                reserved[next_id] = 0
                next_id += 1
            elif op == "reserve":
                rid = next(iter(reserved))
                virt.reserve_decode_block(rid, arg)
                reserved[rid] = max(reserved[rid], arg)
            elif op == "commit":
                rid = next(iter(reserved))
                n = min(arg, reserved[rid])    # never beyond the reserve
                virt.commit_decode_block(rid, n)
                reserved[rid] = 0
                req = virt.requests[rid]
                view = virt.views[req.model]
                if view.n_kv_layers:
                    keep = math.ceil(max(req.tokens, 1)
                                     / view.tokens_per_page)
                    assert len(req.tables[0]) == keep, \
                        "commit did not trim to the exact page count"
            else:
                rid = next(iter(reserved))
                virt.release_request(rid)
                del reserved[rid]
        except OutOfPagesError:
            pass
        mapped = [p for r in virt.requests.values()
                  for t in r.tables for p in t]
        mapped += [p for r in virt.requests.values() for p in r.state_pages]
        assert len(mapped) == len(set(mapped)), "double-mapped page"
        assert len(mapped) + virt.free_pages == budget, "page leak"
        for r in virt.requests.values():
            assert len({len(t) for t in r.tables} | {0}) <= 2, \
                "unequal layer tables"
    for rid in list(reserved):
        virt.release_request(rid)
    assert virt.free_pages == budget


# ---------------------------------------------------------------------------
# HLO proof: K tokens, one dispatch, logits never leave the device
# ---------------------------------------------------------------------------

def test_k_tokens_cost_one_dispatch_and_no_logit_transfer():
    """Structural proof on the compiled HLO: the K-step program is ONE
    dispatch (a depth-0 while with known trip count K wrapping the layer
    scan), makes zero mid-program host transfers, and its only host-
    visible outputs are the [K, B] sampled token ids plus the carried KV
    pool — no [*, vocab] float tensor (logits are consumed on device)."""
    name, K, B, seq = MOE, 4, 2, 8
    cfg = get_smoke_config(name).replace(dtype="float32")
    models = {name: cfg}
    model = build_model(cfg)
    params = {name: model.init(jax.random.PRNGKey(0))}
    kv_pool, _, pooled = build_pools(models, params, page_budget=256,
                                     page_bytes=4096,
                                     pool_dtype=jnp.float32)
    virt = kv_pool.virtualizer
    for b in range(B):
        virt.register_request(b, name, seq)
        virt.reserve_decode_block(b, K)
    view = virt.views[name]
    max_pages = max(1, math.ceil((seq + K) / view.tokens_per_page))
    tables = virt.batch_tables(name, [0, 1], max_pages)
    step = MultiStepFusedStep(pooled[name], k=K)
    abuf, slot_table = pooled[name].arena.acquire(name)
    hlo = step._step.lower(
        step._p_kv, abuf, slot_table, jnp.zeros((B,), jnp.int32), virt.pool,
        tables, jnp.full((B,), seq, jnp.int32), jnp.full((B,), K, jnp.int32),
        jnp.full((B,), -1, jnp.int32),
        jax.random.PRNGKey(0)).compile().as_text()

    # one host dispatch commits the whole K-token block; the host-driven
    # baseline pays its per-layer dispatch train K times over
    assert dispatch_count(cfg.n_layers, fused=True, decode_steps=K) == 1
    assert dispatch_count(cfg.n_layers, fused=False, decode_steps=K) == \
        (2 + cfg.n_layers * 5) * K
    trips = ha.while_trip_structure(hlo)
    assert (0, K) in trips, f"no depth-0 while with trip {K}: {trips}"
    assert (1, cfg.n_layers) in trips, \
        f"no depth-1 layer scan with trip {cfg.n_layers}: {trips}"
    assert ha.host_transfer_count(hlo) == 0
    outs = ha.entry_output_shapes(hlo)
    assert ("s32", [K, B]) in outs, f"token block missing from {outs}"
    assert not any(dims and dims[-1] == cfg.vocab_size
                   and dt.startswith("f") for dt, dims in outs), \
        f"logits-shaped tensor leaves the device: {outs}"
