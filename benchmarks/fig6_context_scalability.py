"""Fig. 6: max aggregate RPS vs context length, three systems.

LongAlign-like context bins; per bin, Little's-law max RPS under each
system's placement + KV budget; vertical drops mark capacity cliffs
(a request of that context can no longer be admitted anywhere).
"""
from __future__ import annotations


from repro.configs import PAPER_COLOC_SET, get_config
from repro.runtime.simulator import max_rps_for_context, paper_placements

BINS = [1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072, 262144,
        524288, 1_048_576]


def run(csv=print) -> dict:
    models = {n: get_config(n) for n in PAPER_COLOC_SET}
    out = {}
    for system in ("static", "kvcached", "crosspool"):
        pl = paper_placements(models, system)
        rps = [max_rps_for_context(models, pl, c) for c in BINS]
        out[system] = rps
        for c, r in zip(BINS, rps):
            csv(f"fig6,{system},ctx={c},max_rps={r:.4f}")
        cliff = next((c for c, r in zip(BINS, rps) if r == 0.0), None)
        csv(f"fig6,{system},first_cliff_ctx,{cliff}")
    # the paper's qualitative claim: crosspool stays positive at bins where
    # baselines have already dropped
    longest = {s: max((c for c, r in zip(BINS, out[s]) if r > 0), default=0)
               for s in out}
    csv(f"fig6,longest_supported,static={longest['static']},"
        f"kvcached={longest['kvcached']},crosspool={longest['crosspool']}")
    assert longest["crosspool"] >= longest["kvcached"] >= 0
    assert longest["crosspool"] >= longest["static"]
    return out


if __name__ == "__main__":
    run()
