"""Error-feedback int8 gradient compression for data-parallel reduction.

At 1000+ nodes the DP all-reduce of bf16 gradients is DCN/ICI-bound; int8
quantization with an error-feedback accumulator (1-bit-Adam style residual
carrying) cuts the payload 2x with no asymptotic convergence loss:

    q      = quantize(g + e)        # per-tensor symmetric int8
    e'     = (g + e) - dequant(q)   # residual carried to the next step
    g_used = dequant(q)

In the SPMD dry-run the quantize->(all-reduce)->dequantize pair brackets
the gradient reduction; the HLO then carries int8 operands through the
reduction boundary (the collective-bytes term in §Roofline shrinks 2x).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params: Dict) -> Dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Dict, error_fb: Dict) -> Tuple[Dict, Dict]:
    """Returns (grads_to_use, new_error_feedback)."""

    def one(g, e):
        total = g.astype(jnp.float32) + e
        q, scale = _quantize(total)
        deq = _dequantize(q, scale)
        return deq.astype(g.dtype), total - deq

    out = jax.tree.map(one, grads, error_fb)
    used = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return used, new_e
