"""Table 3: ablation of the layer-wise pipeline + control lowering.

Two complementary measurements:
  (a) REAL: the CrossPool engine serving the smoke-scale colocation trio
      (deepened to 8 layers so per-layer dispatch overhead is visible) on
      TWO forced host devices — the KV pool on device 0, the weights pool
      on device 1 with real inter-device hidden-state transfers.  Runs in a
      subprocess so the device-count flag never leaks into other benches.
      Wall-clock decode throughput across the four (pipeline x lowering)
      modes; warmup excluded.
  (b) SIM:  the paper-scale cost model at 0.5 RPS/model (as in Table 3).
"""
from __future__ import annotations

import copy
import os
import subprocess
import sys

from repro.configs import PAPER_COLOC_SET, get_config
from repro.runtime import observe as trace_mod
from repro.runtime.simulator import DecodeSimulator, paper_placements

MODES = [(False, False), (False, True), (True, False), (True, True)]

_REAL_SCRIPT = r"""
import time
import jax, jax.numpy as jnp, numpy as np
from repro.configs import PAPER_COLOC_SET, get_smoke_config
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime import observe as trace_mod

assert len(jax.devices()) == 2, jax.devices()
models = {n: get_smoke_config(n).replace(n_layers=8, dtype="float32")
          for n in PAPER_COLOC_SET}

def run_mode(pipeline, lowering):
    engine = CrossPoolEngine(models, page_budget=16384, page_bytes=4096,
                             max_batch=2, max_ctx=64,
                             mode=EngineMode(pipeline, lowering), seed=1)
    reqs = trace_mod.make_requests(list(models), rps_per_model=100.0,
                                   horizon_s=0.12, kind="sharegpt", seed=1,
                                   scale_tokens=0.05, max_new_cap=8)
    reqs = reqs[:9]
    for r in reqs:
        r.prompt_tokens = max(min(r.prompt_tokens, 16), 4)
        r.arrival_time = 0.0
    stats = engine.run(reqs)
    decode_steps = sum(len(v) for v in stats.step_times.values())
    decode_time = sum(sum(v) for v in stats.step_times.values())
    toks = stats.tokens_out
    return toks, decode_time

for pipeline, lowering in [(False, False), (False, True), (True, False),
                           (True, True)]:
    toks, dt = run_mode(pipeline, lowering)
    print(f"RESULT,{int(pipeline)},{int(lowering)},{toks},{dt:.4f}",
          flush=True)
"""


def run_real(csv=print) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, "-c", _REAL_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=1500)
    if r.returncode != 0:
        raise RuntimeError(f"real ablation failed:\n{r.stdout[-2000:]}\n"
                           f"{r.stderr[-2000:]}")
    out = {}
    for line in r.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, p, l, toks, dt = line.split(",")
            tput = int(toks) / max(float(dt), 1e-9)
            out[(bool(int(p)), bool(int(l)))] = tput
    for (pipeline, lowering), tput in sorted(out.items()):
        csv(f"table3_real,pipeline={'On' if pipeline else 'Off'},"
            f"lowering={'On' if lowering else 'Off'},"
            f"decode_tok_s={tput:.2f}")
    base = out[(False, False)]
    csv(f"table3_real,lowering_gain,{out[(False, True)] / base:.2f}x")
    csv(f"table3_real,pipeline_gain,{out[(True, False)] / base:.2f}x")
    csv(f"table3_real,combined_gain,{out[(True, True)] / base:.2f}x")
    return out


def run_sim(csv=print, horizon_s: float = 90.0) -> dict:
    models = {n: get_config(n) for n in PAPER_COLOC_SET}
    proto = trace_mod.make_requests(
        list(models), rps_per_model=0.5, horizon_s=horizon_s,
        kind="sharegpt", seed=2)
    out = {}
    for pipeline, lowering in MODES:
        reqs = copy.deepcopy(proto)
        pl = paper_placements(models, "crosspool", pipelined=pipeline,
                              lowered=lowering)
        DecodeSimulator(models, pl).run(reqs)
        tok = sum(r.generated for r in reqs)
        span = max((r.finish_time for r in reqs if r.finish_time),
                   default=1.0)
        tput = tok / span
        out[(pipeline, lowering)] = tput
        csv(f"table3_sim,pipeline={'On' if pipeline else 'Off'},"
            f"lowering={'On' if lowering else 'Off'},"
            f"throughput_tok_s={tput:.2f}")
    base = out[(False, False)]
    both = out[(True, True)]
    csv(f"table3_sim,combined_gain,{both / base:.2f}x")
    assert both > out[(True, False)] and both > out[(False, True)] > base
    return out


def run(csv=print) -> dict:
    real = run_real(csv)
    sim = run_sim(csv)
    # directionality of the real measurement: fused control beats per-layer
    # host dispatch (the dominant effect at CPU scale)
    assert real[(False, True)] > real[(False, False)]
    return {"real": real, "sim": sim}


if __name__ == "__main__":
    run()
