"""qwen3-moe-235b-a22b — Qwen3 MoE family [hf:Qwen/Qwen3-30B-A3B; hf].

Assigned config: 94L d_model=4096 64H (GQA kv=4) d_ff=1536(per expert)
vocab=151936, MoE 128 experts top-8.  qk_norm per Qwen3; head_dim=128
(Qwen3 decouples head_dim from d_model/n_heads).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151_936,
    attention="gqa",
    qk_norm=True,
    n_experts=128,
    experts_per_token=8,
    rope_theta=1_000_000.0,
    max_position=131_072,
    source="hf:Qwen/Qwen3-30B-A3B (scaled per assignment); hf",
)

# Reduced same-family config for CPU smoke tests.
SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8, d_ff=32,
    vocab_size=256, n_experts=8, experts_per_token=2, max_position=512,
)
