"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig7,table1]

Each benchmark prints ``name,key,value`` CSV rows and asserts its paper
claim; a failing claim fails the harness.  Every run also writes a
machine-readable ``BENCH_summary.json`` (name -> ok/fail, wall seconds,
key metrics) so the perf trajectory can be tracked per PR.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

import numpy as np

from benchmarks import (elastic_burst, fig1b_kv_accumulation,
                        fig2_kv_availability, fig6_context_scalability,
                        fig7_tbt, kernels_bench, multistep_decode,
                        multiturn_cache, online_tbt,
                        table1_weight_breakdown, table3_ablation)

BENCHES = {
    "fig1b": fig1b_kv_accumulation.run,
    "fig2": fig2_kv_availability.run,
    "table1": table1_weight_breakdown.run,
    "fig6": fig6_context_scalability.run,
    "fig7": fig7_tbt.run,
    "table3": table3_ablation.run,
    "kernels": kernels_bench.run,
    "online": online_tbt.run,
    "elastic": elastic_burst.run,
    "multistep": multistep_decode.run,
    "multiturn": multiturn_cache.run,
}


def _jsonable(v):
    """Benchmarks return ad-hoc dicts (tuple keys, numpy scalars, nested
    tuples); flatten them into plain JSON."""
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, (np.floating, np.integer)):
        return v.item()
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


def _parse_only(arg: str | None) -> dict:
    if arg is None:
        return dict(BENCHES)
    todo = {}
    for name in arg.split(","):
        name = name.strip()
        if not name:
            continue
        if name not in BENCHES:
            raise SystemExit(
                f"unknown benchmark {name!r}; known: {sorted(BENCHES)}")
        todo[name] = BENCHES[name]
    if not todo:
        raise SystemExit("--only selected no benchmarks")
    return todo


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only", default=None, metavar="NAMES",
        help="comma-separated subset, e.g. --only fig7,table1 "
             f"(known: {','.join(BENCHES)})")
    ap.add_argument("--summary", default="BENCH_summary.json",
                    help="machine-readable per-benchmark results file")
    ap.add_argument("--merge", action="store_true",
                    help="update the existing summary file instead of "
                         "rewriting it — lets timing-sensitive benchmarks "
                         "run in their own fresh process (CI runs "
                         "multistep this way: a long-lived process's "
                         "heap/compile-cache state perturbs its P99s)")
    args = ap.parse_args(argv)
    todo = _parse_only(args.only)
    summary = {}
    if args.merge:
        try:
            with open(args.summary) as f:
                summary = json.load(f)
        except (OSError, json.JSONDecodeError):
            pass
    failures = 0
    for name, fn in todo.items():
        print(f"\n# === {name} ===")
        t0 = time.time()
        try:
            metrics = fn()
            wall = time.time() - t0
            summary[name] = {"ok": True, "wall_s": round(wall, 2),
                             "metrics": _jsonable(metrics)}
            print(f"# {name}: OK ({wall:.1f}s)")
        except Exception as e:
            failures += 1
            wall = time.time() - t0
            summary[name] = {"ok": False, "wall_s": round(wall, 2),
                             "error": f"{type(e).__name__}: {e}"}
            print(f"# {name}: FAILED\n{traceback.format_exc()}")
    with open(args.summary, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
    print(f"\n# benchmarks: {len(todo) - failures}/{len(todo)} passed "
          f"(summary -> {args.summary})")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
