"""Chunked Mamba2 SSD (state-space duality) in pure JAX.

This is the *scalable* full-sequence form: O(S/chunk) scan steps with
matmuls inside, vs. the O(S) sequential recurrence in ``ref.ssd_scan``.
Validated against the sequential oracle in tests; the Pallas ``ssd_scan``
kernel implements the same chunk decomposition with VMEM tiling.

Math (arXiv:2405.21060 §6): within a chunk of length L with per-step log
decay a_t = dt_t * A and inclusive cumsum La_t:

  intra:  Y[t] += sum_{s<=t} (C_t.B_s) exp(La_t - La_s) dt_s x_s
  state:  S_c   = sum_s exp(La_L - La_s) dt_s (B_s ⊗ x_s)
  recur:  h_{c+1} = exp(La_L) h_c + S_c
  inter:  Y[t] += C_t . (exp(La_t) h_c)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_scan_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                     B_: jax.Array, C_: jax.Array, chunk: int = 64,
                     h0: Optional[jax.Array] = None,
                     ) -> Tuple[jax.Array, jax.Array]:
    """Same contract as ``ref.ssd_scan``.

    x: [B,S,H,P]; dt: [B,S,H]; A: [H]; B_/C_: [B,S,G,N]; h0: [B,H,P,N].
    S must be divisible by ``chunk`` (pad upstream).
    """
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"
    nc = S // chunk

    f32 = jnp.float32
    xc = x.astype(f32).reshape(Bb, nc, chunk, H, P)
    dtc = dt.astype(f32).reshape(Bb, nc, chunk, H)
    Bc = jnp.repeat(B_.astype(f32), rep, axis=2).reshape(Bb, nc, chunk, H, N)
    Cc = jnp.repeat(C_.astype(f32), rep, axis=2).reshape(Bb, nc, chunk, H, N)

    a = dtc * A[None, None, None, :]                  # [B,nc,L,H] log decays
    La = jnp.cumsum(a, axis=2)                        # inclusive cumsum
    La_total = La[:, :, -1, :]                        # [B,nc,H]

    # --- intra-chunk (quadratic within chunk) ------------------------------
    # decay[l,s] = exp(La_l - La_s) for s<=l else 0
    diff = La[:, :, :, None, :] - La[:, :, None, :, :]      # [B,nc,L,S=L,H]
    l_idx = jnp.arange(chunk)
    tri = (l_idx[:, None] >= l_idx[None, :])[None, None, :, :, None]
    # double-where: masked (upper-triangle) entries have diff > 0 and can
    # overflow exp; zeroing them AFTER exp still leaks NaN through the
    # gradient of where — so clamp inside first.
    decay = jnp.where(tri, jnp.exp(jnp.where(tri, diff, 0.0)), 0.0)
    scores = jnp.einsum("bclhn,bcshn->bclsh", Cc, Bc) * decay
    y_intra = jnp.einsum("bclsh,bcsh,bcshp->bclhp", scores, dtc, xc)

    # --- per-chunk end states ----------------------------------------------
    decay_to_end = jnp.exp(La_total[:, :, None, :] - La)    # [B,nc,L,H]
    S_c = jnp.einsum("bcsh,bcshn,bcshp->bchpn",
                     dtc * decay_to_end, Bc, xc)            # [B,nc,H,P,N]

    # --- inter-chunk recurrence (scan over chunks) --------------------------
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), f32)

    def step(h, inp):
        s_c, la_tot = inp                                   # [B,H,P,N],[B,H]
        h_next = h * jnp.exp(la_tot)[..., None, None] + s_c
        return h_next, h                                    # emit state at chunk START

    S_cm = jnp.moveaxis(S_c, 1, 0)                          # [nc,B,H,P,N]
    La_tm = jnp.moveaxis(La_total, 1, 0)                    # [nc,B,H]
    h_final, h_starts = jax.lax.scan(step, h0, (S_cm, La_tm))
    h_starts = jnp.moveaxis(h_starts, 0, 1)                 # [B,nc,H,P,N]

    # --- inter-chunk contribution -------------------------------------------
    C_dec = Cc * jnp.exp(La)[..., None]                     # [B,nc,L,H,N]
    y_inter = jnp.einsum("bclhn,bchpn->bclhp", C_dec, h_starts)

    y = (y_intra + y_inter).reshape(Bb, S, H, P).astype(x.dtype)
    return y, h_final


def ssd_decode_step(h: jax.Array, x_t: jax.Array, dt_t: jax.Array,
                    A: jax.Array, B_t: jax.Array, C_t: jax.Array,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD recurrence (the decode fast path).

    h: [B,H,P,N] (f32); x_t: [B,H,P]; dt_t: [B,H]; B_t/C_t: [B,G,N].
    Returns (y_t [B,H,P], h_next).  The state h *is* this family's
    "KV cache": constant size per request — the planner treats it as a
    fixed page allocation (DESIGN.md §Arch-applicability).
    """
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t.astype(jnp.float32), rep, axis=1)   # [B,H,N]
    Ch = jnp.repeat(C_t.astype(jnp.float32), rep, axis=1)
    dA = jnp.exp(dt_t.astype(jnp.float32) * A[None, :])     # [B,H]
    h_next = (h * dA[..., None, None]
              + dt_t.astype(jnp.float32)[..., None, None]
              * x_t.astype(jnp.float32)[..., :, None] * Bh[..., None, :])
    y = jnp.einsum("bhpn,bhn->bhp", h_next, Ch).astype(x_t.dtype)
    return y, h_next
