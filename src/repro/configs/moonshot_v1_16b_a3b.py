"""moonshot-v1-16b-a3b — kimi/Moonlight [hf:moonshotai/Moonlight-16B-A3B; hf].

Assigned config: 48L d_model=2048 16H (GQA kv=16 => MHA-like, Type I)
d_ff=1408(per expert) vocab=163840, MoE 64 experts top-6.
(The HF Moonlight checkpoint is DeepSeek-V3-like with shared experts; the
assignment pins the simpler 64e top-6 GQA form, which we follow verbatim.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163_840,
    attention="gqa",
    n_experts=64,
    experts_per_token=6,
    rope_theta=50_000.0,
    max_position=131_072,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=32,
    vocab_size=256, n_experts=8, experts_per_token=2, max_position=512,
)
