"""Fig. 1b: accumulated active KV-cache of 4 cold models at 0.2 RPS / 1 h.

Reproduces the motivation plot: per-model active KV fluctuates and rarely
peaks simultaneously, so the P99 of the AGGREGATE is far below the sum of
per-model peaks — the pooling opportunity (Eq. 1-2 timelines).
"""
from __future__ import annotations

import numpy as np

from benchmarks._stats import percentile
from repro.configs import get_config
from repro.core.planner import WorkloadSpec, active_kv_timeline

MODELS = ["qwen3-14b", "minicpm3-4b", "gemma3-12b", "moonshot-v1-16b-a3b"]


def run(csv=print) -> dict:
    rng = np.random.default_rng(0)
    horizon = 3600.0
    peaks, timelines = {}, {}
    for i, name in enumerate(MODELS):
        cfg = get_config(name)
        n = 400
        r = np.random.default_rng(i)
        spec = WorkloadSpec(
            model=cfg, arrival_rate=0.2,
            prompt_tokens=r.integers(64, 2048, n),
            output_tokens=r.integers(32, 1024, n),
            decode_time=r.uniform(2.0, 40.0, n))
        u = active_kv_timeline(spec, rng, horizon, dt=2.0)
        timelines[name] = u
        peaks[name] = u.max()
    agg = sum(timelines.values())
    sum_peaks = sum(peaks.values())
    agg_p99 = percentile(agg, 99)
    agg_peak = float(agg.max())
    for name in MODELS:
        csv(f"fig1b,{name}_peak_gib,{peaks[name] / 2 ** 30:.3f}")
        csv(f"fig1b,{name}_mean_gib,"
            f"{float(np.mean(timelines[name])) / 2 ** 30:.3f}")
    csv(f"fig1b,aggregate_p99_gib,{agg_p99 / 2 ** 30:.3f}")
    csv(f"fig1b,aggregate_peak_gib,{agg_peak / 2 ** 30:.3f}")
    csv(f"fig1b,sum_of_peaks_gib,{sum_peaks / 2 ** 30:.3f}")
    csv(f"fig1b,pooling_gain_p99_vs_sum_peaks,"
        f"{sum_peaks / max(agg_p99, 1):.2f}x")
    assert agg_p99 < sum_peaks, "pooling must beat per-model worst case"
    return {"agg_p99": agg_p99, "sum_peaks": sum_peaks}


if __name__ == "__main__":
    run()
