"""Pallas TPU decode attention: contiguous and paged (virtualized) KV.

``paged_decode_attention`` is the KV-cache-pool hot loop: attention reads
K/V through a *page table*, the TPU-native analogue of the paper's CUDA-VMM
virtualized paging (DESIGN.md §2).  The page table is passed as a
**scalar-prefetch** operand (``pltpu.PrefetchScalarGridSpec``) so the
``kv_pages`` BlockSpec index_map can select the physical page for each grid
step — indirection happens at the DMA level, not as a gather in the compute.

Grid: ``(batch, page_blocks)``, page dimension sequential, online-softmax
state in VMEM scratch across pages of one request.

``repro.kernels.ops.paged_kv_write`` is the matching write-side primitive:
one XLA scatter that lands ``n`` token rows at their (page, slot)
coordinates in the flat pool — jit- and donation-friendly, so the serving
engine updates the pool buffer in place once per step instead of rebinding
it per token.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Contiguous decode attention (cache [B,T,KV,D], per-row lengths)
# ---------------------------------------------------------------------------

def _decode_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, block_t: int, n_kv: int):
    b = pl.program_id(0)
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    length = lengths_ref[b]

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(t * block_t < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # [H, D]
        k = k_ref[0].astype(jnp.float32)                     # [bt, KV, D]
        v = v_ref[0].astype(jnp.float32)
        H, D = q.shape
        G = H // n_kv
        qg = q.reshape(n_kv, G, D)
        t_valid = (t * block_t + jax.lax.broadcasted_iota(
            jnp.int32, (block_t, 1, 1), 0)) < length
        v = jnp.where(t_valid, v, 0.0)   # 0 * OOB-garbage guard
        s = jnp.einsum("kgd,tkd->kgt", qg, k)                # [KV,G,bt]
        pos = t * block_t + jax.lax.broadcasted_iota(
            jnp.int32, (n_kv, G, block_t), 2)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[:, :, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))     # [KV,G]
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[..., None])                    # [KV,G,bt]
        l_ref[:, :, 0] = l_ref[:, :, 0] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[..., None]
                        + jnp.einsum("kgt,tkd->kgd", p, v))
        m_ref[:, :, 0] = m_cur

    @pl.when(t == nt - 1)
    def _finish():
        l = l_ref[:, :, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        out = acc_ref[...] / safe[..., None]                 # [KV,G,D]
        H = out.shape[0] * out.shape[1]
        o_ref[0, 0] = out.reshape(H, -1).astype(o_ref.dtype)


def contiguous_decode_attention(q: jax.Array, cache_k: jax.Array,
                                cache_v: jax.Array, lengths: jax.Array, *,
                                scale: float, block_t: int = 256,
                                interpret: bool = True) -> jax.Array:
    """q: [B,1,H,D]; cache: [B,T,KV,D]; lengths: [B] -> [B,1,H,D]."""
    B, _, H, D = q.shape
    T, KV = cache_k.shape[1], cache_k.shape[2]
    block_t = min(block_t, T)
    nt = pl.cdiv(T, block_t)
    # fold scale into q once (cheaper than per-block multiply)
    qs = (q.astype(jnp.float32) * scale).astype(q.dtype)

    kernel = functools.partial(_decode_kernel, block_t=block_t, n_kv=KV)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nt),
        in_specs=[
            pl.BlockSpec((1, 1, H, D), lambda b, t, L: (b, 0, 0, 0)),
            pl.BlockSpec((1, block_t, KV, D), lambda b, t, L: (b, t, 0, 0)),
            pl.BlockSpec((1, block_t, KV, D), lambda b, t, L: (b, t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, H, D), lambda b, t, L: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, H // KV, D), jnp.float32),
            pltpu.VMEM((KV, H // KV, 128), jnp.float32),
            pltpu.VMEM((KV, H // KV, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qs, cache_k, cache_v)


# ---------------------------------------------------------------------------
# Paged decode attention (page-table indirection via scalar prefetch)
# ---------------------------------------------------------------------------

def _paged_kernel(page_table_ref, lengths_ref, q_ref, pages_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page_size: int, n_kv: int):
    b = pl.program_id(0)
    p = pl.program_id(1)
    np_ = pl.num_programs(1)
    length = lengths_ref[b]

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    mapped = page_table_ref[b, p] >= 0

    @pl.when((p * page_size < length) & mapped)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # [H, D]
        kv = pages_ref[0].astype(jnp.float32)                # [ps, 2, KV, D]
        k, v = kv[:, 0], kv[:, 1]                            # [ps, KV, D]
        H, D = q.shape
        G = H // n_kv
        qg = q.reshape(n_kv, G, D)
        t_valid = (p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (page_size, 1, 1), 0)) < length
        v = jnp.where(t_valid, v, 0.0)   # 0 * OOB-garbage guard
        s = jnp.einsum("kgd,tkd->kgt", qg, k)
        pos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (n_kv, G, page_size), 2)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[:, :, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        pmat = jnp.exp(s - m_cur[..., None])
        l_ref[:, :, 0] = l_ref[:, :, 0] * alpha + jnp.sum(pmat, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[..., None]
                        + jnp.einsum("kgt,tkd->kgd", pmat, v))
        m_ref[:, :, 0] = m_cur

    @pl.when(p == np_ - 1)
    def _finish():
        l = l_ref[:, :, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        out = acc_ref[...] / safe[..., None]
        H = out.shape[0] * out.shape[1]
        o_ref[0, 0] = out.reshape(H, -1).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, kv_pages: jax.Array,
                           page_table: jax.Array, lengths: jax.Array, *,
                           scale: float, interpret: bool = True) -> jax.Array:
    """Decode attention through the virtualizer's page table.

    q:          [B,1,H,D]
    kv_pages:   [N_pages, page_size, 2, KV, D]  (physical pool)
    page_table: [B, max_pages] int32, -1 = unmapped
    lengths:    [B]
    """
    B, _, H, D = q.shape
    page_size, KV = kv_pages.shape[1], kv_pages.shape[3]
    max_pages = page_table.shape[1]
    qs = (q.astype(jnp.float32) * scale).astype(q.dtype)

    kernel = functools.partial(_paged_kernel, page_size=page_size, n_kv=KV)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, H, D), lambda b, p, pt, L: (b, 0, 0, 0)),
            # physical page selected via the prefetched page table — the DMA
            # engine follows the indirection, not the compute.
            pl.BlockSpec((1, page_size, 2, KV, D),
                         lambda b, p, pt, L: (jnp.maximum(pt[b, p], 0), 0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, H, D), lambda b, p, pt, L: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, H // KV, D), jnp.float32),
            pltpu.VMEM((KV, H // KV, 128), jnp.float32),
            pltpu.VMEM((KV, H // KV, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qs, kv_pages)


# ---------------------------------------------------------------------------
# Paged MLA decode attention (absorbed form over [latent | rope] pages)
# ---------------------------------------------------------------------------
#
# In the absorbed MLA decode the per-token cache row is the concatenation
# [latent (r) | rope key (rp)], and with the absorbed query
# q = [q_lat | q_rope] the scores are a single dot product against the full
# row while the value is the latent prefix alone:
#
#   s(t)   = q_lat . latent_t + q_rope . rope_t = q . kv_t
#   ctx    = softmax(s) @ latent
#
# so one untyped page layout [ps, r + rp] serves both reads.

def _paged_mla_kernel(page_table_ref, lengths_ref, q_ref, pages_ref, o_ref,
                      acc_ref, m_ref, l_ref, *, page_size: int,
                      latent_dim: int):
    b = pl.program_id(0)
    p = pl.program_id(1)
    np_ = pl.num_programs(1)
    length = lengths_ref[b]

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    mapped = page_table_ref[b, p] >= 0

    @pl.when((p * page_size < length) & mapped)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # [H, r+rp]
        kv = pages_ref[0].astype(jnp.float32)                # [ps, r+rp]
        v = kv[:, :latent_dim]                               # [ps, r]
        pos_t = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (page_size, 1), 0)
        v = jnp.where(pos_t < length, v, 0.0)   # 0 * OOB-garbage guard
        s = q @ kv.T                                         # [H, ps]
        pos_s = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (q.shape[0], page_size), 1)
        s = jnp.where(pos_s < length, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))     # [H]
        alpha = jnp.exp(m_prev - m_cur)
        pmat = jnp.exp(s - m_cur[:, None])                   # [H, ps]
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(pmat, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pmat @ v
        m_ref[:, 0] = m_cur

    @pl.when(p == np_ - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def paged_mla_decode_attention(q: jax.Array, kv_pages: jax.Array,
                               page_table: jax.Array, lengths: jax.Array, *,
                               latent_dim: int, scale: float,
                               interpret: bool = True) -> jax.Array:
    """Absorbed-MLA decode attention through the virtualizer's page table.

    q:          [B,1,H, r+rp]  absorbed query [q_latent | q_rope]
    kv_pages:   [N_pages, page_size, r+rp]  (physical pool, typed view)
    page_table: [B, max_pages] int32, -1 = unmapped
    lengths:    [B]
    Returns the latent context [B,1,H,r]; the caller applies W_uv / W_o.
    """
    B, _, H, e = q.shape
    page_size = kv_pages.shape[1]
    max_pages = page_table.shape[1]
    qs = (q.astype(jnp.float32) * scale).astype(q.dtype)

    kernel = functools.partial(_paged_mla_kernel, page_size=page_size,
                               latent_dim=latent_dim)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, H, e), lambda b, p, pt, L: (b, 0, 0, 0)),
            pl.BlockSpec((1, page_size, e),
                         lambda b, p, pt, L: (jnp.maximum(pt[b, p], 0), 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, H, latent_dim),
                               lambda b, p, pt, L: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((H, latent_dim), jnp.float32),
            pltpu.VMEM((H, 128), jnp.float32),
            pltpu.VMEM((H, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, H, latent_dim), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qs, kv_pages)
