"""Config dataclasses for the CrossPool reproduction.

A single :class:`ModelConfig` covers every assigned architecture family:
dense / MoE decoders (GQA, MQA, MLA attention), sliding-window patterns
(gemma3), pure SSM (mamba2), hybrid SSM+shared-attention (zamba2),
encoder-decoder audio backbones (whisper) and VLM backbones (llava).

Configs are *data*: the model zoo in ``repro.models`` interprets them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Mapping, Optional, Tuple

# ---------------------------------------------------------------------------
# Runtime defaults
# ---------------------------------------------------------------------------

# Decode tokens committed per host dispatch when control lowering is ON
# (``runtime.engine.EngineMode.decode_steps_per_dispatch``).  1 preserves
# the seed single-step behaviour; >1 enables the persistent multi-step
# decode path (``core.control.MultiStepFusedStep``) which amortises the
# host dispatch + sampling round-trip across K tokens.  Host-driven
# lowering (the ablation baseline) always runs K=1.
DEFAULT_DECODE_STEPS_PER_DISPATCH = 1

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style).

    The KV cache stores only the compressed latent (``kv_lora_rank``) plus a
    shared rotary key (``qk_rope_head_dim``) per token — this is the paper's
    Type II ("KV-head-limited") flagship case.
    """

    q_lora_rank: int = 0          # 0 = no query compression
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64

    @property
    def kv_bytes_per_token_factor(self) -> int:
        """Cached scalars per token per layer (latent + rope key)."""
        return self.kv_lora_rank + self.qk_rope_head_dim


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD configuration (state-space duality, arXiv:2405.21060)."""

    d_state: int = 128
    head_dim: int = 64
    n_groups: int = 1
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description for one model.

    ``family`` selects the block layout:
      * ``dense``  — attention + dense SwiGLU FFN each layer
      * ``moe``    — attention + top-k routed expert FFN each layer
      * ``ssm``    — Mamba2 SSD block each layer (attention-free)
      * ``hybrid`` — Mamba2 blocks with periodic *shared* attention blocks
      * ``vlm``    — dense decoder backbone; vision frontend is a stub
      * ``audio``  — encoder-decoder backbone; audio frontend is a stub
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- attention flavour ---------------------------------------------
    attention: str = "gqa"            # "gqa" | "mla" | "none"
    qk_norm: bool = False
    mla: Optional[MLAConfig] = None
    # sliding-window pattern: every ``swa_pattern``-th layer is global,
    # the rest use a local window of ``sliding_window`` tokens (gemma3 5:1).
    sliding_window: int = 0
    swa_pattern: int = 0

    # --- MoE -------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    router_jitter: float = 0.0
    capacity_factor: float = 1.25

    # --- SSM / hybrid ----------------------------------------------------
    ssm: Optional[SSMConfig] = None
    # hybrid layout: groups of (ssm_per_group SSM layers + 1 shared attn
    # block).  ``n_layers`` = hybrid_groups * (ssm_per_group + 1) + tail_ssm.
    hybrid_groups: int = 0
    ssm_per_group: int = 0
    tail_ssm_layers: int = 0

    # --- encoder-decoder ---------------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0               # e.g. whisper: 1500 mel frames

    # --- modality frontend (STUB: precomputed embeddings as inputs) -------
    frontend: str = "none"             # "none" | "audio_frames" | "vision_patches"
    frontend_tokens: int = 0           # prepended embedding tokens per request

    # --- misc --------------------------------------------------------------
    mlp_kind: str = "swiglu"           # "swiglu" (3-matrix) | "gelu" (2-matrix)
    max_position: int = 131072
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    source: str = ""                   # provenance note ([hf:...] / [arXiv:...])

    # ------------------------------------------------------------------
    # Derived quantities
    # ------------------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.attention == "none"

    @property
    def q_dim(self) -> int:
        if self.attention == "mla":
            assert self.mla is not None
            return self.n_heads * (self.mla.qk_nope_head_dim + self.mla.qk_rope_head_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """Per-token KV-cache bytes across ALL layers (paper's kappa(M)).

        This drives the planner (Eq. 1): MLA caches the latent only; SWA
        layers cache at most ``sliding_window`` tokens (counted as full rate
        here and clipped by window in the capacity model); SSM layers cache
        nothing per token (constant-size state handled separately).
        """
        if self.attention == "mla":
            assert self.mla is not None
            per_layer = self.mla.kv_bytes_per_token_factor
            return per_layer * self.n_decoder_attn_layers * bytes_per_el
        if self.attn_free:
            return 0
        per_layer = 2 * self.n_kv_heads * self.head_dim  # K and V
        return per_layer * self.n_decoder_attn_layers * bytes_per_el

    def state_bytes_per_request(self, bytes_per_el: int = 2) -> int:
        """Constant per-request state (SSM recurrent state + conv cache)."""
        if self.ssm is None:
            return 0
        d_in = self.ssm.d_inner(self.d_model)
        nh = self.ssm.n_heads(self.d_model)
        per_layer = nh * self.ssm.head_dim * self.ssm.d_state  # h state
        per_layer += (d_in + 2 * self.ssm.n_groups * self.ssm.d_state) * (
            self.ssm.conv_width - 1
        )  # conv cache
        return per_layer * self.n_ssm_layers * bytes_per_el

    @property
    def n_decoder_attn_layers(self) -> int:
        """Number of decoder layers that keep a growing KV cache."""
        if self.family == "hybrid":
            return self.hybrid_groups  # one shared attention block per group
        if self.family == "ssm":
            return 0
        return self.n_layers

    @property
    def n_ssm_layers(self) -> int:
        if self.family == "ssm":
            return self.n_layers
        if self.family == "hybrid":
            return self.hybrid_groups * self.ssm_per_group + self.tail_ssm_layers
        return 0

    @property
    def n_global_attn_layers(self) -> int:
        """Layers whose KV grows with full context (for long-ctx capacity)."""
        if self.swa_pattern > 0:
            return self.n_layers // self.swa_pattern
        return self.n_decoder_attn_layers

    @property
    def supports_long_context(self) -> bool:
        """True if attention cost/memory is sub-quadratic in context.

        Pure full-attention archs skip the ``long_500k`` shape (DESIGN.md).
        """
        if self.family in ("ssm", "hybrid"):
            return True
        if self.swa_pattern > 0:        # only 1/pattern layers are global
            return True
        if self.attention == "mla":     # compressed latent KV
            return True
        return False

    # ------------------------------------------------------------------
    # Parameter counting (for Table 1 and roofline MODEL_FLOPS)
    # ------------------------------------------------------------------
    def param_counts(self) -> dict:
        """Analytic parameter counts split by module group."""
        d = self.d_model
        counts = {"embed": self.vocab_size * d, "attn": 0, "ffn": 0, "ssm": 0,
                  "norm": 0, "head": 0 if self.tie_embeddings else self.vocab_size * d}

        def attn_params() -> int:
            if self.attention == "mla":
                m = self.mla
                q_in = m.q_lora_rank if m.q_lora_rank else d
                p = 0
                if m.q_lora_rank:
                    p += d * m.q_lora_rank + m.q_lora_rank  # down proj + norm
                p += q_in * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim) + m.kv_lora_rank
                p += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                p += self.n_heads * m.v_head_dim * d
                return p
            p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qk_norm:
                p += 2 * self.head_dim
            return p

        def dense_ffn_params(ff: int) -> int:
            n_mats = 3 if self.mlp_kind == "swiglu" else 2
            return n_mats * d * ff

        def moe_ffn_params() -> int:
            p = self.n_experts * 3 * d * self.d_ff
            p += d * self.n_experts  # router
            if self.n_shared_experts:
                p += self.n_shared_experts * 3 * d * self.d_ff
            return p

        def ssm_params() -> int:
            s = self.ssm
            d_in = s.d_inner(d)
            nh = s.n_heads(d)
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            p = d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)  # in_proj
            p += conv_dim * s.conv_width                          # conv1d
            p += nh * 2                                           # A_log, D
            p += nh                                               # dt_bias
            p += d_in                                             # norm
            p += d_in * d                                         # out_proj
            return p

        if self.family in ("dense", "vlm"):
            counts["attn"] = self.n_layers * attn_params()
            counts["ffn"] = self.n_layers * dense_ffn_params(self.d_ff)
            counts["norm"] = self.n_layers * 2 * d + d
        elif self.family == "moe":
            counts["attn"] = self.n_layers * attn_params()
            counts["ffn"] = self.n_layers * moe_ffn_params()
            counts["norm"] = self.n_layers * 2 * d + d
        elif self.family == "ssm":
            counts["ssm"] = self.n_layers * ssm_params()
            counts["norm"] = self.n_layers * d + d
        elif self.family == "hybrid":
            counts["ssm"] = self.n_ssm_layers * ssm_params()
            counts["attn"] = self.hybrid_groups * attn_params()   # shared-per-group
            counts["ffn"] = self.hybrid_groups * dense_ffn_params(self.d_ff)
            counts["norm"] = self.n_layers * 2 * d + d
        elif self.family == "audio":
            counts["attn"] = (self.n_encoder_layers + 2 * self.n_layers) * attn_params()
            counts["ffn"] = (self.n_encoder_layers + self.n_layers) * dense_ffn_params(self.d_ff)
            counts["norm"] = (self.n_encoder_layers + self.n_layers) * 3 * d + 2 * d
        else:
            raise ValueError(f"unknown family {self.family}")
        counts["total"] = sum(counts.values())
        return counts

    def active_param_counts(self) -> int:
        """Active parameters per token (MoE uses top-k experts only)."""
        c = self.param_counts()
        if not self.is_moe:
            return c["total"]
        d = self.d_model
        active_ffn = self.n_layers * (
            (self.experts_per_token + self.n_shared_experts) * 3 * d * self.d_ff
            + d * self.n_experts
        )
        return c["total"] - c["ffn"] + active_ffn

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Elastic pool rebalancing (DESIGN.md §8)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ElasticConfig:
    """Knobs for the online KV<->weights boundary rebalancer.

    Like :class:`ModelConfig`, this is pure data: ``repro.core.elastic``
    interprets it.  The split between the KV page pool and the weight
    slab arena is re-estimated from a sliding telemetry window (windowed
    Eq. 1-2) every ``interval_steps`` session steps; a move is applied
    only when it clears ``hysteresis`` AND ``cooldown_steps`` have passed
    since the last one, and never moves more than ``max_step_fraction``
    of either pool at once — three dampers that keep a bursty signal from
    thrashing the boundary.
    """

    enabled: bool = True
    interval_steps: int = 4          # re-plan cadence (session steps)
    window_s: float = 30.0           # telemetry window feeding the re-plan
    hysteresis: float = 0.15         # min fractional budget change to act
    cooldown_steps: int = 8          # min steps between APPLIED moves
    ewma_alpha: float = 0.25         # occupancy-EWMA smoothing factor
    quantile: float = 0.95           # windowed Eq. (2) sizing quantile
    max_step_fraction: float = 0.5   # max fraction of a pool moved at once
    min_page_budget: int = 16        # absolute KV-pool floor (pages)
    headroom_pages: int = 0          # admission reserve while shrinking


# ---------------------------------------------------------------------------
# Prefix caching (DESIGN.md §11)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CacheConfig:
    """Knobs for the radix-tree prefix cache over the shared KV pool.

    Pure data, interpreted by ``repro.core.prefix_cache`` and the engine.
    Disabled by default: with ``enabled=False`` the engine is byte-for-byte
    the pre-cache engine (no tree, no refcounts, no extra device work).

    ``max_pages_fraction`` bounds the DEVICE pages the tree may retain
    beyond live requests (as a fraction of the live page budget); inserts
    past the bound evict LRU leaves first.  ``second_chance`` reuses the
    elastic host swap tier as a second-chance cache tier: pages evicted
    from the device are swapped out instead of dropped, and a later match
    faults them back bit-exactly instead of re-prefilling.
    """

    enabled: bool = False
    max_pages_fraction: float = 0.5
    second_chance: bool = True


# ---------------------------------------------------------------------------
# Service-level objectives (DESIGN.md §13)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SLObjective:
    """Per-model latency objectives, in milliseconds.

    ``None`` fields are not monitored.  ``target`` is the availability
    target for every monitored metric on this model: a sample is "bad"
    when it strictly exceeds the threshold (exact equality is within
    SLO), and the error budget is ``1 - target``.
    """

    ttft_ms: Optional[float] = None       # time to first token
    tbt_p99_ms: Optional[float] = None    # inter-token gap (tail objective)
    queue_wait_ms: Optional[float] = None  # admission front-door wait
    target: float = 0.99


@dataclass(frozen=True)
class SLOConfig:
    """Declarative SLOs, evaluated by ``runtime.observe.SLOMonitor``.

    Multi-rate burn-rate alerting (the SRE-workbook shape): a breach
    fires only when BOTH the long window and the short window burn the
    error budget faster than ``burn_rate_threshold`` — the long window
    keeps alerts significant, the short window makes them reset quickly
    once the condition clears.  Windows are in engine virtual time.
    """

    objectives: Mapping[str, SLObjective] = dataclasses.field(
        default_factory=dict)           # model name -> objectives
    window_s: float = 30.0              # long (significance) window
    short_window_s: float = 3.0         # fast (recency) window
    burn_rate_threshold: float = 1.0    # budget-burn multiple to alert at


# ---------------------------------------------------------------------------
# Flight recorder (DESIGN.md §13)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FlightRecorderConfig:
    """Knobs for the session flight recorder (``runtime.flightrec``).

    The recorder keeps a bounded ring of every causal input (submits,
    clock reads, cancels, injections) plus informational pool events,
    periodic pool snapshots at quiescent step boundaries, and the full
    per-request token streams.  ``dump_path`` is the auto-dump target on
    a pool accounting failure or the first SLO breach; ``None`` means
    on-demand dumps only (``engine.recorder.dump(path)``).
    """

    enabled: bool = True
    ring_size: int = 4096               # bounded event ring (drops counted)
    snapshot_interval_steps: int = 8    # pool snapshot cadence (steps)
    max_snapshots: int = 128            # bounded snapshot ring
    dump_path: Optional[str] = None     # auto-dump target (JSON)
    dump_on_breach: bool = True         # dump on first SLO breach too


# ---------------------------------------------------------------------------
# Unified engine construction surface
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EngineConfig:
    """One bundle for ``CrossPoolEngine(config=...)`` — the canonical
    construction surface (the loose ``mode=`` / ``elastic=`` kwargs that
    accreted across PRs 4-7 remain as deprecated aliases for one release).

    ``mode`` is the engine's ``EngineMode`` (held loosely typed here so the
    config layer stays import-free of the runtime); ``elastic`` enables the
    online KV<->weights rebalancer; ``cache`` configures the radix-tree
    prefix cache.  ``None`` fields mean "engine default".

    ``sanitize`` attaches the pool shadow-sanitizer
    (``repro.analysis.sanitizer.PoolSanitizer``): every hook event is
    reconciled against the pool counters and a full structural audit runs
    at each step boundary — pure checking, no behavior change.  The
    ``CROSSPOOL_SANITIZE=1`` environment variable forces it on regardless
    (how CI runs the whole tier-1 suite sanitized).
    """

    mode: Optional[object] = None            # runtime.engine.EngineMode
    elastic: Optional[ElasticConfig] = None
    cache: Optional[CacheConfig] = None
    sanitize: bool = False
    slo: Optional[SLOConfig] = None          # burn-rate SLO monitoring
    flightrec: Optional[FlightRecorderConfig] = None  # session black box


# ---------------------------------------------------------------------------
# Input shapes (assigned shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (seq_len, global_batch) workload cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, with the reason if skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: 500k decode is quadratic-KV (DESIGN.md skip list)"
    return True, ""
