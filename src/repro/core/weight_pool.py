"""Weights-pool virtualizer: an expert-slab arena for cold models' FFN.

The KV side of the paper virtualizes cache memory behind ONE shared page
pool (``repro.core.virtualizer``).  This module is its weights-side twin
(DESIGN.md §5): device FFN/MoE bytes for every colocated cold model come
out of ONE pre-allocated **arena** of fixed-size slabs, and "loading a
model" is slot-table bookkeeping plus an async host->device upload — not a
per-model ``device_put`` that scales with the colocation count.

  * the arena is an untyped byte array ``[slot_budget, slab_bytes]``
    (uint8): heterogeneous models — bf16 experts, f32 routers — share the
    same physical slabs and are reconstructed bit-exactly by in-program
    bitcasts, the weights analogue of the KV pool's untyped pages;
  * every model's FFN tree is decomposed into per-layer **slab units**:
    one unit per expert (``wg``/``wu``/``wd`` of one expert of one layer)
    plus one "rest" unit per layer (router, shared experts, or the whole
    dense MLP).  A unit occupies ``ceil(unit_bytes / slab_bytes)`` slabs;
  * **slow path** (host, per activation): ``activate`` / ``evict`` move
    slab ids between the free list and per-model slot tables.  Mapping is
    ATOMIC — eviction candidates are planned first and the slab count is
    taken in one step, so ``OutOfSlabsError`` leaves the arena untouched
    (same rule as ``KVVirtualizer.register_request``);
  * **fast path** (device, per layer): ``ffn_stage`` gathers one layer's
    slab rows through the model's slot table (``ModelArenaView
    .unpack_layer``) and bitcasts them back into expert/MLP weight
    tensors — weights are read through a table exactly like KV pages;
  * master copies stay HOST-resident (packed slab form), so eviction is
    free (weights are read-only) and re-activation re-uploads the same
    bytes: an evict/re-activate round trip is bit-for-bit invisible;
  * uploads are per-layer scatters, so the layer-wise pipeline can
    prefetch layer L+1's slabs while layer L's attention runs
    (``prefetch_layer``) — the paper's transfer-hiding scheduler extended
    from hidden states to weights.

Idle models are evicted clock/LRU under pressure; models with in-flight
requests are pinned and never evicted (the weights analogue of "active
pages are never revoked", paper §3.1).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.errors import check
from repro.kernels.ops import donate_argnums
from repro.models.moe import EXPERT_STACKED_LEAVES

#: Slab granularity of the weights arena.  Weights move in whole-expert
#: units (tens of MB at paper scale), so the slab is far coarser than the
#: 16 KiB KV page: 1 MiB keeps per-expert internal fragmentation under a
#: slab per unit while the slot table stays short.
DEFAULT_SLAB_BYTES = 1 << 20


class OutOfSlabsError(RuntimeError):
    pass


# ---------------------------------------------------------------------------
# Static layout: how one model's FFN tree maps onto slabs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LeafSpec:
    """One weight tensor inside a slab unit."""

    path: Tuple[str, ...]          # e.g. ("moe", "wg") / ("mlp", "wd")
    dtype: jnp.dtype
    shape: Tuple[int, ...]         # per-unit shape (no layer/expert axes)
    offset: int                    # byte offset inside the unit
    nbytes: int


@dataclass(frozen=True)
class UnitSpec:
    """A fixed-size allocation unit: one expert, or one layer's rest."""

    kind: str                      # "expert" | "rest"
    count: int                     # units of this kind per layer (E or 1)
    leaves: Tuple[LeafSpec, ...]
    unit_bytes: int
    slabs_per_unit: int
    slab_offset: int               # first slab of this kind in a layer row


def _leaf_paths(tree: Dict, prefix: Tuple[str, ...] = ()) -> List[Tuple[Tuple[str, ...], np.ndarray]]:
    out = []
    for k in sorted(tree):
        v = tree[k]
        if isinstance(v, dict):
            out.extend(_leaf_paths(v, prefix + (k,)))
        else:
            out.append((prefix + (k,), v))
    return out


def _is_expert_leaf(path: Tuple[str, ...], cfg: ModelConfig) -> bool:
    """Leaves stacked over the expert axis: moe/{wg,wu,wd} [L,E,...]."""
    return (cfg.is_moe and len(path) == 2 and path[0] == "moe"
            and path[1] in EXPERT_STACKED_LEAVES)


def _build_specs(kind: str, leaves: Sequence[Tuple[Tuple[str, ...], np.ndarray]],
                 count: int, per_unit_axes: int, slab_bytes: int,
                 slab_offset: int) -> Optional[UnitSpec]:
    """Lay ``leaves`` out back-to-back inside one unit.

    ``per_unit_axes`` is how many leading axes (layer, expert) to strip
    from the stacked array shape to get the per-unit tensor shape.
    """
    if not leaves:
        return None
    specs, off = [], 0
    for path, arr in leaves:
        shape = tuple(arr.shape[per_unit_axes:])
        dt = jnp.dtype(arr.dtype)
        nbytes = int(np.prod(shape, dtype=np.int64)) * dt.itemsize if shape \
            else dt.itemsize
        specs.append(LeafSpec(path, dt, shape, off, nbytes))
        off += nbytes
    return UnitSpec(kind, count, tuple(specs), off,
                    max(1, math.ceil(off / slab_bytes)), slab_offset)


def _bitcast_from_bytes(raw: jax.Array, dtype: jnp.dtype) -> jax.Array:
    """uint8 [..., n*itemsize] -> dtype [..., n] (value-exact)."""
    itemsize = jnp.dtype(dtype).itemsize
    n = raw.shape[-1] // itemsize
    arr = raw.reshape(raw.shape[:-1] + (n, itemsize))
    return jax.lax.bitcast_convert_type(arr, dtype)


@dataclass
class ModelArenaView:
    """Static slab geometry of one model + the in-program unpacker.

    The layout is identical for every layer, so a model's residency is a
    ``[n_layers, slabs_per_layer]`` slot table and ``unpack_layer`` is one
    gather + static slicing/bitcasting compiled into the FFN stage.
    """

    name: str
    n_layers: int
    units: Tuple[UnitSpec, ...]
    slabs_per_layer: int
    slab_bytes: int

    @property
    def total_slabs(self) -> int:
        return self.n_layers * self.slabs_per_layer

    def unpack_layer(self, arena: jax.Array, row: jax.Array) -> Dict:
        """Rebuild one layer's FFN param tree from the arena.

        ``arena``: [slot_budget, slab_bytes] uint8; ``row``:
        [slabs_per_layer] int32 slab ids.  ONE gather for the whole layer,
        then static slices + bitcasts per leaf — bit-for-bit the packed
        host bytes.
        """
        rows = arena[row]                       # [slabs_per_layer, slab_bytes]
        out: Dict = {}
        for u in self.units:
            chunk = jax.lax.slice_in_dim(
                rows, u.slab_offset,
                u.slab_offset + u.count * u.slabs_per_unit, axis=0)
            chunk = chunk.reshape(u.count, u.slabs_per_unit * self.slab_bytes)
            for leaf in u.leaves:
                raw = jax.lax.slice_in_dim(
                    chunk, leaf.offset, leaf.offset + leaf.nbytes, axis=1)
                val = _bitcast_from_bytes(raw, leaf.dtype)
                # expert units keep their stacked [E, ...] axis even when
                # E == 1 (apply_moe expects the init_moe layout); rest
                # units are per-layer tensors with no unit axis
                val = val.reshape(((u.count,) if u.kind == "expert" else ())
                                  + leaf.shape)
                dst = out
                for k in leaf.path[:-1]:
                    dst = dst.setdefault(k, {})
                dst[leaf.path[-1]] = val
        return out


def build_view_and_slabs(name: str, cfg: ModelConfig, w_tree: Dict, *,
                         slab_bytes: int
                         ) -> Tuple[ModelArenaView, np.ndarray]:
    """Decompose a split FFN tree into (static view, packed host slabs).

    ``w_tree`` is ``split_exec.split_params``' weights-pool half with
    layer-stacked leaves (host numpy).  Returns the view plus the packed
    master copy ``[n_layers, slabs_per_layer, slab_bytes]`` uint8 — the
    HOST-resident source every (re-)upload scatters from.
    """
    layer_leaves = _leaf_paths(w_tree["layers"])
    n_layers = layer_leaves[0][1].shape[0]
    expert = [(p, a) for p, a in layer_leaves if _is_expert_leaf(p, cfg)]
    rest = [(p, a) for p, a in layer_leaves if not _is_expert_leaf(p, cfg)]

    units: List[UnitSpec] = []
    off = 0
    eu = _build_specs("expert", expert, cfg.n_experts, 2, slab_bytes, off)
    if eu is not None:
        units.append(eu)
        off += eu.count * eu.slabs_per_unit
    ru = _build_specs("rest", rest, 1, 1, slab_bytes, off)
    if ru is not None:
        units.append(ru)
        off += ru.slabs_per_unit
    view = ModelArenaView(name, n_layers, tuple(units), off, slab_bytes)

    slabs = np.zeros((n_layers, view.slabs_per_layer, slab_bytes), np.uint8)
    by_path = {p: a for p, a in layer_leaves}
    for u in view.units:
        for leaf in u.leaves:
            arr = np.ascontiguousarray(by_path[leaf.path])
            # [L, count, unit_elems*itemsize] raw bytes of this leaf
            raw = arr.reshape(n_layers, u.count, -1).view(np.uint8)
            span = slabs[:, u.slab_offset:
                         u.slab_offset + u.count * u.slabs_per_unit]
            span = span.reshape(n_layers, u.count,
                                u.slabs_per_unit * slab_bytes)
            span[:, :, leaf.offset:leaf.offset + leaf.nbytes] = raw
    return view, slabs


# ---------------------------------------------------------------------------
# Analytic accounting (planner / Table 1 — no weights needed)
# ---------------------------------------------------------------------------

def _cfg_itemsize(cfg: ModelConfig) -> int:
    return 4 if cfg.dtype == "float32" else 2


def slabs_for_config(cfg: ModelConfig, slab_bytes: int = DEFAULT_SLAB_BYTES
                     ) -> int:
    """Arena slabs a fully resident model needs, from the config alone.

    Mirrors :func:`build_view_and_slabs` geometry: per layer, E expert
    units (3 matrices each) + one rest unit (router [+ shared experts], or
    the whole dense MLP).
    """
    d, isz = cfg.d_model, _cfg_itemsize(cfg)
    n_mats = 3 if cfg.mlp_kind == "swiglu" else 2
    if cfg.is_moe:
        expert_bytes = 3 * d * cfg.d_ff * isz
        rest_bytes = d * cfg.n_experts * 4                 # f32 router
        if cfg.n_shared_experts:
            rest_bytes += 3 * d * cfg.n_shared_experts * cfg.d_ff * isz
        per_layer = (cfg.n_experts * math.ceil(expert_bytes / slab_bytes)
                     + math.ceil(rest_bytes / slab_bytes))
    else:
        per_layer = math.ceil(n_mats * d * cfg.d_ff * isz / slab_bytes)
    return cfg.n_layers * per_layer


def static_ffn_bytes(cfg: ModelConfig) -> int:
    """Per-model-static baseline: the model's full FFN bytes device-resident."""
    return cfg.param_counts()["ffn"] * _cfg_itemsize(cfg)


# ---------------------------------------------------------------------------
# The arena
# ---------------------------------------------------------------------------

@dataclass
class Residency:
    """One resident model's mapping into the arena."""

    slots: np.ndarray              # [n_layers, slabs_per_layer] int32
    uploaded: np.ndarray           # [n_layers] bool (per-layer streaming)
    last_used: int = 0             # LRU clock tick
    rev: int = -1                  # bumped per activation (table cache key)


_ARENA_SCATTER = None
_ARENA_GATHER = None


def _arena_scatter(arena, ids, rows):
    """One donated-buffer scatter of packed slab rows into the arena."""
    global _ARENA_SCATTER
    if _ARENA_SCATTER is None:
        _ARENA_SCATTER = jax.jit(
            lambda a, i, r: a.at[i].set(r),
            donate_argnums=donate_argnums(0))
    return _ARENA_SCATTER(arena, ids, rows)


class WeightArena:
    """Host-side slab allocator over one device-resident weights arena."""

    def __init__(self, *, slab_bytes: int = DEFAULT_SLAB_BYTES, device=None):
        self.slab_bytes = slab_bytes
        self.device = device
        self.slot_budget = 0
        self.arena: Optional[jax.Array] = None
        self.free_list: List[int] = []
        self.views: Dict[str, ModelArenaView] = {}
        self.host_slabs: Dict[str, np.ndarray] = {}
        self.residency: Dict[str, Residency] = {}
        self.pins: Dict[str, int] = {}
        self._clock = 0
        self._rev_counter = 0
        self._table_cache: Dict[str, dict] = {}
        # stats
        self.activations = 0
        self.evictions = 0
        self.layer_uploads = 0
        self.resizes = 0
        # optional observability sink (core.hooks.CoreHooks); every hook
        # fires AFTER the matching stat counter above has been updated
        self.hooks = None

    # ------------------------------------------------------------------
    # registration / allocation
    # ------------------------------------------------------------------
    def add_model(self, name: str, cfg: ModelConfig, w_tree: Dict) -> None:
        """Register a cold model: pack its host master slabs + build the
        static view.  No device memory is touched."""
        view, slabs = build_view_and_slabs(name, cfg, w_tree,
                                           slab_bytes=self.slab_bytes)
        self.views[name] = view
        self.host_slabs[name] = slabs

    def finalize(self, slot_budget: Optional[int] = None, *,
                 allocate: bool = True) -> None:
        """Fix the budget and (optionally) allocate the device arena.

        Default budget = every registered model fully resident — callers
        shrink it to force demand paging of cold models.
        """
        if slot_budget is None:
            slot_budget = max(
                sum(v.total_slabs for v in self.views.values()), 1)
        self.slot_budget = slot_budget
        self.free_list = list(range(slot_budget - 1, -1, -1))
        if allocate:
            arena = jnp.zeros((slot_budget, self.slab_bytes), jnp.uint8)
            self.arena = jax.device_put(arena, self.device) \
                if self.device is not None else arena

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    @property
    def free_slabs(self) -> int:
        return len(self.free_list)

    @property
    def resident_slabs(self) -> int:
        return self.slot_budget - len(self.free_list)

    def device_bytes(self) -> int:
        """Device FFN bytes: fixed by ``slot_budget`` alone."""
        return self.slot_budget * self.slab_bytes

    def host_master_bytes(self) -> int:
        return sum(s.nbytes for s in self.host_slabs.values())

    def is_resident(self, name: str) -> bool:
        return name in self.residency

    def pinned_slabs(self) -> int:
        """Slabs the elastic rebalancer can never reclaim: every pinned
        model's full footprint, resident or promised (an admitted cold
        model's pin is taken BEFORE its activation maps slots)."""
        return sum(self.views[n].total_slabs
                   for n in self.pins if n in self.views)

    def min_slot_budget(self) -> int:
        """Smallest budget a shrink may target: pinned footprints, and
        never below the largest registered model (a smaller arena would
        make that model permanently unserviceable — admission fails
        loudly on it)."""
        largest = max((v.total_slabs for v in self.views.values()),
                      default=1)
        return max(self.pinned_slabs(), largest, 1)

    def residency_by_model(self) -> Dict[str, int]:
        """Resident slab count per model — the slab-timeline source for
        flight-recorder snapshots and Perfetto counter tracks."""
        return {name: int(res.slots.size)
                for name, res in self.residency.items()}

    def utilization(self) -> Dict[str, float]:
        return {
            "slot_budget": self.slot_budget,
            "resident_slabs": self.resident_slabs,
            "free_slabs": self.free_slabs,
            "resident_models": len(self.residency),
            "activations": self.activations,
            "evictions": self.evictions,
            "layer_uploads": self.layer_uploads,
            "device_bytes": self.device_bytes(),
            "occupancy": self.resident_slabs / max(self.slot_budget, 1),
            "pinned_slabs": self.pinned_slabs(),
            "resizes": self.resizes,
        }

    # ------------------------------------------------------------------
    # slow path: activate / evict (atomic)
    # ------------------------------------------------------------------
    def _next_rev(self) -> int:
        self._rev_counter += 1
        return self._rev_counter

    def touch(self, name: str) -> None:
        if name in self.residency:
            self._clock += 1
            self.residency[name].last_used = self._clock

    def pin(self, name: str) -> None:
        self.pins[name] = self.pins.get(name, 0) + 1

    def unpin(self, name: str) -> None:
        n = self.pins.get(name, 0) - 1
        if n <= 0:
            self.pins.pop(name, None)
        else:
            self.pins[name] = n
        self.touch(name)

    def _take(self, n: int) -> List[int]:
        """Atomically pop ``n`` slabs: raises BEFORE mutating any state."""
        if n > len(self.free_list):
            raise OutOfSlabsError(
                f"need {n} slabs, {len(self.free_list)} free "
                f"(budget {self.slot_budget})")
        return [self.free_list.pop() for _ in range(n)]

    def _plan_evictions(self, need: int) -> List[str]:
        """LRU victims whose slabs make ``need`` fit — WITHOUT evicting.

        Raises ``OutOfSlabsError`` (no state change) when even evicting
        every unpinned idle model cannot free enough.
        """
        if need <= self.free_slabs:
            return []
        victims: List[str] = []
        would_free = self.free_slabs
        idle = sorted((r.last_used, n) for n, r in self.residency.items()
                      if n not in self.pins)
        for _, n in idle:
            victims.append(n)
            would_free += self.views[n].total_slabs
            if would_free >= need:
                return victims
        raise OutOfSlabsError(
            f"activation needs {need} slabs; only {would_free} reachable "
            f"after evicting all idle models (budget {self.slot_budget}, "
            f"pinned: {sorted(self.pins)})")

    def activate(self, name: str, *, upload: bool = True) -> Residency:
        """Make a cold model resident: map its slabs (evicting idle LRU
        models under pressure) and optionally upload every layer.

        Atomic: eviction victims are planned BEFORE any state changes and
        the slab count is taken in one ``_take``, so ``OutOfSlabsError``
        leaves the free list, every residency and all pins untouched.
        ``upload=False`` maps slots only — the pipeline's per-layer
        prefetch (or ``ensure_model_uploaded``) streams the bytes in.
        """
        if name in self.residency:
            self.touch(name)
            return self.residency[name]
        view = self.views[name]
        for victim in self._plan_evictions(view.total_slabs):
            self.evict(victim)
        slabs = self._take(view.total_slabs)
        res = Residency(
            slots=np.asarray(slabs, np.int32).reshape(
                view.n_layers, view.slabs_per_layer),
            uploaded=np.zeros(view.n_layers, bool),
            rev=self._next_rev())
        self.residency[name] = res
        self.activations += 1
        self.touch(name)
        if self.hooks is not None:
            self.hooks.arena_activate(name, view.total_slabs)
        if upload:
            self.ensure_model_uploaded(name)
        return res

    def evict(self, name: str) -> None:
        """Return an idle model's slabs to the free list.

        Master bytes live on the host, so eviction copies nothing back;
        re-activation reproduces the identical weights.
        """
        if name in self.pins:
            raise ValueError(f"cannot evict pinned model {name!r}")
        res = self.residency.pop(name)
        self.free_list.extend(int(s) for s in res.slots.ravel())
        self._table_cache.pop(name, None)
        self.evictions += 1
        if self.hooks is not None:
            self.hooks.arena_evict(name, res.slots.size)

    # ------------------------------------------------------------------
    # elastic boundary: live resize (DESIGN.md §8)
    # ------------------------------------------------------------------
    def resize(self, new_budget: int) -> Dict[str, int]:
        """Grow or shrink ``slot_budget`` at a step boundary.

        Growing copies the arena into the prefix of a larger buffer and
        prepends fresh ids to the (pop-from-the-end) free list, so low
        slabs keep being preferred deterministically.  Shrinking evicts
        idle unpinned models LRU until the survivors fit, then compacts
        every surviving residency into the retained prefix with ONE
        jitted gather and bumps each residency's rev (slot-table caches
        refresh; host masters are untouched, so the moved bytes stay
        bit-exact).  Raises ``OutOfSlabsError`` when pinned residents
        alone exceed the new budget — no state changes beyond completed
        evictions.
        """
        new_budget = int(new_budget)
        check(new_budget >= 1, f"slot budget must be >= 1, got {new_budget}")
        old_budget = self.slot_budget
        if new_budget == old_budget:
            return {"slot_budget": old_budget, "evicted": 0, "moved": 0}
        if new_budget > old_budget:
            if self.arena is not None:
                pad = jnp.zeros((new_budget - old_budget, self.slab_bytes),
                                self.arena.dtype)
                self.arena = jnp.concatenate([self.arena, pad], axis=0)
            self.free_list = list(range(new_budget - 1, old_budget - 1, -1)) \
                + self.free_list
            self.slot_budget = new_budget
            self.resizes += 1
            if self.hooks is not None:
                self.hooks.arena_resize(old_budget, new_budget, 0, 0)
            return {"slot_budget": new_budget, "evicted": 0, "moved": 0}

        # --- shrink: evict idle LRU until the survivors fit -------------
        evicted = 0
        while self.resident_slabs > new_budget:
            idle = sorted((r.last_used, n) for n, r in self.residency.items()
                          if n not in self.pins)
            if not idle:
                raise OutOfSlabsError(
                    f"cannot shrink arena to {new_budget} slabs: "
                    f"{self.resident_slabs} resident and every resident "
                    f"model is pinned (pinned: {sorted(self.pins)})")
            self.evict(idle[0][1])
            evicted += 1
        # compact survivors into [0, new_budget) in deterministic order
        old_ids: List[int] = []
        for name in sorted(self.residency):
            old_ids.extend(int(s) for s in self.residency[name].slots.ravel())
        k = len(old_ids)
        perm = np.zeros(new_budget, np.int32)
        perm[:k] = np.asarray(old_ids, np.int32) if k else []
        if self.arena is not None:
            global _ARENA_GATHER
            if _ARENA_GATHER is None:
                _ARENA_GATHER = jax.jit(lambda a, i: a[i])
            self.arena = _ARENA_GATHER(self.arena, jnp.asarray(perm))
        next_id = 0
        for name in sorted(self.residency):
            res = self.residency[name]
            n = res.slots.size
            res.slots = np.arange(next_id, next_id + n,
                                  dtype=np.int32).reshape(res.slots.shape)
            res.rev = self._next_rev()
            next_id += n
        self.free_list = list(range(new_budget - 1, k - 1, -1))
        self.slot_budget = new_budget
        self.resizes += 1
        if self.hooks is not None:
            self.hooks.arena_resize(old_budget, new_budget, evicted, k)
        return {"slot_budget": new_budget, "evicted": evicted, "moved": k}

    # ------------------------------------------------------------------
    # uploads (slow path, but overlappable with compute)
    # ------------------------------------------------------------------
    def _upload_layers(self, name: str, layers: np.ndarray) -> None:
        res = self.residency[name]
        if self.arena is not None:
            ids = res.slots[layers].reshape(-1)
            rows = self.host_slabs[name][layers].reshape(-1, self.slab_bytes)
            self.arena = _arena_scatter(self.arena, jnp.asarray(ids),
                                        jnp.asarray(rows))
        res.uploaded[layers] = True
        self.layer_uploads += len(layers)
        if self.hooks is not None:
            self.hooks.arena_upload(
                name, len(layers) * self.views[name].slabs_per_layer)

    def prefetch_layer(self, name: str, layer: int) -> None:
        """Issue (async) the upload of one layer's slabs; no-op if already
        uploaded or out of range — the pipeline calls this for layer L+1
        while layer L's attention is in flight."""
        res = self.residency.get(name)
        if res is None or layer < 0 or layer >= len(res.uploaded) \
                or res.uploaded[layer]:
            return
        self._upload_layers(name, np.asarray([layer]))

    def ensure_model_uploaded(self, name: str) -> None:
        """Upload every not-yet-streamed layer (one scatter)."""
        res = self.residency[name]
        missing = np.flatnonzero(~res.uploaded)
        if len(missing):
            self._upload_layers(name, missing)

    def acquire(self, name: str) -> Tuple[jax.Array, jax.Array]:
        """(arena buffer, slot table) with ``name`` resident and uploaded —
        the one residency protocol every decode step goes through.

        ``activate`` is a host-side no-op (LRU touch) when the model is
        already resident; a cold call activates it on first use.
        """
        self.activate(name)
        self.ensure_model_uploaded(name)
        return self.arena, self.slot_table(name)

    # ------------------------------------------------------------------
    # fast path: device slot tables
    # ------------------------------------------------------------------
    def slot_table(self, name: str) -> jax.Array:
        """[n_layers, slabs_per_layer] int32 device table, cached per
        activation rev (re-activation remaps -> re-upload)."""
        res = self.residency.get(name)
        if res is None:
            raise KeyError(f"model {name!r} is not resident in the arena")
        entry = self._table_cache.get(name)
        if entry is not None and entry["rev"] == res.rev:
            return entry["dev"]
        dev = jnp.asarray(res.slots)
        if self.device is not None:
            dev = jax.device_put(dev, self.device)
        self._table_cache[name] = {"rev": res.rev, "dev": dev}
        return dev
