"""Training substrate: optimizer, train step, checkpointing, data, and
gradient compression."""
