"""Checkable invariants (DESIGN.md §12): every rule must actually fire.

Three legs, one deliberate violation per rule:

* lint (CP001..CP007): each rule is fed a minimal source snippet at a
  repo-shaped fake path containing exactly its violation and must report
  exactly that rule id; the pragma escape hatch suppresses it; the REAL
  tree lints clean (the CI gate, asserted here too so a regression fails
  tier-1 before it fails CI);
* jaxpr/HLO audit (CPA01..CPA04): closure capture is caught on a traced
  function, and each HLO check fires on a synthetic module exhibiting
  its violation — plus the donation parser round-trips a real compiled
  donated program;
* shadow sanitizer (SAN01..SAN08): each invariant is violated by
  corrupting a real ``KVVirtualizer``/``WeightArena`` and ``audit()``
  must raise ``PoolSanitizerError`` with that rule id; an engine run
  with the sanitizer attached produces the bit-exact token stream of a
  detached run and reports zero violations.
"""
import os
from types import SimpleNamespace

import numpy as np
import pytest

from repro.analysis.lint import default_roots, lint_paths, lint_source
from repro.analysis import jaxpr_audit as ja
from repro.analysis.sanitizer import PoolSanitizer, PoolSanitizerError
from repro.configs import EngineConfig, PAPER_COLOC_SET, get_smoke_config
from repro.core.virtualizer import KVVirtualizer
from repro.core.weight_pool import Residency, WeightArena

MODEL = sorted(PAPER_COLOC_SET)[0]


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# lint: one deliberate violation per rule
# ---------------------------------------------------------------------------

def test_cp001_host_sync_in_jitted_body():
    src = (
        "import jax\n"
        "def step(x):\n"
        "    y = jax.device_get(x)\n"
        "    return y\n"
        "fast = jax.jit(step)\n"
    )
    assert rules_of(lint_source(src, "src/repro/core/control.py")) \
        == ["CP001"]


def test_cp001_block_until_ready_in_scanned_body():
    src = (
        "import jax\n"
        "def body(c, x):\n"
        "    x.block_until_ready()\n"
        "    return c, x\n"
        "out = jax.lax.scan(body, 0, xs)\n"
    )
    assert "CP001" in rules_of(lint_source(src, "src/repro/core/control.py"))


def test_cp002_sampling_outside_sampler():
    src = "import jax.numpy as jnp\ntok = jnp.argmax(logits, -1)\n"
    assert rules_of(lint_source(src, "src/repro/runtime/engine.py")) \
        == ["CP002"]
    # the canonical module itself is exempt
    assert lint_source(src, "src/repro/runtime/sampler.py") == []


def test_cp003_counter_bump_without_hook():
    src = (
        "class KVVirtualizer:\n"
        "    def swap_out(self, n):\n"
        "        self.swap_out_pages += n\n"
        "        return n\n"
    )
    assert rules_of(lint_source(src, "src/repro/core/virtualizer.py")) \
        == ["CP003"]


def test_cp003_satisfied_by_adjacent_hook():
    src = (
        "class KVVirtualizer:\n"
        "    def swap_out(self, n):\n"
        "        self.swap_out_pages += n\n"
        "        if self.hooks is not None:\n"
        "            self.hooks.kv_swap_out(n)\n"
        "        return n\n"
    )
    assert lint_source(src, "src/repro/core/virtualizer.py") == []


def test_cp004_loose_engine_kwargs():
    src = "eng = CrossPoolEngine(models, mode=EngineMode(), seed=0)\n"
    assert rules_of(lint_source(src, "benchmarks/new_bench.py")) == ["CP004"]
    ok = "eng = CrossPoolEngine(models, config=EngineConfig(), seed=0)\n"
    assert lint_source(ok, "benchmarks/new_bench.py") == []


def test_cp005_adhoc_percentile():
    src = "import numpy as np\np99 = np.percentile(xs, 99)\n"
    assert rules_of(lint_source(src, "src/repro/runtime/engine.py")) \
        == ["CP005"]
    assert lint_source(src, "benchmarks/_stats.py") == []


def test_cp006_wall_clock_in_engine():
    src = "import time\nt0 = time.perf_counter()\n"
    assert rules_of(lint_source(src, "src/repro/runtime/engine.py")) \
        == ["CP006"]
    # same call outside the clock-scoped paths is fine
    assert lint_source(src, "benchmarks/new_bench.py") == []


def test_cp007_bare_assert_in_accounting():
    src = "def f(n):\n    assert n >= 0\n    return n\n"
    assert rules_of(lint_source(src, "src/repro/core/virtualizer.py")) \
        == ["CP007"]
    assert lint_source(src, "src/repro/runtime/engine.py") == []


def test_pragma_suppresses_and_is_line_scoped():
    src = (
        "import time\n"
        "t0 = time.perf_counter()  # cp: allow(CP006) dispatch duration\n"
        "t1 = time.perf_counter()\n"
    )
    found = lint_source(src, "src/repro/runtime/engine.py")
    assert [f.line for f in found] == [3]


def test_syntax_error_reports_cp000():
    assert rules_of(lint_source("def f(:\n", "src/repro/core/x.py")) \
        == ["CP000"]


def test_real_tree_lints_clean():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    findings = lint_paths(default_roots(repo))
    assert findings == [], "\n".join(str(f) for f in findings)


# ---------------------------------------------------------------------------
# jaxpr/HLO audit: one violation per check
# ---------------------------------------------------------------------------

def test_cpa01_closure_captured_constant():
    import jax.numpy as jnp

    baked = jnp.zeros((64, 1024), jnp.float32)       # 256 KiB constant

    def leaky(x):
        return x + baked

    found = ja.audit_closure(leaky, (jnp.zeros((64, 1024), jnp.float32),))
    assert [f.check for f in found] == ["CPA01"]

    def clean(x, pool):
        return x + pool

    assert ja.audit_closure(
        clean, (jnp.zeros((4,)), jnp.zeros((4,)))) == []


SYNTH_NO_ALIAS = """\
HloModule m, entry_computation_layout={(f32[4]{0})->f32[4]{0}}

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4] parameter(0)
  ROOT %r = f32[4] add(%p0, %p0)
}
"""

SYNTH_ALIASED = """\
HloModule m, input_output_alias={ {0}: (4, {}, may-alias) }, \
entry_computation_layout={(f32[4]{0})->f32[4]{0}}

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4] parameter(0)
  %w.1 = f32[4] while(%p0), condition=%cond, body=%body, \
backend_config={"known_trip_count":{"n":"4"}}
  ROOT %r = f32[4] add(%w.1, %w.1)
}

%body (b0: f32[4]) -> f32[4] {
  %b0 = f32[4] parameter(0)
  %w.2 = f32[4] while(%b0), condition=%cond2, body=%body2, \
backend_config={"known_trip_count":{"n":"2"}}
  ROOT %rb = f32[4] add(%w.2, %b0)
}

%body2 (c0: f32[4]) -> f32[4] {
  %c0 = f32[4] parameter(0)
  ROOT %rc = f32[4] add(%c0, %c0)
}
"""

SYNTH_TRANSFER = """\
HloModule m, input_output_alias={ {0}: (0, {}, may-alias) }, \
entry_computation_layout={(f32[4]{0})->f32[4]{0}}

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4] parameter(0)
  %t = token[] after-all()
  %s = (f32[4], u32[], token[]) send(%p0, %t), channel_id=1
  %w.1 = f32[4] while(%p0), condition=%cond, body=%body, \
backend_config={"known_trip_count":{"n":"2"}}
  ROOT %r = f32[4] add(%w.1, %w.1)
}

%body (b0: f32[4]) -> f32[4] {
  %b0 = f32[4] parameter(0)
  ROOT %rb = f32[4] add(%b0, %b0)
}
"""


def test_cpa02_dropped_donation_on_synthetic_hlo():
    found = ja.audit_hlo(SYNTH_NO_ALIAS, pool_param=0, n_layers=2, k=1,
                         expect_donation=True)
    assert "CPA02" in [f.check for f in found]
    # never requested -> never "dropped"
    found = ja.audit_hlo(SYNTH_NO_ALIAS, pool_param=0, n_layers=2, k=1,
                         expect_donation=False)
    assert "CPA02" not in [f.check for f in found]


def test_cpa03_host_transfer_on_synthetic_hlo():
    found = ja.audit_hlo(SYNTH_TRANSFER, pool_param=0, n_layers=2, k=2)
    assert "CPA03" in [f.check for f in found]


def test_cpa04_dispatch_structure():
    # K=4 over a 2-layer scan: the aliased module has exactly that shape
    assert ja.audit_hlo(SYNTH_ALIASED, pool_param=4, n_layers=2, k=4) == []
    # claiming K=8 must fail structurally
    found = ja.audit_hlo(SYNTH_ALIASED, pool_param=4, n_layers=2, k=8)
    assert [f.check for f in found] == ["CPA04"]
    # a module with no while at all fails the K=1 layer-scan claim too
    found = ja.audit_hlo(SYNTH_NO_ALIAS, pool_param=0, n_layers=2, k=1,
                         expect_donation=False)
    assert [f.check for f in found] == ["CPA04"]


def test_alias_parser_roundtrips_real_donated_program():
    import jax
    import jax.numpy as jnp

    from repro.launch import hlo_analysis as ha

    f = jax.jit(lambda p, x: p.at[0].add(x), donate_argnums=(0,))
    hlo = f.lower(jnp.zeros((8, 4)), jnp.ones((4,))).compile().as_text()
    assert ha.donated_params(hlo) == [0]
    g = jax.jit(lambda p, x: p + x)
    hlo = g.lower(jnp.zeros((8, 4)), jnp.ones((4,))).compile().as_text()
    assert ha.donated_params(hlo) == []


# ---------------------------------------------------------------------------
# sanitizer: one corruption per invariant
# ---------------------------------------------------------------------------

def make_virt(budget=32):
    virt = KVVirtualizer({MODEL: get_smoke_config(MODEL)},
                         page_budget=budget, page_bytes=4096,
                         allocate_device_pool=False)
    virt.register_request(0, MODEL, 8)
    return virt


def expect_rule(san, rule):
    with pytest.raises(PoolSanitizerError) as ei:
        san.audit()
    assert ei.value.rule == rule, str(ei.value)


def test_sanitizer_clean_audit():
    virt = make_virt()
    san = PoolSanitizer(virt)
    san.audit()
    assert san.audits == 1


def test_san01_page_free_and_mapped():
    virt = make_virt()
    mapped = virt.requests[0].tables[0][0]
    virt.free_list.append(mapped)          # aliased: free AND mapped
    san = PoolSanitizer(virt)
    expect_rule(san, "SAN01")


def test_san01_double_free():
    virt = make_virt()
    virt.free_list.append(virt.free_list[0])
    san = PoolSanitizer(virt)
    expect_rule(san, "SAN01")


def test_san02_page_leak():
    virt = make_virt()
    virt.free_list.pop()                   # page conjured away
    san = PoolSanitizer(virt)
    expect_rule(san, "SAN02")


def test_san03_refcount_drift():
    virt = make_virt()
    virt._refs[virt.requests[0].tables[0][0]] = 5
    san = PoolSanitizer(virt)
    expect_rule(san, "SAN03")


def test_san04_swap_tier_drift():
    virt = make_virt()
    assert virt.swap_out(0) > 0
    virt.swapped_now += 1
    san = PoolSanitizer(virt)
    expect_rule(san, "SAN04")


def test_san04_swap_slot_aliased_free_and_used():
    virt = make_virt()
    assert virt.swap_out(0) > 0
    _, _, slot = next(virt.requests[0].swapped_entries())
    virt.swap_free.append(slot)
    san = PoolSanitizer(virt)
    expect_rule(san, "SAN04")


def test_san05_commit_outran_reservation():
    virt = make_virt()
    view = virt.views[MODEL]
    virt.requests[0].tokens += view.tokens_per_page * 4   # phantom commit
    san = PoolSanitizer(virt)
    expect_rule(san, "SAN05")


def test_san05_ragged_layer_tables():
    virt = make_virt()
    tabs = virt.requests[0].tables
    if len(tabs) < 2:
        pytest.skip("model has a single KV layer")
    tabs[0].append(tabs[1].pop())          # pages conserved, tables ragged
    san = PoolSanitizer(virt)
    expect_rule(san, "SAN05")


def make_arena():
    arena = WeightArena(slab_bytes=4096)
    arena.views = {"m": SimpleNamespace(total_slabs=2, n_layers=1,
                                        slabs_per_layer=2)}
    arena.finalize(4, allocate=False)
    slabs = arena._take(2)
    arena.residency["m"] = Residency(
        slots=np.asarray(slabs, np.int32).reshape(1, 2),
        uploaded=np.zeros(1, bool), rev=1)
    return arena


def test_san06_unpin_before_finish():
    virt = make_virt()
    arena = make_arena()
    adm = SimpleNamespace(inflight={"m": 1})
    san = PoolSanitizer(virt, arena=arena, admission=adm)
    expect_rule(san, "SAN06")              # in flight, zero pins
    arena.pin("m")
    san.audit()                            # pinned -> clean


def test_san07_counter_bump_without_matching_hook():
    virt = make_virt()
    san = PoolSanitizer(virt)
    virt.hooks = san
    virt.swap_out_pages += 3               # drift injected behind the hook
    with pytest.raises(PoolSanitizerError) as ei:
        virt.swap_out(0)
    assert ei.value.rule == "SAN07"


def test_san08_arena_slab_aliased():
    virt = make_virt()
    arena = make_arena()
    san = PoolSanitizer(virt, arena=arena)
    san.audit()
    arena.free_list.append(int(arena.residency["m"].slots.ravel()[0]))
    expect_rule(san, "SAN08")


# ---------------------------------------------------------------------------
# engine integration: attached sanitizer is invisible in the streams
# ---------------------------------------------------------------------------

def run_engine(sanitize):
    import jax
    from repro.runtime.engine import CrossPoolEngine, EngineMode
    from repro.runtime.request import Request

    models = {MODEL: get_smoke_config(MODEL).replace(dtype="float32")}
    eng = CrossPoolEngine(
        models, page_budget=128, page_bytes=4096, slab_bytes=4096,
        max_batch=2, max_ctx=64,
        config=EngineConfig(mode=EngineMode(pipeline=True, lowering=True),
                            sanitize=sanitize),
        seed=0)
    streams = {}
    for i in range(3):
        req = Request(request_id=i, model=MODEL, prompt_tokens=4,
                      max_new_tokens=4, arrival_time=0.0)
        eng.submit(req, on_token=lambda e: streams.setdefault(
            e.request_id, []).append(e.token))
    eng.drain()
    return eng, streams


def test_sanitized_engine_streams_bit_exact(monkeypatch):
    # the CI sanitized leg exports CROSSPOOL_SANITIZE=1, which would
    # attach a sanitizer to the "off" engine too — clear it so this test
    # compares a genuinely detached engine against an attached one
    monkeypatch.delenv("CROSSPOOL_SANITIZE", raising=False)
    eng_off, streams_off = run_engine(False)
    eng_on, streams_on = run_engine(True)
    assert eng_off.sanitizer is None
    assert eng_on.sanitizer is not None
    assert streams_on == streams_off       # pure checking, zero behavior
    assert eng_on.sanitizer.audits > 0
    assert eng_on.sanitizer.events > 0


def test_env_var_attaches_sanitizer(monkeypatch):
    from repro.runtime.engine import CrossPoolEngine

    monkeypatch.setenv("CROSSPOOL_SANITIZE", "1")
    models = {MODEL: get_smoke_config(MODEL).replace(dtype="float32")}
    eng = CrossPoolEngine(models, page_budget=64, page_bytes=4096,
                          slab_bytes=4096, max_batch=1, max_ctx=32, seed=0)
    assert eng.sanitizer is not None
