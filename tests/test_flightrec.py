"""Flight recorder + deterministic replay acceptance (ISSUE 10).

* a session with elastic rebalancing, prefix caching, and K=4 multi-step
  decode — plus mid-session cancel and ``reset_stats`` — records a
  flight record that replays BIT-EXACTLY (token streams, event ring,
  rebalance decisions, pool snapshots, final accounting) in BOTH
  lowering modes, including in a fresh process via
  ``python -m repro.launch.replay``;
* induced pool corruption (``inject_corruption``) auto-dumps an incident
  record that the replayer reproduces to the same failing step and
  sanitizer rule;
* the recorder-off / observer-off path stays bit-exact with the fully
  instrumented one;
* record hygiene: causal drops are refused, version is checked.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.analysis.sanitizer import PoolSanitizerError
from repro.configs import (CacheConfig, ElasticConfig, EngineConfig,
                           FlightRecorderConfig, SLObjective, SLOConfig,
                           get_smoke_config)
from repro.runtime import flightrec
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime.request import Request
from repro.launch import replay as replay_mod

MOE, MLA = "qwen3-moe-235b-a22b", "minicpm3-4b"


def _models():
    return {n: get_smoke_config(n).replace(dtype="float32")
            for n in (MOE, MLA)}


def _config(lowering, *, flightrec_on=True, slo=False, sanitize=False,
            dump_path=None):
    return EngineConfig(
        mode=EngineMode(pipeline=True, lowering=lowering,
                        decode_steps_per_dispatch=4),
        elastic=ElasticConfig(interval_steps=2, cooldown_steps=2,
                              window_s=8.0),
        cache=CacheConfig(enabled=True),
        sanitize=sanitize,
        slo=(SLOConfig(objectives={MOE: SLObjective(ttft_ms=1e-3,
                                                    tbt_p99_ms=1e-3)},
                       window_s=4.0, short_window_s=0.5) if slo else None),
        flightrec=(FlightRecorderConfig(ring_size=65536,
                                        snapshot_interval_steps=2,
                                        dump_path=dump_path)
                   if flightrec_on else None))


def _engine(lowering, observer=None, **cfg_kw):
    return CrossPoolEngine(_models(), page_budget=2048, page_bytes=4096,
                           slab_bytes=4096, max_batch=2, max_ctx=64, seed=0,
                           config=_config(lowering, **cfg_kw),
                           observer=observer)


def _requests(models):
    """Real prompt ids with a shared per-model system prefix, so the
    radix cache gets hits — constructed from a fixed seed so every
    engine in a test sees the identical workload."""
    rng = np.random.default_rng(7)
    system = {n: rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
              for n, cfg in models.items()}

    def mk(rid, model, n_prompt, n_new, t):
        tail = rng.integers(0, models[model].vocab_size,
                            max(0, n_prompt - 32)).astype(np.int32)
        ids = np.concatenate([system[model], tail])[:n_prompt]
        return Request(rid, model, n_prompt, n_new, t,
                       prompt_ids=ids.astype(np.int32))

    return [mk(0, MOE, 40, 6, 0.0), mk(1, MLA, 36, 6, 0.0),
            mk(2, MOE, 40, 12, 0.1),       # shares r0's full prompt
            mk(3, MLA, 44, 6, 0.3), mk(4, MOE, 38, 4, 0.5)]


def _drive(engine):
    """A representative session: staggered submits, multi-step decode,
    a cancel landing mid-decode from an on_token callback, a stats
    reset, and a drain to quiescence."""
    reqs = _requests(_models())
    h0 = engine.submit(reqs[0])
    engine.submit(reqs[1])
    engine.step(0.05)
    engine.advance(0.1)
    engine.submit(reqs[2])
    victim = engine.submit(reqs[3],
                           on_token=lambda ev: engine.cancel(victim))
    engine.step()
    engine.submit(reqs[4])
    for _ in range(40):
        if not engine.busy:
            break
        engine.step()
    engine.cancel(h0)            # no-op terminal cancel, still an op
    return engine.finalize()


@pytest.mark.parametrize("lowering", [True, False],
                         ids=["lowered", "interpret"])
def test_record_replay_bit_exact(lowering):
    engine = _engine(lowering)
    _drive(engine)
    record = json.loads(json.dumps(engine.recorder.to_record()))
    assert record["version"] == flightrec.RECORD_VERSION
    assert not flightrec.causal_drops(record)
    kinds = {e["kind"] for e in record["events"]}
    assert {"op", "clock", "commit"} <= kinds
    assert "cache_hit" in kinds, "shared prefix should hit the radix cache"
    assert record["snapshots"], "interval-2 snapshots should have fired"
    assert record["streams"], "token streams should have been captured"

    report = replay_mod.replay(record)
    assert report.ok, report.mismatches
    assert report.tokens > 0 and report.steps > 0


def test_replay_fresh_process(tmp_path):
    engine = _engine(True)
    _drive(engine)
    path = tmp_path / "flight.json"
    engine.recorder.dump(str(path))
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.replay", str(path)],
        capture_output=True, text=True, env=env, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "BIT-EXACT" in proc.stdout


@pytest.mark.parametrize("kind", flightrec.INJECTION_KINDS)
def test_corruption_record_replays_to_same_step(tmp_path, kind):
    path = tmp_path / f"incident_{kind}.json"
    engine = _engine(True, sanitize=True, dump_path=str(path))
    reqs = _requests(_models())
    engine.submit(reqs[0])
    engine.submit(reqs[2])     # 12 new tokens: still decoding at injection
    engine.step(0.05)
    engine.step()
    flightrec.inject_corruption(engine, kind)
    with pytest.raises(PoolSanitizerError) as exc:
        engine.step()
    assert path.exists(), "incident should auto-dump the black box"
    record = replay_mod.load_record(str(path))
    failure = record["failure"]
    assert failure["type"] == "PoolSanitizerError"
    assert failure["rule"] == exc.value.rule
    assert failure["step"] == engine._step_index

    report = replay_mod.replay(record)
    assert report.failure_reproduced, report.mismatches
    assert report.ok, report.mismatches


def test_recorder_off_path_bit_exact():
    """observer=None + flightrec=None + slo=None must not perturb the
    session: token ids AND virtual timestamps identical to the fully
    instrumented engine's."""
    from repro.runtime.observe import EngineObserver

    instrumented = _engine(True, observer=EngineObserver(), slo=True)
    bare = _engine(True, flightrec_on=False)
    assert bare.recorder is None and bare.slo is None

    # identical workloads; the bare engine re-uses the instrumented run's
    # recorded dispatch-duration stream so virtual timestamps compare
    # exactly (real perf_counter readings differ run to run)
    stats_a = _drive(instrumented)
    bare.attach_replay_clock(
        flightrec.record_clock(instrumented.recorder.to_record()))
    stats_b = _drive(bare)
    streams_a = {rid: (h.request.output_ids, h.request.token_times)
                 for rid, h in instrumented.handles.items()}
    streams_b = {rid: (h.request.output_ids, h.request.token_times)
                 for rid, h in bare.handles.items()}
    assert streams_a == streams_b
    assert stats_a.tokens_out == stats_b.tokens_out
    assert instrumented.slo.breach_count() > 0   # and it saw real breaches


def test_replay_refuses_causal_drops(tmp_path):
    engine = _engine(True)
    record = engine.recorder.to_record()
    record["dropped"] = {"op": 3, "cache_hit": 5}
    path = tmp_path / "dropped.json"
    path.write_text(json.dumps(record))
    with pytest.raises(replay_mod.ReplayError, match="causal"):
        replay_mod.load_record(str(path))
    # informational drops alone are fine: the causal stream is intact
    record["dropped"] = {"cache_hit": 5}
    path.write_text(json.dumps(record))
    assert replay_mod.load_record(str(path))["dropped"] == {"cache_hit": 5}


def test_record_version_guard(tmp_path):
    engine = _engine(True)
    record = engine.recorder.to_record()
    record["version"] = 999
    path = tmp_path / "bad.json"
    path.write_text(json.dumps(record))
    with pytest.raises(replay_mod.ReplayError, match="version"):
        replay_mod.load_record(str(path))
