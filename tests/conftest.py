"""Test-suite bootstrap.

Prefers the real ``hypothesis`` (installed in CI via requirements-dev.txt);
falls back to the deterministic stub in ``_hypothesis_fallback`` so the
property tests still collect and run in hermetic environments.
"""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_fallback
    _hypothesis_fallback.install()
