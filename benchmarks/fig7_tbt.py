"""Fig. 7: decode-side TBT P95/P99 on ShareGPT-like traffic, 0.2-1.0 RPS.

Discrete-event simulation of the paper's five-GPU testbed for the three
systems.  Reports per-model P95/P99 TBT and the kvcached/crosspool P99
ratio (the paper reports up to 10.4x at 0.8 RPS).
"""
from __future__ import annotations

import copy

import numpy as np

from benchmarks._stats import percentile
from repro.configs import PAPER_COLOC_SET, get_config
from repro.runtime import observe as trace_mod
from repro.runtime.simulator import DecodeSimulator, paper_placements

RATES = (0.2, 0.4, 0.6, 0.8, 1.0)

# multi-step decode: "crosspool-k4" commits 4 tokens per persistent
# dispatch (EngineMode.decode_steps_per_dispatch=4), amortizing the
# launch cost; all pool/placement bytes are identical to "crosspool"
SYSTEMS = ("static", "kvcached", "crosspool", "crosspool-k4")


def _placement(models, system):
    if system == "crosspool-k4":
        return paper_placements(models, "crosspool", decode_steps=4)
    return paper_placements(models, system)


def run(csv=print, horizon_s: float = 150.0, seed: int = 0) -> dict:
    models = {n: get_config(n) for n in PAPER_COLOC_SET}
    out = {}
    for rps in RATES:
        proto = trace_mod.make_requests(
            list(models), rps_per_model=rps, horizon_s=horizon_s,
            kind="sharegpt", seed=seed)
        for system in SYSTEMS:
            reqs = copy.deepcopy(proto)
            pl = _placement(models, system)
            res = DecodeSimulator(models, pl).run(reqs)
            p95 = percentile(res["tbt"], 95)
            p99 = percentile(res["tbt"], 99)
            # tokens/sec/device roofline column: served decode tokens per
            # wall second per testbed GPU (5-GPU testbed, same horizon for
            # every system, so the column is comparable across rows)
            tps_dev = res["tokens_out"] / horizon_s / 5.0
            out[(system, rps)] = (p95, p99, tps_dev, res["per_model_tbt"])
            csv(f"fig7,{system},rps={rps},p95_ms={p95 * 1e3:.2f},"
                f"p99_ms={p99 * 1e3:.2f},tok_s_dev={tps_dev:.2f},"
                f"finished={res['finished']}")
    # headline: P99 reduction of crosspool vs kvcached at 0.8 RPS per model
    for rps in (0.8, 1.0):
        for name in models:
            kv = percentile(out[("kvcached", rps)][3][name], 99)
            xp = percentile(out[("crosspool", rps)][3][name], 99)
            if np.isfinite(kv) and np.isfinite(xp) and xp > 0:
                csv(f"fig7,p99_reduction,{name},rps={rps},"
                    f"{kv / xp:.2f}x")
    p99_kv = out[("kvcached", 0.8)][1]
    p99_xp = out[("crosspool", 0.8)][1]
    assert p99_xp < p99_kv, "crosspool must beat kvcached tail at 0.8 RPS"
    # multi-step never hurts the tail: the only modelled delta is the
    # amortized dispatch, so K=4 must be <= K=1 at every rate
    for rps in RATES:
        assert out[("crosspool-k4", rps)][1] <= out[("crosspool", rps)][1], \
            f"crosspool-k4 P99 regressed vs crosspool at {rps} RPS"
    return {k: v[:3] for k, v in out.items()}


if __name__ == "__main__":
    run()
