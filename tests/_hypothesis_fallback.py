"""Minimal stand-in for ``hypothesis`` when it is not installed.

CI installs the real hypothesis (see requirements-dev.txt); this fallback
keeps the property tests COLLECTIBLE and RUNNING in hermetic environments
where third-party installs are unavailable.  It implements just the
strategy surface the test-suite uses (integers / floats / sampled_from /
lists / tuples) and drives each ``@given`` test with a deterministic,
seeded sample sweep instead of hypothesis's adaptive search + shrinking.

Registered from ``conftest.py`` ONLY when ``import hypothesis`` fails.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

_FALLBACK_EXAMPLES = 10     # per-test cap; keeps the sweep cheap


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def lists(elements, *, min_size=0, max_size=10, **_kw):
    return _Strategy(
        lambda rng: [elements.example(rng)
                     for _ in range(rng.randint(min_size, max_size))])


def tuples(*elements):
    return _Strategy(lambda rng: tuple(e.example(rng) for e in elements))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def composite(fn):
    """hypothesis-style ``@st.composite``: ``fn(draw, ...)`` becomes a
    strategy factory; ``draw`` resolves nested strategies recursively."""
    def builder(*args, **kwargs):
        return _Strategy(
            lambda rng: fn(lambda s: s.example(rng), *args, **kwargs))
    return builder


def settings(max_examples=None, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            limit = getattr(wrapper, "_fallback_max_examples", None) \
                or getattr(fn, "_fallback_max_examples", None) \
                or _FALLBACK_EXAMPLES
            rng = random.Random(0)
            for _ in range(min(limit, _FALLBACK_EXAMPLES)):
                pos = tuple(s.example(rng) for s in arg_strategies)
                kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*args, *pos, **kwargs, **kw)
        # hide the strategy-supplied params from pytest's fixture resolution
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items()
                  if name not in kw_strategies]
        if arg_strategies:
            keep = len(params) - len(arg_strategies)
            params = params[:keep]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper
    return deco


def install() -> None:
    """Register fake ``hypothesis`` / ``hypothesis.strategies`` modules."""
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "lists", "tuples",
                 "booleans", "composite"):
        setattr(st_mod, name, globals()[name])
    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.__is_fallback__ = True
    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
