"""Roofline derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device on the
partitioned module).  Collective bytes are NOT in cost_analysis: we parse
the post-partitioning HLO text and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instance (per-device payload; ring-algorithm wire bytes are ~(n-1)/n of
this, so the term is a slight over-estimate — consistent across cells).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.  "bf16[16,4096]{1,0} all-gather(" including tuple shapes
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one shape token like 'bf16[16,4096]'."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str, loop_factor: int = 1) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the whole module.

    XLA cost analysis (and a naive text scan) counts a while-loop body ONCE,
    but a scan-over-layers body executes ``loop_factor`` times.  Collectives
    in non-ENTRY computations (loop bodies) are therefore multiplied by
    ``loop_factor``; ENTRY-level collectives (e.g. the post-accumulation
    gradient reduction) count once.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    in_entry = False
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if raw.startswith("ENTRY"):
            in_entry = True
            continue
        if raw.startswith("}"):
            in_entry = False
            continue
        for kind in _COLLECTIVES:
            marker = f" {kind}("
            if marker not in line or "=" not in line:
                continue
            lhs, rhs = line.split("=", 1)
            rhs = rhs.strip()
            # result shape(s) precede the op name
            head = rhs.split(marker)[0].strip()
            total = 0
            for m in _SHAPE_RE.finditer(head):
                total += _shape_bytes(m.group(0))
            scale = 1 if in_entry else loop_factor
            out[kind] += total * scale
            out["count"] += 1
            break
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    strategy: str
    chips: int
    flops_per_device: float
    bytes_per_device: float                  # HLO 'bytes accessed' (raw)
    bytes_model: float                       # analytic minimum HBM traffic
    collective_per_device: float
    collective_breakdown: Dict[str, int]
    model_flops: float                       # 6*N*D (or active) for train;
    #                                          2*N_active*tokens for serving
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    link_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def t_memory_hlo(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def t_memory(self) -> float:
        """Memory term: analytic minimum traffic (weights + KV + optimizer
        + activations actually touched per step, per device).  The HLO
        'bytes accessed' number is reported alongside but its loop/fusion
        accounting on this backend is unreliable for ranking."""
        return self.bytes_model / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_per_device / self.link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total compiled FLOPs — remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the bound term that is useful model compute —
        (model_flops/chips/peak) / bound_time."""
        if self.bound_time == 0:
            return 0.0
        ideal = self.model_flops / self.chips / self.peak_flops
        return ideal / self.bound_time

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "strategy": self.strategy, "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "bytes_model": self.bytes_model,
            "collective_per_device": self.collective_per_device,
            "collective_breakdown": self.collective_breakdown,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_memory_hlo": self.t_memory_hlo,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_estimate(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful FLOPs per executed step.

    train: 6 * N_active * tokens  (fwd 2ND + bwd 4ND)
    prefill: 2 * N_active * tokens (+ attention quadratic term)
    decode: 2 * N_active * batch (one token each) + attention context reads
    """
    n_active = cfg.active_param_counts()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        base = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        base = 2.0 * n_active * tokens
        # causal attention score+value FLOPs: 2 * 2 * B * S^2/2 * H * hd
        if not cfg.attn_free:
            base += (2.0 * shape.global_batch * shape.seq_len ** 2 / 2
                     * cfg.n_heads * cfg.head_dim * 2)
    else:  # decode: one token per sequence
        base = 2.0 * n_active * shape.global_batch
        if not cfg.attn_free:
            base += (4.0 * shape.global_batch * shape.seq_len
                     * cfg.n_heads * cfg.head_dim)
    return base


def analytic_bytes_estimate(cfg: ModelConfig, shape: ShapeConfig,
                            chips: int, microbatches: int = 1,
                            kv_itemsize: int = 2) -> float:
    """Minimum per-device HBM traffic per executed step (napkin math).

    decode : active weights read once + full KV cache read + 1-token write
    prefill: weights + KV written + O(tokens*d) activation traffic
    train  : weights read fwd+bwd (2x2B) + f32 grads written (4B) + AdamW
             state read+write (m,v: 2x2xmb) + params update (2x2B)
             + saved scan carries (remat: one [B,S,D] per layer per mb)
    All divided by ``chips`` (weights/KV/activations are all sharded over
    the mesh under every strategy used here).
    """
    n_active = cfg.active_param_counts()
    n_total = cfg.param_counts()["total"]
    B, S = shape.global_batch, shape.seq_len
    kappa = cfg.kv_bytes_per_token() * kv_itemsize / 2
    state = cfg.state_bytes_per_request()

    if shape.kind == "decode":
        # SWA archs only keep window-KV on local layers
        if cfg.swa_pattern > 0:
            g = cfg.n_global_attn_layers
            loc = cfg.n_layers - g
            per_layer = kappa / max(cfg.n_decoder_attn_layers, 1)
            kv = B * (g * S + loc * min(cfg.sliding_window, S)) * per_layer
        else:
            kv = B * S * kappa
        # weight read: non-FFN fully + DISTINCT experts for MoE
        counts = cfg.param_counts()
        w = (counts["total"] - counts["ffn"]) * 2
        if cfg.is_moe:
            expert_bytes = 3 * cfg.d_model * cfg.d_ff * 2
            distinct = min(cfg.n_experts, B * cfg.experts_per_token) \
                + cfg.n_shared_experts
            w += cfg.n_layers * distinct * expert_bytes
        else:
            w += counts["ffn"] * 2
        return (w + kv + B * state) / chips

    if shape.kind == "prefill":
        kv = B * S * kappa
        act = B * S * cfg.d_model * 2 * cfg.n_layers * 4
        return (2 * n_active + kv + act) / chips

    # train
    mdt = 2 if n_total > 5e10 else 4          # moment dtype bytes
    weights = 2 * n_total * 2                 # fwd + bwd reads (bf16)
    grads = 4 * n_total                       # f32 grad write
    opt = n_total * (2 * 2 * mdt + 2 * 2)     # m,v r/w + param r/w
    carries = B * S * cfg.d_model * 2 * cfg.n_layers  # remat-saved inputs
    act = B * S * cfg.d_model * 2 * cfg.n_layers * 6  # recompute traffic
    return (weights * max(microbatches, 1) + grads + opt + carries + act) \
        / chips


def trip_factor(cfg: ModelConfig, shape: ShapeConfig,
                microbatches: int = 1) -> int:
    """How many times the dominant scan body executes per step.

    XLA cost analysis counts while bodies once; the per-layer scan body runs
    L times (enc+dec for whisper), and the gradient-accumulation scan
    multiplies by ``microbatches`` for train cells.  Nested structures
    (gemma3 groups, zamba2 hybrid) still total ~n_layers body executions.
    """
    L = cfg.n_layers
    if cfg.family == "audio":
        L += cfg.n_encoder_layers
    if shape.kind == "train":
        L *= max(microbatches, 1)
    return max(L, 1)


def build_report(*, arch: str, shape: ShapeConfig, mesh_name: str,
                 strategy: str, chips: int, cost: Dict, hlo_text: str,
                 cfg: ModelConfig, microbatches: int = 1,
                 kv_itemsize: int = 2) -> RooflineReport:
    """FLOPs + collective bytes via loop-aware HLO parsing (hlo_analysis);
    raw cost_analysis values are kept for reference.  XLA counts while
    bodies once (verified empirically), so the parser multiplies every
    computation by its execution count derived from the known scan
    structure."""
    from repro.launch import hlo_analysis as ha
    trips = ha.depth_trips_for(cfg, shape, microbatches)
    stats = ha.analyze(hlo_text, trips)
    coll = dict(stats.collective_bytes)
    coll["count"] = stats.coll_count
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, strategy=strategy,
        chips=chips,
        flops_per_device=stats.flops,
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        bytes_model=analytic_bytes_estimate(cfg, shape, chips, microbatches,
                                            kv_itemsize),
        collective_per_device=float(stats.collective_total),
        collective_breakdown=coll,
        model_flops=model_flops_estimate(cfg, shape),
    )
