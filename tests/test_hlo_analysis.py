"""Unit tests for the loop-aware HLO analyzer (the §Roofline methodology).

Validates the central claim of EXPERIMENTS.md §Methodology: XLA's
cost_analysis counts while bodies once; our parser recovers the true
totals using known_trip_count.
"""
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as ha

L, N, D = 8, 64, 128


def _scanned(x, Ws):
    y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, Ws)
    return y


def test_cost_analysis_counts_loop_bodies_once():
    c = ha.xla_cost_analysis(jax.jit(_scanned).lower(
        jnp.ones((N, D)), jnp.ones((L, D, D))).compile())
    one_layer = 2 * N * D * D
    assert abs(c["flops"] - one_layer) < one_layer * 0.01


def test_analyzer_recovers_full_flops():
    Ws = jnp.ones((L, D, D))
    x = jnp.ones((N, D))
    hlo = jax.jit(_scanned).lower(x, Ws).compile().as_text()
    stats = ha.analyze(hlo, [L])
    want = 2 * N * D * D * L
    assert abs(stats.flops - want) < want * 0.01


def test_analyzer_nested_scans():
    """Outer scan (3) x inner scan (L) multiply correctly."""
    Ws = jnp.ones((L, D, D))
    x = jnp.ones((N, D))

    def outer(x, Ws):
        def body(c, _):
            return _scanned(c, Ws), None
        y, _ = jax.lax.scan(body, x, None, length=3)
        return y

    hlo = jax.jit(outer).lower(x, Ws).compile().as_text()
    stats = ha.analyze(hlo, [3, L])
    want = 2 * N * D * D * L * 3
    assert abs(stats.flops - want) < want * 0.01


def test_known_trip_count_overrides_depth_guess():
    """Even with WRONG depth hints, backend_config trips win."""
    Ws = jnp.ones((L, D, D))
    x = jnp.ones((N, D))
    hlo = jax.jit(_scanned).lower(x, Ws).compile().as_text()
    stats = ha.analyze(hlo, [999])           # bogus hint
    want = 2 * N * D * D * L
    assert abs(stats.flops - want) < want * 0.01


def test_collective_counting_with_loops():
    """psum inside a scan counts once per trip."""
    mesh = jax.make_mesh((1,), ("x",))

    def f(v):
        def body(c, _):
            return c + jax.lax.psum(c, "x"), None
        y, _ = jax.lax.scan(body, v, None, length=5)
        return y

    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
        fn = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                       check_vma=False)
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
        fn = sm(f, mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
    with mesh:
        hlo = jax.jit(fn).lower(jnp.ones((16, 128))).compile().as_text()
    stats = ha.analyze(hlo, [5])
    # 1-device psum may be optimized away entirely; the invariant is that
    # IF present it is multiplied by the trip count (payload % trip == 0)
    if stats.collective_total:
        assert stats.collective_total % 5 == 0


def test_multistep_structure_helpers():
    """The DESIGN.md §9 structural analyzers: a K-step scan over a layer
    scan shows up as a depth-0 while of trip K wrapping a depth-1 while
    of trip L, with no host transfers, and the entry output is the
    carried tensor (not per-step intermediates)."""
    K = 4
    Ws = jnp.ones((L, D, D))
    x = jnp.ones((N, D))

    def ksteps(x, Ws):
        def body(c, _):
            return _scanned(c, Ws), None
        y, _ = jax.lax.scan(body, x, None, length=K)
        return y

    hlo = jax.jit(ksteps).lower(x, Ws).compile().as_text()
    trips = ha.while_trip_structure(hlo)
    assert (0, K) in trips, trips
    assert (1, L) in trips, trips
    assert ha.host_transfer_count(hlo) == 0
    outs = ha.entry_output_shapes(hlo)
    assert ("f32", [N, D]) in outs, outs
