"""Multi-step fused decode: K tokens per dispatch vs K=1, real engine.

The tentpole claim of the persistent multi-step decode path (DESIGN.md
§9): committing K tokens per host dispatch amortizes the dispatch/commit
overhead that dominates decode TBT for cold small-batch models, at EQUAL
DEVICE BYTES — the K=4 engine is provisioned with the identical page
budget and slab budget, and the pre-reserved decode block comes out of
the same admission-time page reservation, so nothing is bought with
extra memory.

Two measured phases on the same warmed engine pair:

  * combined — the full colocation trio round-robin (the serving shape
    the online benchmarks use): reports tokens/sec/device per K and the
    all-gap P50 TBT.  The all-gap P99 is NOT the right lens here: a
    round-robin block-boundary gap spans the other two models' whole
    dispatches for both K, so the tail is K-invariant by construction;
  * per-model — each model served alone, decode-heavy.  Here the tail IS
    the dispatch overhead, and the paper's subjects (the cold MoE
    models) must improve P99 TBT by >= 2x; the MLA model's smaller win
    (cheap dense dispatch, host overhead a larger share) rides along
    unguarded.

Token streams must be bit-exact between K=1 and K=4 — the multi-step
program is a ``lax.scan`` over the SAME per-step body, so this is an
identity, not a tolerance.  Guarded metric: the K=4/K=1 MoE P99-TBT
ratio (machine speed cancels; lower is better).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks._stats import percentile
from repro.configs import EngineConfig, PAPER_COLOC_SET, get_smoke_config
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime.request import Request

PROMPT = 8
MAX_NEW = 24                  # decode-heavy: 1 token of prompt per 3 decoded
PAGE_BUDGET = 4096
PAGE_BYTES = 4096
SLAB_BYTES = 4096
WARMUPS = 3                   # first runs also stream arena slabs resident
TRIALS = 3                    # median-of-3 P99 per phase
MOE_TARGETS = tuple(n for n in PAPER_COLOC_SET
                    if get_smoke_config(n).is_moe)


def _models():
    return {n: get_smoke_config(n).replace(dtype="float32")
            for n in PAPER_COLOC_SET}


def _engine(k: int) -> CrossPoolEngine:
    return CrossPoolEngine(
        _models(), page_budget=PAGE_BUDGET, page_bytes=PAGE_BYTES,
        slab_bytes=SLAB_BYTES, max_batch=2, max_ctx=64,
        config=EngineConfig(
            mode=EngineMode(pipeline=True, lowering=True,
                            decode_steps_per_dispatch=k)),
        seed=0)


def _trace(base_id: int, names):
    """Two full slots per model, all at t=0: every decode dispatch runs at
    the same batch shape in both engines."""
    rng = np.random.default_rng(13)
    reqs = []
    for i, name in enumerate(names):
        cfg = get_smoke_config(name)
        for j in range(2):
            reqs.append(Request(
                base_id + 10 * i + j, name, PROMPT, MAX_NEW, 0.0,
                prompt_ids=rng.integers(0, cfg.vocab_size, PROMPT)))
    return reqs


def _serve(engine, base_id: int, names):
    reqs = _trace(base_id, names)
    for r in reqs:
        r.arrival_time = engine.now
    t0 = time.perf_counter()
    stats = engine.run(reqs)
    wall = time.perf_counter() - t0
    return reqs, stats, wall


def _phase(engine, base_id: int, names):
    """Warm the exact shapes (and the arena slab residency — the first
    couple of runs stream slabs in), then take median-of-TRIALS."""
    for w in range(WARMUPS):
        _serve(engine, base_id + 50_000 + 1_000 * w, names)
    runs = [_serve(engine, base_id + 1_000 * t, names)
            for t in range(TRIALS)]
    p99s = sorted(percentile([g for r in reqs for g in r.tbt_samples()], 99)
                  for reqs, _, _ in runs)
    p50s = sorted(percentile([g for r in reqs for g in r.tbt_samples()], 50)
                  for reqs, _, _ in runs)
    reqs, stats, wall = runs[0]
    return {"p99": p99s[len(p99s) // 2], "p50": p50s[len(p50s) // 2],
            "reqs": reqs, "tokens": stats.tokens_out, "wall": wall}


def _assert_streams_equal(a, b):
    by_id = {r.request_id: r for r in b}
    for r in a:
        assert r.output_ids == by_id[r.request_id].output_ids, \
            f"request {r.request_id} diverged between K=1 and K=4"


def run(csv=print) -> dict:
    eng1, eng4 = _engine(1), _engine(4)
    # equal device bytes: identical KV pool and identical arena budget
    assert eng1.virt.pool.nbytes == eng4.virt.pool.nbytes
    assert eng1.arena.slot_budget == eng4.arena.slot_budget
    n_dev = max(jax.device_count(), 1)
    out = {}

    # --- combined round-robin: throughput roofline + P50 ------------------
    all1 = _phase(eng1, 100_000, PAPER_COLOC_SET)
    all4 = _phase(eng4, 100_000, PAPER_COLOC_SET)
    assert all1["tokens"] == all4["tokens"] > 0
    _assert_streams_equal(all1["reqs"], all4["reqs"])
    tps1 = all1["tokens"] / all1["wall"] / n_dev
    tps4 = all4["tokens"] / all4["wall"] / n_dev
    csv(f"multistep,combined,k1_tok_s_dev={tps1:.1f},"
        f"k4_tok_s_dev={tps4:.1f},k1_p50_ms={all1['p50'] * 1e3:.3f},"
        f"k4_p50_ms={all4['p50'] * 1e3:.3f}")
    out.update({
        "k1_tok_s_per_device": tps1, "k4_tok_s_per_device": tps4,
        "combined_k1_p50_tbt_s": all1["p50"],
        "combined_k4_p50_tbt_s": all4["p50"],
        "tokens_out": int(all4["tokens"]),
    })

    # --- per-model: the dispatch-amortization tail claim ------------------
    moe_ratios = []
    for i, name in enumerate(PAPER_COLOC_SET):
        m1 = _phase(eng1, 200_000 + 10_000 * i, [name])
        m4 = _phase(eng4, 200_000 + 10_000 * i, [name])
        _assert_streams_equal(m1["reqs"], m4["reqs"])
        ratio = m4["p99"] / m1["p99"] if m1["p99"] else float("nan")
        guarded = name in MOE_TARGETS
        csv(f"multistep,{name},k1_p99_ms={m1['p99'] * 1e3:.3f},"
            f"k4_p99_ms={m4['p99'] * 1e3:.3f},k4_over_k1={ratio:.3f},"
            f"guarded={guarded}")
        out[f"{name}_k1_p99_tbt_s"] = m1["p99"]
        out[f"{name}_k4_p99_tbt_s"] = m4["p99"]
        if guarded:
            moe_ratios.append(ratio)
            # the acceptance bound: >= 2x P99 TBT at equal device bytes
            assert m4["p99"] * 2.0 <= m1["p99"], \
                (f"{name}: K=4 P99 {m4['p99']:.6f}s is not 2x better "
                 f"than K=1 {m1['p99']:.6f}s")

    # guarded: worst MoE ratio (lower is better, well under 0.5)
    out["moe_k4_over_k1_p99"] = max(moe_ratios)
    return out


if __name__ == "__main__":
    run()
