"""Discrete-event decode simulator: Static Partition vs kvcached vs CrossPool.

The engine (engine.py) runs the real CrossPool code on this host's devices;
this simulator models the paper's five-GPU A100 testbed so the three
*systems* can be compared at the paper's scale (Fig. 6 capacity, Fig. 7
TBT).  Costs are grounded napkin math over the hardware:

  decode step time = max(weight-read, kv-read, flops) + control overhead
    weight-read = active_param_bytes / (HBM_bw * gpus_in_group)
    kv-read     = sum_ctx * kappa / (HBM_bw * gpus_holding_kv)
    control     = per-layer host dispatch (baselines) vs persistent-kernel
                  dispatch (crosspool), + inter-pool hidden-state transfer

Contention is physical: a decode step exclusively occupies its placement's
GPUs; colocated models queue on shared GPUs (kvcached's tail-latency
mechanism per paper §5.3).  CrossPool splits each step into an attention
stage (KV-pool GPU) and an FFN stage (weights-pool GPUs) which pipeline
across models (§3.2), so the pools contend far less.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


from repro.configs.base import ModelConfig
from repro.runtime.request import Request

# --- A100-40G testbed constants (paper §5.1) -------------------------------
HBM_BW = 1.55e12                  # bytes/s
PEAK_FLOPS = 312e12               # bf16
NVLINK_BW = 300e9                 # bytes/s effective per direction
HBM_BYTES = 40e9
HOST_DISPATCH = 30e-6             # per CUDA-graph launch from host
PERSISTENT_DISPATCH = 60e-6       # once per token (control lowered)


@dataclass
class SystemPlacement:
    """One system's decode-side placement on the 5-GPU testbed."""

    system: str                                 # static | kvcached | crosspool
    gpu_sets: Dict[str, Tuple[int, ...]]        # model -> GPUs for its step
    kv_visible: Dict[str, float]                # bytes one request can reach
    kv_pool_bytes: Dict[str, float]             # per model budget (shared ok)
    shared_pool: bool                           # pool shared across models?
    kv_gpus: Dict[str, Tuple[int, ...]]         # GPUs holding a request's KV
    pipelined: bool = False                     # layer-wise pipeline
    lowered: bool = False                       # persistent-kernel control
    ffn_gpus: Dict[str, Tuple[int, ...]] = field(default_factory=dict)
    # K decode tokens per host dispatch (multi-step fused decode).  Only
    # meaningful with ``lowered`` — the host-driven path stays K=1, which
    # mirrors EngineMode.decode_steps_per_dispatch in the real engine.
    decode_steps: int = 1


def _ffn_read_bytes(cfg: ModelConfig, batch: int) -> float:
    """FFN weight bytes touched per decode step.

    MoE: ~min(E, batch*topk) DISTINCT experts activate per layer (cold-model
    batches are small, so most expert weights stay untouched — this is the
    memory-side reason cold MoE serving is weight-read-bound)."""
    if cfg.is_moe:
        expert_bytes = 3 * cfg.d_model * cfg.d_ff * 2
        distinct = min(cfg.n_experts,
                       batch * cfg.experts_per_token) + cfg.n_shared_experts
        return cfg.n_layers * distinct * expert_bytes
    return cfg.param_counts()["ffn"] * 2


def crosspool_stage_times(cfg: ModelConfig, batch: int, sum_ctx: int,
                          placement: SystemPlacement
                          ) -> Tuple[float, float, float, float]:
    """(attn_stage, transfer, ffn_stage, control) for one decode step."""
    name = cfg.name
    n_kv = len(placement.kv_gpus[name])
    n_ffn = len(placement.ffn_gpus[name])
    counts = cfg.param_counts()
    attn_bytes = (counts["total"] - counts["ffn"]) * 2       # non-FFN weights
    attn_read = attn_bytes / (HBM_BW * n_kv)
    kv_read = sum_ctx * cfg.kv_bytes_per_token() / (HBM_BW * n_kv)
    ffn_read = _ffn_read_bytes(cfg, batch) / (HBM_BW * n_ffn)
    xfer = 2 * cfg.n_layers * batch * cfg.d_model * 2 / NVLINK_BW
    if placement.lowered:
        # one persistent-kernel dispatch commits K tokens; its launch cost
        # amortizes to 1/K per token (the stage reads themselves don't)
        control = PERSISTENT_DISPATCH / max(placement.decode_steps, 1)
    else:
        control = HOST_DISPATCH * 2 * cfg.n_layers
    return attn_read + kv_read, xfer, ffn_read, control


def decode_step_time(cfg: ModelConfig, batch: int, sum_ctx: int,
                     placement: SystemPlacement) -> float:
    """One decode iteration for a model's running batch."""
    name = cfg.name
    kappa = cfg.kv_bytes_per_token()
    n_step = len(placement.gpu_sets[name])
    n_kv = len(placement.kv_gpus[name])

    if placement.system == "crosspool":
        attn_stage, xfer, ffn_stage, control = crosspool_stage_times(
            cfg, batch, sum_ctx, placement)
        if placement.pipelined:
            # steady-state: the longer stage hides the shorter one
            compute = max(attn_stage, ffn_stage) + xfer
        else:
            compute = attn_stage + ffn_stage + xfer
        return compute + control

    # monolithic systems: whole model on the step GPUs
    counts = cfg.param_counts()
    w_bytes = (counts["total"] - counts["ffn"]) * 2 + _ffn_read_bytes(cfg,
                                                                      batch)
    w_read = w_bytes / (HBM_BW * n_step)
    kv_read = sum_ctx * kappa / (HBM_BW * n_kv)
    flops = 2 * cfg.active_param_counts() * batch / (PEAK_FLOPS * n_step)
    control = HOST_DISPATCH * cfg.n_layers
    return max(w_read + kv_read, flops) + control


def prefill_time(cfg: ModelConfig, prompt: int,
                 placement: SystemPlacement) -> float:
    n = len(placement.gpu_sets[cfg.name])
    flops = 2 * cfg.active_param_counts() * prompt
    return flops / (PEAK_FLOPS * n) + 2e-3


# ---------------------------------------------------------------------------
# Placements for the paper's Table 2 testbed
# ---------------------------------------------------------------------------

def paper_placements(models: Dict[str, ModelConfig],
                     system: str, *, pipelined: bool = True,
                     lowered: bool = True, decode_steps: int = 1,
                     hbm_bytes: Optional[float] = None) -> SystemPlacement:
    """The paper's 5-GPU placements (Table 2), parameterized by system.

    models: ordered dict of the colocation trio {Q, G, D}-analogues.
    ``hbm_bytes`` defaults to auto-sizing the testbed to the paper's weight
    occupancy (~77% of total HBM holds weights, §5.1: 154 GB on 200 GB) —
    our stand-in trio is bigger than the paper's 30B models, so the same
    occupancy ratio, not the same absolute GB, is what transfers.
    """
    names = list(models)
    q, g, d = names[0], names[1], names[2]

    def wbytes(n):
        return models[n].param_counts()["total"] * 2

    def ffn_b(n):
        return models[n].param_counts()["ffn"] * 2

    hbm = hbm_bytes or sum(wbytes(n) for n in names) / 5 / 0.77

    if system == "static":
        gpu_sets = {q: (0, 1), g: (2, 3), d: (4,)}
        kv_pool = {n: max(len(gpu_sets[n]) * hbm - wbytes(n), 0.0)
                   for n in names}
        # a request sees its replica's slice (tp = min(kv_heads, gpus))
        kv_vis = {}
        for n in names:
            cfg = models[n]
            G = len(gpu_sets[n])
            kvh = 1 if cfg.attention == "mla" else max(cfg.n_kv_heads, 1)
            stripe = min(kvh, G)
            kv_vis[n] = kv_pool[n] / G * stripe
        return SystemPlacement("static", gpu_sets, kv_vis, kv_pool,
                               shared_pool=False, kv_gpus=gpu_sets)

    if system == "kvcached":
        gpu_sets = {q: (0, 1, 2, 3), g: (1, 2, 3, 4), d: (0, 4)}
        total = max(5 * hbm - sum(wbytes(n) for n in names), 0.0)
        free_per_gpu = total / 5
        kv_pool = {n: total for n in names}
        kv_vis = {}
        for n in names:
            cfg = models[n]
            G = len(gpu_sets[n])
            # DP attention for KV-head-limited models: one request's KV is
            # confined to its rank's stripe (paper §2.2 / Fig. 2a)
            kvh = 1 if cfg.attention == "mla" else max(cfg.n_kv_heads, 1)
            stripe = min(kvh, G)
            kv_vis[n] = free_per_gpu * stripe
        return SystemPlacement("kvcached", gpu_sets, kv_vis, kv_pool,
                               shared_pool=True, kv_gpus=gpu_sets)

    if system == "crosspool":
        kv_gpu = (0,)
        w_gpus = (1, 2, 3, 4)
        non_ffn = sum(wbytes(n) - ffn_b(n) for n in names)
        pool = max(hbm - non_ffn, 0.0)
        gpu_sets = {n: kv_gpu + w_gpus for n in names}
        return SystemPlacement(
            "crosspool", gpu_sets,
            kv_visible={n: pool for n in names},
            kv_pool_bytes={n: pool for n in names},
            shared_pool=True,
            kv_gpus={n: kv_gpu for n in names},
            ffn_gpus={n: w_gpus for n in names},
            pipelined=pipelined, lowered=lowered,
            decode_steps=decode_steps if lowered else 1)

    raise ValueError(system)


# ---------------------------------------------------------------------------
# Event-driven decode simulation (Fig. 7)
# ---------------------------------------------------------------------------

class DecodeSimulator:
    def __init__(self, models: Dict[str, ModelConfig],
                 placement: SystemPlacement, *, max_batch: int = 8):
        self.models = models
        self.pl = placement
        self.max_batch = max_batch

    def run(self, requests: List[Request]) -> Dict:
        pl = self.pl
        gpu_free = [0.0] * 5
        pool_used = {n: 0.0 for n in self.models}   # bytes (shared aliases)
        shared_used = 0.0
        running: Dict[str, List[Request]] = {n: [] for n in self.models}
        queued: Dict[str, List[Request]] = {n: [] for n in self.models}
        rejected: List[Request] = []

        events: List[Tuple[float, int, str, object]] = []
        for r in requests:
            heapq.heappush(events, (r.arrival_time, r.request_id, "arrive", r))
        step_busy = {n: False for n in self.models}
        eid = 10 ** 9

        def kv_need(r: Request) -> float:
            cfg = self.models[r.model]
            return (r.prompt_tokens + r.max_new_tokens) * \
                cfg.kv_bytes_per_token() + cfg.state_bytes_per_request()

        def try_admit(r: Request, now: float) -> bool:
            nonlocal shared_used
            need = kv_need(r)
            if need > pl.kv_visible[r.model]:
                return False                     # can never fit: reject
            used = shared_used if pl.shared_pool else pool_used[r.model]
            budget = pl.kv_pool_bytes[r.model]
            if used + need > budget:
                queued[r.model].append(r)
                return True
            if pl.shared_pool:
                shared_used += need
            else:
                pool_used[r.model] += need
            running[r.model].append(r)
            r.admit_time = now
            return True

        def release(r: Request) -> None:
            nonlocal shared_used
            need = kv_need(r)
            if pl.shared_pool:
                shared_used -= need
            else:
                pool_used[r.model] -= need

        def schedule_step(model: str, now: float) -> None:
            nonlocal eid
            if step_busy[model] or not running[model]:
                return
            batch = running[model][: self.max_batch]
            cfg = self.models[model]
            sum_ctx = sum(r.context_length for r in batch)
            prefill_extra = sum(
                prefill_time(cfg, r.prompt_tokens, pl) for r in batch
                if r.generated == 0 and r.first_token_time == 0.0)
            step_busy[model] = True
            eid += 1
            if pl.system == "crosspool" and pl.pipelined:
                # stage-level resource occupancy: attention holds only the
                # KV-pool GPU(s); FFN holds only the weights-pool GPUs — so
                # another model's attention overlaps this model's FFN
                # (paper Fig. 4).
                t_attn, xfer, t_ffn, ctrl = crosspool_stage_times(
                    cfg, len(batch), sum_ctx, pl)
                kv_g = pl.kv_gpus[model]
                start = max([now] + [gpu_free[g] for g in kv_g])
                a_end = start + t_attn + ctrl / 2 + prefill_extra
                for g in kv_g:
                    gpu_free[g] = a_end
                heapq.heappush(events, (a_end + xfer / 2, eid, "attn_done",
                                        (model, batch, t_ffn, xfer, ctrl)))
                return
            gpus = pl.gpu_sets[model]
            start = max([now] + [gpu_free[g] for g in gpus])
            dt = decode_step_time(cfg, len(batch), sum_ctx, pl) + prefill_extra
            end = start + dt
            for g in gpus:
                gpu_free[g] = end
            heapq.heappush(events, (end, eid, "step_done", (model, batch)))

        while events:
            now, _, kind, payload = heapq.heappop(events)
            if kind == "arrive":
                r: Request = payload
                if not try_admit(r, now):
                    rejected.append(r)
                    continue
                schedule_step(r.model, now)
            elif kind == "attn_done":
                model, batch, t_ffn, xfer, ctrl = payload
                w_g = pl.ffn_gpus[model]
                start = max([now] + [gpu_free[g] for g in w_g])
                end = start + t_ffn + ctrl / 2 + xfer / 2
                for g in w_g:
                    gpu_free[g] = end
                eid += 1
                heapq.heappush(events, (end, eid, "step_done", (model, batch)))
            elif kind == "step_done":
                model, batch = payload
                step_busy[model] = False
                done = []
                for r in batch:
                    if r.generated == 0:
                        r.first_token_time = now
                    r.generated += 1
                    r.token_times.append(now)
                    if r.done:
                        done.append(r)
                for r in done:
                    running[model].remove(r)
                    release(r)
                    r.finish_time = now
                    # admit queued
                    while queued[model]:
                        nxt = queued[model][0]
                        need = kv_need(nxt)
                        used = shared_used if pl.shared_pool else \
                            pool_used[model]
                        if used + need <= pl.kv_pool_bytes[model]:
                            queued[model].pop(0)
                            try_admit(nxt, now)
                        else:
                            break
                schedule_step(model, now)

        tbt = [g for r in requests for g in r.tbt_samples()]
        per_model_tbt = {
            n: [g for r in requests if r.model == n for g in r.tbt_samples()]
            for n in self.models}
        return {
            "tbt": tbt,
            "per_model_tbt": per_model_tbt,
            "rejected": len(rejected),
            "finished": sum(1 for r in requests if r.finish_time > 0),
            "tokens_out": sum(r.generated for r in requests),
        }


# ---------------------------------------------------------------------------
# Capacity scan (Fig. 6)
# ---------------------------------------------------------------------------

def max_rps_for_context(models: Dict[str, ModelConfig],
                        placement: SystemPlacement, ctx: int,
                        output_tokens: int = 256) -> float:
    """Little's-law estimate of the max aggregate RPS at context ``ctx``.

    N_fit concurrent requests of this context fit in the (visible) KV pool;
    each resides for ~output_tokens decode steps; max rate = N_fit / T_res.
    A vertical drop to 0 marks the capacity cliff (request can never fit).
    """
    total = 0.0
    for n, cfg in models.items():
        kappa = cfg.kv_bytes_per_token()
        need = ctx * kappa + cfg.state_bytes_per_request()
        if need == 0:
            continue
        if need > placement.kv_visible[n]:
            continue                                # cliff for this model
        n_fit = max(int(placement.kv_pool_bytes[n] // need), 0)
        if placement.shared_pool:
            n_fit = max(n_fit // len(models), 1) if n_fit else 0
        if n_fit == 0:
            continue
        step = decode_step_time(cfg, min(n_fit, 8), ctx * min(n_fit, 8),
                                placement)
        t_res = output_tokens * step
        total += n_fit / t_res
    return total
