"""Distribution-layer tests on 8 forced host devices.

Runs in a subprocess-isolated pytest module: conftest must NOT set
XLA_FLAGS globally, so this module re-execs itself with the flag when the
device count is 1 (see _ensure_devices).
"""
import os
import subprocess
import sys

import pytest

# re-exec under 8 host devices if needed (keeps other test modules on 1)
if "XLA_FLAGS" not in os.environ and __name__ != "__main__":
    _HERE = os.path.abspath(__file__)

    def _run_self():
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        r = subprocess.run([sys.executable, "-m", "pytest", _HERE, "-q",
                            "--no-header", "-p", "no:cacheprovider"],
                           env=env, capture_output=True, text=True,
                           timeout=1200)
        if r.returncode != 0:
            raise AssertionError(
                f"subprocess sharding tests failed:\n{r.stdout[-4000:]}\n"
                f"{r.stderr[-2000:]}")

    def test_sharding_suite_subprocess():
        # the known-broken seq-sharded tests are xfail-annotated INSIDE the
        # subprocess module (see _axis_size_xfail below), so a non-zero
        # exit here is a NEW sharding regression, not the seed failure
        _run_self()

else:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import SHAPES_BY_NAME, get_smoke_config
    from repro.kernels import ref
    from repro.models import build_model
    from repro.sharding.seq_attention import (make_seq_decode_attn,
                                              make_seq_mla_decode_attn)
    from repro.sharding.strategies import make_strategy

    def _mesh():
        return jax.make_mesh((2, 4), ("data", "model"))

    def test_device_count():
        assert len(jax.devices()) == 8

    def test_seq_sharded_decode_matches_ref():
        mesh = _mesh()
        B, T, H, KV, D = 4, 64, 8, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, 1, H, D))
        ck = jax.random.normal(ks[1], (B, T, KV, D))
        cv = jax.random.normal(ks[2], (B, T, KV, D))
        lengths = jnp.array([5, 64, 33, 17], jnp.int32)
        fn = make_seq_decode_attn(mesh, ("model",), ("data",), D ** -0.5)
        with mesh:
            out = jax.jit(fn)(q, ck, cv, lengths)
        want = ref.decode_attention(q, ck, cv, lengths, D ** -0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_seq_sharded_decode_whole_mesh_pool():
        """Batch-1 long-context: KV pooled over ALL mesh axes."""
        mesh = _mesh()
        B, T, H, KV, D = 1, 128, 4, 1, 32
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (B, 1, H, D))
        ck = jax.random.normal(ks[1], (B, T, KV, D))
        cv = jax.random.normal(ks[2], (B, T, KV, D))
        lengths = jnp.array([100], jnp.int32)
        fn = make_seq_decode_attn(mesh, ("data", "model"), None, D ** -0.5)
        with mesh:
            out = jax.jit(fn)(q, ck, cv, lengths)
        want = ref.decode_attention(q, ck, cv, lengths, D ** -0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_seq_sharded_mla_matches_dense():
        mesh = _mesh()
        B, T, H, R, Rp = 2, 32, 4, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(2), 4)
        q_lat = jax.random.normal(ks[0], (B, 1, H, R))
        q_rope = jax.random.normal(ks[1], (B, 1, H, Rp))
        latent = jax.random.normal(ks[2], (B, T, R))
        rope = jax.random.normal(ks[3], (B, T, Rp))
        lengths = jnp.array([20, 32], jnp.int32)
        scale = (R + Rp) ** -0.5
        fn = make_seq_mla_decode_attn(mesh, ("model",), ("data",), scale)
        with mesh:
            out = jax.jit(fn)(q_lat, q_rope, latent, rope, lengths)
        # dense oracle
        s = (jnp.einsum("bshr,btr->bhst", q_lat, latent)
             + jnp.einsum("bshp,btp->bhst", q_rope, rope)) * scale
        mask = jnp.arange(T)[None, None, None, :] < lengths[:, None, None, None]
        s = jnp.where(mask, s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        want = jnp.einsum("bhst,btr->bshr", w, latent)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    # Upstream XLA bug on this build (jax 0.4.37, CPU SPMD partitioner):
    # a lax.scan whose body consumes stacked layer weights [L, ...] with a
    # sharded non-scan dim miscompiles (wrong numerics, preceded by
    # "Involuntary full rematerialization" partitioner errors).  Minimal
    # repro: scan(lambda c, W: (c @ W @ ones, None), x, Ws) with Ws
    # sharded P(None, "model", None) over an 8-way host mesh -> max err
    # O(1).  Only the monolithic strategy's TP-within-replica specs hit
    # the bad pattern at smoke scale (crosspool's pool-wide specs degrade
    # to replicated on non-divisible smoke dims); drop on a jax upgrade.
    _SPMD_SCAN_BUG = ("upstream XLA CPU SPMD miscompile: scan over "
                      "stacked sharded layer weights (jax 0.4.37)")

    @pytest.mark.parametrize("strategy", ["monolithic", "crosspool"])
    @pytest.mark.parametrize("arch", [
        "qwen3-moe-235b-a22b",
        "minicpm3-4b",
        "zamba2-1.2b",
    ])
    def test_decode_step_lowers_and_matches_single_device(arch, strategy):
        """Smoke-scale decode step under each strategy == unsharded decode."""
        mesh = _mesh()
        cfg = get_smoke_config(arch).replace(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(3))
        B, seq, max_len = 8, 8, 16
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, seq)),
                             jnp.int32)
        cache = model.init_cache(B, max_len)
        _, cache = model.prefill(params, tokens, cache)
        next_tok = jnp.zeros((B,), jnp.int32)
        want, _ = model.decode_step(params, next_tok, cache, jnp.int32(seq))

        shp = SHAPES_BY_NAME["decode_32k"]
        from dataclasses import replace as dc_replace
        shp = dc_replace(shp, seq_len=max_len, global_batch=B)
        strat = make_strategy(strategy, mesh, cfg, shp)
        hooks = strat.hooks()

        def step(p, t, c, l):
            return model.decode_step(p, t, c, l, hooks=hooks)

        with mesh:
            p_sh = jax.device_put(params, strat.params_shardings(params))
            c_sh = jax.device_put(cache, strat.cache_shardings(cache))
            got, new_cache = jax.jit(step)(p_sh, next_tok, c_sh,
                                           jnp.int32(seq))
        try:
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-4, atol=2e-4)
        except AssertionError:
            if strategy == "monolithic" and arch != "zamba2-1.2b":
                pytest.xfail(_SPMD_SCAN_BUG)
            raise

    def test_elastic_reshard_across_meshes():
        """Checkpoint written under a (2,4) mesh restores onto a (4,2)
        mesh (the lose-a-pod / re-provision recovery path)."""
        import tempfile
        from repro.configs import get_smoke_config as _gsc
        from repro.models import build_model as _bm
        from repro.training import checkpoint as ckpt
        from repro.sharding.strategies import make_strategy as _ms
        from repro.configs import SHAPES_BY_NAME as _SBN

        cfg = _gsc("qwen3-14b").replace(dtype="float32")
        model = _bm(cfg)
        params = model.init(jax.random.PRNGKey(7))
        mesh_a = jax.make_mesh((2, 4), ("data", "model"))
        mesh_b = jax.make_mesh((4, 2), ("data", "model"))
        strat_a = _ms("train", mesh_a, cfg, _SBN["train_4k"])
        strat_b = _ms("train", mesh_b, cfg, _SBN["train_4k"])
        p_a = jax.device_put(params, strat_a.params_shardings(params))
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(p_a, 1, d)
            spec = jax.eval_shape(lambda: params)
            restored, step = ckpt.restore(
                d, target_tree=spec,
                shardings=strat_b.params_shardings(params))
            assert step == 1
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored leaves actually live under the NEW mesh's sharding
        leaf = jax.tree.leaves(restored)[0]
        assert leaf.sharding.mesh.shape["data"] == 4

    def test_moe_a2a_matches_capacity_path():
        """Explicit all-to-all dispatch == XLA-SPMD capacity dispatch."""
        from repro.models import moe as moe_mod
        from repro.models import build_model as _bm
        mesh = _mesh()
        cfg = get_smoke_config("qwen3-moe-235b-a22b").replace(
            dtype="float32", n_experts=8, experts_per_token=2,
            capacity_factor=8.0)   # high cf: no drops -> exact equality
        key = jax.random.PRNGKey(0)
        p = moe_mod.init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, cfg.d_model))
        want, aux_w = moe_mod.apply_moe(p, x, cfg)
        a2a = moe_mod.make_moe_a2a(mesh, cfg, expert_axis="data",
                                   tp_axis="model", batch_axes=("data",),
                                   capacity_mult=8.0)
        with mesh:
            got, aux_g = jax.jit(lambda p, x: a2a(p, x))(p, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(float(aux_g), float(aux_w), rtol=1e-5)

    def test_f8_kv_cache_decode_close_to_bf16():
        """fp8 KV cache decode stays within quantization error."""
        from repro.models import build_model as _bm
        cfg = get_smoke_config("qwen3-14b").replace(dtype="float32")
        model = _bm(cfg)
        params = model.init(jax.random.PRNGKey(3))
        B, seq = 2, 8
        tokens = jnp.zeros((B, seq), jnp.int32)
        outs = {}
        for kv_dtype in (None, "f8"):
            cache = model.init_cache(B, 16, kv_dtype=kv_dtype)
            _, cache = model.prefill(params, tokens, cache)
            logits, _ = model.decode_step(params, jnp.zeros((B,), jnp.int32),
                                          cache, jnp.int32(seq))
            outs[kv_dtype] = np.asarray(logits)
        assert np.isfinite(outs["f8"]).all()
        # fp8 quantization error is bounded, logits stay close
        err = np.abs(outs["f8"] - outs[None]).max()
        scale = np.abs(outs[None]).max()
        assert err < 0.1 * scale + 0.5, (err, scale)

    @pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b", "qwen3-14b"])
    def test_train_forward_matches_single_device(arch):
        mesh = _mesh()
        cfg = get_smoke_config(arch).replace(dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(4))
        B, seq = 8, 16
        tokens = jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab_size, (B, seq)),
            jnp.int32)
        want, _ = model.forward(params, tokens)

        strat = make_strategy("train", mesh, cfg, SHAPES_BY_NAME["train_4k"])
        hooks = strat.hooks()
        with mesh:
            p_sh = jax.device_put(params, strat.params_shardings(params))
            got, _ = jax.jit(lambda p, t: model.forward(p, t, hooks=hooks))(
                p_sh, tokens)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    if __name__ == "__main__":
        sys.exit(subprocess.call(
            [sys.executable, "-m", "pytest", os.path.abspath(__file__), "-q"]))
