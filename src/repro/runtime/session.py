"""Online serving session primitives: handles, token events, prefill batching.

The engine's front-end is event-driven (DESIGN.md §7): callers ``submit``
requests one at a time and drive ``step`` — there is no offline trace.
This module holds the request-level objects that API hands out:

* :class:`RequestHandle` — the caller's view of one submitted request.
  The admission controller's verdict (admit / queue / reject — the
  front door's backpressure) is visible on the handle immediately after
  ``submit`` instead of being buried in engine internals, and per-token
  streaming arrives through the handle's ``on_token`` callback.
* :class:`TokenEvent` — one generated token: which request, which
  position in its stream, at what engine time, and whether it is the
  first (TTFT) or last (stream-done) token.  The event contract is
  per-token even when the engine commits K tokens per dispatch
  (DESIGN.md §9): a committed K-block fans out as K events with
  timestamps interpolated across the block's wall time, so streaming
  callbacks and TBT accounting never see the block structure.
* :class:`RebalanceEvent` — one applied elastic boundary move (the
  session-facing view of ``core.elastic.RebalanceDecision``): how many
  device bytes moved between the KV page pool and the weight arena, and
  what it cost (pages swapped to the host tier, models evicted).
* :class:`PrefillBatcher` — the arrival-coalescing phase of the step
  loop.  Admitted same-model requests whose prompts quantize to the SAME
  bucket are packed into one ``[B, S]`` :class:`PrefillGroup` and execute
  as a single streaming-prefill pass; per-request expert routing keeps a
  coalesced pass bit-exact with B separate ``[1, S]`` passes (see
  ``split_exec.make_stage_fns``).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.request import Request


class HandleState(enum.Enum):
    """Lifecycle of a submitted request, as seen through its handle.

    ``QUEUED`` and ``REJECTED`` surface the admission controller's
    backpressure; ``ADMITTED`` means pages are mapped and the weight pin
    is held but the request has not reached a batch slot yet;
    ``DECODING`` covers prefill-committed through last token.
    """

    QUEUED = "queued"
    ADMITTED = "admitted"
    DECODING = "decoding"
    FINISHED = "finished"
    REJECTED = "rejected"
    CANCELLED = "cancelled"

    @property
    def terminal(self) -> bool:
        return self in (HandleState.FINISHED, HandleState.REJECTED,
                        HandleState.CANCELLED)


@dataclass
class TokenEvent:
    """One generated token, as surfaced by ``step``/``on_token``."""

    request_id: int
    model: str
    token: int
    index: int                  # 0-based position in the output stream
    time: float                 # engine virtual time of emission
    first: bool = False         # the TTFT token (sampled by prefill)
    done: bool = False          # stream complete with this token


@dataclass(frozen=True)
class RebalanceEvent:
    """One applied elastic KV<->weights boundary move (DESIGN.md §8).

    Emitted at the step boundary that applied it; ``kv_delta_bytes`` is
    positive when the KV pool grew at the arena's expense.  The sum of
    the two pools' device bytes is invariant across events (byte
    conservation is the rebalancer's contract).
    """

    step: int
    time: float                  # engine virtual time of application
    page_budget: Tuple[int, int]     # (old, new) KV pool pages
    slot_budget: Tuple[int, int]     # (old, new) arena slabs
    kv_delta_bytes: int
    swapped_out: int             # pages pushed to the host swap tier
    evicted_models: int          # idle models LRU-evicted from the arena
    reason: str                  # "kv_demand" | "weight_demand"


@dataclass
class RequestHandle:
    """Caller-side view of one submitted request.

    ``admission`` is the front door's verdict at submit time ("admitted"
    / "queued" / "rejected") and never changes; ``state`` tracks the live
    lifecycle (a queued request that later drains moves to ``ADMITTED``).
    """

    request: Request
    admission: str
    state: HandleState
    on_token: Optional[Callable[[TokenEvent], None]] = None
    # prefix-cache outcome, set at admission (DESIGN.md §11): how many
    # leading prompt tokens were served from the radix tree (0 for
    # cache-off, cache-ineligible — synthetic prompts — or a cold miss)
    cached_tokens: int = 0
    cache_hit: bool = False
    _engine: object = field(default=None, repr=False)

    @property
    def request_id(self) -> int:
        return self.request.request_id

    @property
    def model(self) -> str:
        return self.request.model

    @property
    def tokens(self) -> List[int]:
        """Tokens streamed so far (grows between ``step`` calls)."""
        return list(self.request.output_ids)

    @property
    def done(self) -> bool:
        return self.state.terminal

    def cancel(self) -> bool:
        """Cancel through the owning engine (see ``CrossPoolEngine.cancel``)."""
        return self._engine.cancel(self)


# ---------------------------------------------------------------------------
# prefill coalescing
# ---------------------------------------------------------------------------

#: Prompt-length quantization ladder shared with the seed engine: a prompt
#: occupies the smallest bucket >= its length (capped at max_ctx), so the
#: compiled prefill programs see a handful of static shapes.
PREFILL_BUCKETS = (16, 32, 64, 128, 256, 512)


def prompt_bucket(n: int, max_ctx: int) -> int:
    for b in PREFILL_BUCKETS:
        if n <= b and b <= max_ctx:
            return b
    return max_ctx


@dataclass
class PrefillGroup:
    """Same-model, same-bucket requests coalesced into one [B, S] pass.

    ``ids[i]`` is row i's prompt (synthetic or real, already truncated to
    the bucket); ``n_writes[i]`` is how many of those tokens are real —
    the row's prompt-KV write length and logit position.
    """

    model: str
    bucket: int
    requests: List[Request] = field(default_factory=list)
    ids: List[np.ndarray] = field(default_factory=list)
    n_writes: List[int] = field(default_factory=list)
    # prefix-cache suffix group (DESIGN.md §11): ``fork`` > 0 marks a B=1
    # group whose first ``fork`` prompt tokens are mapped from the radix
    # tree — ``ids[0]`` then holds only the SUFFIX, padded to
    # ``suffix_bucket``, while ``bucket`` stays the FULL prompt's bucket
    # (the cache key and the suffix pass's KV reduction extent)
    fork: int = 0
    suffix_bucket: int = 0

    @property
    def batch_size(self) -> int:
        return len(self.requests)

    def tokens(self) -> np.ndarray:
        """[B, bucket] int32 prompt ids."""
        return np.stack(self.ids).astype(np.int32)

    def true_lens(self):
        """Per-row unpadded lengths: host int for B=1 (the seed trace
        shape), a list for a genuinely coalesced batch."""
        if len(self.n_writes) == 1:
            return self.n_writes[0]
        return list(self.n_writes)


class PrefillBatcher:
    """Select admitted requests for this step and coalesce their prompts.

    Selection mirrors the seed driver exactly — requests are considered
    in waiting order, capped per model by the runner's free batch slots,
    and a cold model that cannot activate under arena pressure stays
    waiting — then selected requests are grouped by (model, bucket) in
    first-seen order.  Prompt ids are drawn (or taken from
    ``request.prompt_ids``) at SELECTION time in waiting order, so the
    id stream is independent of how groups later execute (sequentially,
    batched, or interleaved through the pipeline scheduler).
    """

    def __init__(self, observer=None):
        # optional runtime.observe.EngineObserver: counts WHY a waiting
        # request was deferred this step (batch slots full vs. residency
        # gate) — None is the zero-overhead default
        self.observer = observer

    def plan(self, waiting: List[Request], runners: Dict[str, object],
             rng: np.random.Generator,
             try_activate: Callable[[Request], bool],
             forks: Optional[Dict[int, int]] = None,
             ) -> Tuple[List[PrefillGroup], List[Request]]:
        """Returns (groups in first-seen order, still-waiting requests).

        ``try_activate(request)`` is the engine's residency gate: weight
        slabs mapped for the model AND any host-swapped KV pages faulted
        back in for the request — False keeps the request waiting (pins
        drop and pages free as other requests finish).

        ``forks`` maps request_id -> cached-prefix length for prefix-cache
        hits: such a request becomes its own B=1 SUFFIX group (keyed by
        its id so it never coalesces — its shapes are fork-specific) whose
        ids cover only the uncached tail, padded to the tail's bucket."""
        groups: Dict[Tuple, PrefillGroup] = {}
        still: List[Request] = []
        taken: Dict[str, int] = {}
        obs = self.observer
        for req in waiting:
            runner = runners[req.model]
            free = sum(1 for s in runner.slots if s is None)
            if free == 0 or taken.get(req.model, 0) >= free:
                still.append(req)
                if obs is not None:
                    obs.batcher_deferral(req.model, "slots")
                continue
            if not try_activate(req):
                still.append(req)
                if obs is not None:
                    obs.batcher_deferral(req.model, "residency")
                continue
            taken[req.model] = taken.get(req.model, 0) + 1
            bucket = prompt_bucket(req.prompt_tokens, runner.max_ctx)
            fork = (forks or {}).get(req.request_id, 0)
            if fork > 0:
                real = np.asarray(req.prompt_ids, np.int32).reshape(-1)
                n_suf = req.prompt_tokens - fork
                s_bucket = prompt_bucket(n_suf, runner.max_ctx)
                ids = np.zeros(s_bucket, np.int32)
                ids[:n_suf] = real[fork:req.prompt_tokens]
                g = PrefillGroup(req.model, bucket, fork=fork,
                                 suffix_bucket=s_bucket)
                groups[(req.model, bucket, req.request_id)] = g
                g.requests.append(req)
                g.ids.append(ids)
                g.n_writes.append(n_suf)
                continue
            ids, n_write = self._prompt_ids(req, runner.cfg, bucket, rng)
            key = (req.model, bucket)
            g = groups.get(key)
            if g is None:
                g = groups[key] = PrefillGroup(req.model, bucket)
            g.requests.append(req)
            g.ids.append(ids)
            g.n_writes.append(n_write)
        return list(groups.values()), still

    @staticmethod
    def _prompt_ids(req: Request, cfg, bucket: int,
                    rng: np.random.Generator) -> Tuple[np.ndarray, int]:
        """(row ids [bucket], real-token count).  Prompts longer than the
        bucket are truncated to it, exactly as the seed dense prefill's
        fixed-width cache slice did."""
        if req.prompt_ids is not None:
            real = np.asarray(req.prompt_ids, np.int32).reshape(-1)
            # pages were mapped and the batch-slot length will be set from
            # ``prompt_tokens`` — a mismatched id array would scatter KV
            # past the mapped pages (or attend over never-written ones)
            assert len(real) == req.prompt_tokens, (
                f"request {req.request_id}: prompt_ids length {len(real)} "
                f"!= prompt_tokens {req.prompt_tokens}")
            n = min(req.prompt_tokens, bucket)
            ids = np.zeros(bucket, np.int32)
            ids[:n] = real[:n]
            return ids, n
        ids = rng.integers(0, cfg.vocab_size, bucket).astype(np.int32)
        return ids, min(req.prompt_tokens, bucket)
