"""Public jit'd wrappers for the kernel layer.

Implementation selection:
  * ``xla``     — pure-jnp reference (ref.py).  Default; used by the
                  distributed dry-run so cost_analysis sees real FLOPs.
  * ``pallas``  — pl.pallas_call TPU kernels, run in interpret mode on CPU.

Select globally via :func:`set_default_impl` or per-call via ``impl=``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref

_DEFAULT_IMPL = "xla"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas")
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def _resolve(impl: Optional[str]) -> str:
    return impl or _DEFAULT_IMPL


# --- flash attention -------------------------------------------------------

def flash_attention(q, k, v, *, scale: float, impl: Optional[str] = None):
    if _resolve(impl) == "pallas":
        from repro.kernels import flash_attention as fk
        return fk.flash_attention(q, k, v, scale=scale)
    return ref.flash_attention(q, k, v, scale)


# --- decode attention ------------------------------------------------------

def decode_attention(q, cache_k, cache_v, lengths, *, scale: float,
                     impl: Optional[str] = None):
    if _resolve(impl) == "pallas":
        from repro.kernels import paged_attention as pk
        return pk.contiguous_decode_attention(q, cache_k, cache_v, lengths,
                                              scale=scale)
    return ref.decode_attention(q, cache_k, cache_v, lengths, scale)


def paged_decode_attention(q, kv_pages, page_table, lengths, *, scale: float,
                           impl: Optional[str] = None):
    if _resolve(impl) == "pallas":
        from repro.kernels import paged_attention as pk
        return pk.paged_decode_attention(q, kv_pages, page_table, lengths,
                                         scale=scale)
    return ref.paged_decode_attention(q, kv_pages, page_table, lengths, scale)


# --- grouped expert GEMM ---------------------------------------------------

def moe_gemm(x, w, group_sizes, *, impl: Optional[str] = None):
    if _resolve(impl) == "pallas":
        from repro.kernels import moe_gemm as mk
        return mk.moe_gemm(x, w, group_sizes)
    return ref.moe_gemm(x, w, group_sizes)


# --- Mamba2 SSD ------------------------------------------------------------

def ssd_scan(x, dt, A, B_, C_, *, chunk: int = 64, h0=None,
             impl: Optional[str] = None):
    if _resolve(impl) == "pallas":
        from repro.kernels import ssd_scan as sk
        return sk.ssd_scan(x, dt, A, B_, C_, chunk=chunk, h0=h0)
    from repro.kernels.ssd_chunked import ssd_scan_chunked
    return ssd_scan_chunked(x, dt, A, B_, C_, chunk=chunk, h0=h0)
