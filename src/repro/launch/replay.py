"""Deterministic session replay: ``python -m repro.launch.replay rec.json``.

Reconstructs the engine (models, ``EngineConfig``, pool geometry) from a
flight record (``runtime.flightrec``), re-drives the recorded op stream
with the recorded virtual clock injected, re-records the replay with its
own flight recorder, and diffs the two records:

  * **token streams** — every request's token ids AND virtual emission
    times, bit-exact (JSON round-trips Python floats exactly);
  * **event ring** — ops, clock reads, commits, rebalance decisions,
    cache/swap traffic, SLO breaches: the whole causal + derived stream;
  * **snapshots + final pool accounting** — page holder classes, slab
    residency, refcounts;
  * **failure** — an incident record (sanitizer/accounting error) must
    reproduce the SAME error type and rule at the SAME step.

Determinism argument (DESIGN.md §13): the engine's only nondeterministic
input is ``time.perf_counter`` at its dispatch-duration sites, and those
are injected from the record.  Everything else — params from
``PRNGKey(i)`` in model-dict order, synthetic prompt ids drawn from the
fixed-seed engine rng at batcher selection, planner Monte Carlo on a
fixed seed, telemetry folds — is a pure function of the op stream.

Exit status: 0 on a bit-exact replay (including a reproduced failure),
1 on any mismatch.
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.analysis.sanitizer import PoolSanitizerError
from repro.core.errors import PoolAccountingError
from repro.runtime import flightrec
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime.request import Request


class ReplayError(RuntimeError):
    """The record cannot be replayed at all (vs. replaying and
    mismatching): causal events were dropped from the bounded ring, or
    the record is structurally invalid."""


@dataclass
class ReplayReport:
    ok: bool
    mismatches: List[str] = field(default_factory=list)
    ops: int = 0
    steps: int = 0
    tokens: int = 0
    failure_reproduced: Optional[bool] = None   # None: healthy record

    def summary(self) -> str:
        verdict = "BIT-EXACT" if self.ok else "MISMATCH"
        line = (f"replay {verdict}: {self.ops} ops, {self.steps} steps, "
                f"{self.tokens} tokens")
        if self.failure_reproduced is not None:
            line += (", failure reproduced" if self.failure_reproduced
                     else ", failure NOT reproduced")
        return line


def load_record(path: str) -> Dict[str, Any]:
    with open(path) as f:
        record = json.load(f)
    version = record.get("version")
    if version != flightrec.RECORD_VERSION:
        raise ReplayError(f"record version {version!r} != "
                          f"{flightrec.RECORD_VERSION}")
    drops = flightrec.causal_drops(record)
    if drops:
        raise ReplayError(
            f"causal events were dropped from the bounded ring {drops}; "
            f"re-record with a larger FlightRecorderConfig.ring_size")
    return record


def build_engine(record: Dict[str, Any]) -> CrossPoolEngine:
    """Engine bit-identical to the recorded one: same model dict order
    (params come from ``PRNGKey(i)`` in that order), same pool geometry,
    same config — recorder ON (for the re-record diff) but never
    auto-dumping."""
    h = record["engine"]
    models = {name: flightrec.model_config_from_dict(d)
              for name, d in h["models"].items()}
    config = flightrec.engine_config_from_header(h, dump_path=None)
    config = config.__class__(
        mode=EngineMode(**h["mode"]), elastic=config.elastic,
        cache=config.cache, sanitize=config.sanitize, slo=config.slo,
        flightrec=config.flightrec)
    return CrossPoolEngine(
        models, page_budget=h["page_budget"], page_bytes=h["page_bytes"],
        slot_budget=h["slot_budget"], slab_bytes=h["slab_bytes"],
        max_batch=h["max_batch"], max_ctx=h["max_ctx"], seed=h["seed"],
        config=config)


def _request_from_dict(d: Dict[str, Any]) -> Request:
    ids = d["prompt_ids"]
    return Request(
        request_id=d["request_id"], model=d["model"],
        prompt_tokens=d["prompt_tokens"],
        max_new_tokens=d["max_new_tokens"],
        arrival_time=d["arrival_time"],
        prompt_ids=(None if ids is None
                    else np.asarray(ids, dtype=np.int32)),
        eos_id=d["eos_id"], cache=d["cache"])


def _apply_op(engine: CrossPoolEngine, op: Dict[str, Any]) -> None:
    kind = op["op"]
    if kind == "submit":
        # set the clock directly (advance() would record an extra op the
        # original stream does not have); submit re-records the op
        engine.now = max(engine.now, float(op["now"]))
        engine.submit(_request_from_dict(op["request"]))
    elif kind == "step":
        engine.step(op["now"])
    elif kind == "advance":
        engine.advance(op["now"])
    elif kind == "cancel":
        engine.now = max(engine.now, float(op["now"]))
        if op["rid"] in engine.handles:
            engine.cancel(op["rid"])
    elif kind == "reset_stats":
        engine.reset_stats()
    elif kind == "inject":
        flightrec.inject_corruption(engine, op["corruption"])
    else:
        raise ReplayError(f"unknown op kind {kind!r}")


def _normalize(obj: Any) -> Any:
    """JSON round-trip: the loaded record went through it, so the
    re-recorded one must too before a deep-equality diff (tuples become
    lists, dict keys become strings, floats stay bit-exact)."""
    return json.loads(json.dumps(obj))


def _strip_in_step(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Cancel ops lose their ``in_step`` flag before diffing: a cancel
    issued from inside an ``on_token`` callback was DEFERRED to the step
    boundary in the original, and the replayer (which does not re-drive
    user callbacks) applies it just after the step — the end state is
    identical, only this flag differs (DESIGN.md §13)."""
    out = []
    for e in events:
        if e["kind"] == "op" and e.get("op") == "cancel":
            e = {k: v for k, v in e.items() if k != "in_step"}
        out.append(e)
    return out


def _diff(name: str, got: Any, want: Any, mismatches: List[str]) -> None:
    if got == want:
        return
    detail = ""
    if isinstance(got, list) and isinstance(want, list):
        if len(got) != len(want):
            detail = f" (length {len(got)} vs {len(want)})"
        else:
            for i, (g, w) in enumerate(zip(got, want)):
                if g != w:
                    detail = f" (first divergence at [{i}]: {g!r} != {w!r})"
                    break
    elif isinstance(got, dict) and isinstance(want, dict):
        keys = [k for k in set(got) | set(want)
                if got.get(k) != want.get(k)]
        detail = f" (diverging keys: {sorted(keys)[:4]})"
    mismatches.append(f"{name} mismatch{detail}")


def replay(record: Dict[str, Any]) -> ReplayReport:
    """Re-drive the record and diff the re-recorded session against it."""
    engine = build_engine(record)
    engine.attach_replay_clock(flightrec.record_clock(record))
    ops = flightrec.record_ops(record)
    report = ReplayReport(ok=False, ops=len(ops))
    failure_seen: Optional[Dict[str, Any]] = None
    for op in ops:
        try:
            _apply_op(engine, op)
        except (PoolSanitizerError, PoolAccountingError) as err:
            failure_seen = {
                "step": engine._step_index,
                "type": type(err).__name__,
                "rule": getattr(err, "rule", None),
            }
            break
    replayed = _normalize(engine.recorder.to_record())
    report.steps = engine._step_index
    report.tokens = sum(len(s["tokens"])
                        for s in replayed["streams"].values())

    mism = report.mismatches
    _diff("token streams", replayed["streams"], record["streams"], mism)
    _diff("event ring", _strip_in_step(replayed["events"]),
          _strip_in_step(record["events"]), mism)
    rb = [e for e in replayed["events"] if e["kind"] == "rebalance"]
    rb_want = [e for e in record["events"] if e["kind"] == "rebalance"]
    _diff("rebalance decisions", rb, rb_want, mism)
    _diff("pool snapshots", replayed["snapshots"], record["snapshots"],
          mism)
    _diff("final pool accounting", replayed["final"], record["final"],
          mism)
    want_failure = record.get("failure")
    if want_failure is not None:
        got = (None if failure_seen is None else
               {k: failure_seen[k] for k in ("step", "type", "rule")})
        want = {k: want_failure[k] for k in ("step", "type", "rule")}
        report.failure_reproduced = got == want
        if not report.failure_reproduced:
            mism.append(f"failure mismatch: replay {got!r} vs "
                        f"record {want!r}")
    elif failure_seen is not None:
        mism.append(f"replay failed where the record did not: "
                    f"{failure_seen!r}")
    if engine._replay_dts:
        mism.append(f"{len(engine._replay_dts)} recorded clock entries "
                    f"left unconsumed")
    report.ok = not mism
    return report


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="replay a CrossPool flight record and assert the "
                    "session reproduces bit-exactly")
    ap.add_argument("record", help="flight-record JSON "
                    "(serve --flight-record-out / auto-dump)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="summary line only")
    args = ap.parse_args(argv)

    record = load_record(args.record)
    h = record["engine"]
    if not args.quiet:
        print(f"record: {len(record['events'])} events, "
              f"{len(record['streams'])} streams, "
              f"{len(record['snapshots'])} snapshots, "
              f"models={list(h['models'])}")
        if record.get("failure"):
            f = record["failure"]
            print(f"incident record: {f['type']}"
                  f"{' rule ' + f['rule'] if f.get('rule') else ''} "
                  f"at step {f['step']}")
    report = replay(record)
    print(report.summary())
    for m in report.mismatches:
        print(f"  {m}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
