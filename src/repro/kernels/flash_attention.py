"""Pallas TPU flash attention (prefill): causal GQA with online softmax.

Grid layout: ``(batch, q_head, q_blocks, kv_blocks)`` with the kv-block
dimension innermost and sequential ("arbitrary"), carrying the online-softmax
state (m, l, acc) in VMEM scratch.  Causally-masked-out kv blocks are skipped
with ``pl.when`` — on real TPU this prunes ~half the grid.

Block shapes are the VMEM working set:
  q block   [1, block_q, 1, D]
  k/v block [1, block_k, 1, D]   (the kv head of the current q head)
  scratch   acc [block_q, D] f32, m/l [block_q, 128] f32

``D`` and the block sizes should be multiples of 128 for MXU alignment on
hardware; the kernel itself is shape-generic and is validated on CPU in
interpret mode against ``ref.flash_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_k: int, seq_q: int,
                  seq_k: int, causal: bool):
    i = pl.program_id(2)              # q block index
    j = pl.program_id(3)              # kv block index
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal offset: query at row r attends keys <= r + (seq_k - seq_q)
    offset = seq_k - seq_q
    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)           # [bq, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # [bk, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        # zero the OOB kv padding rows: p is 0 there, but 0 * garbage = NaN
        k_valid = (j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, 1), 0)) < seq_k
        v = jnp.where(k_valid, v, 0.0)
        s = (q @ k.T) * scale                               # [bq, bk]
        if causal:
            s = jnp.where(q_pos + offset >= k_pos, s, NEG_INF)
        s = jnp.where(k_pos < seq_k, s, NEG_INF)            # kv padding

        m_prev = m_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[:, 0] = m_cur

    if causal:
        # skip kv blocks fully above the diagonal
        pl.when(j * block_k <= (i + 1) * block_q - 1 + offset)(_compute)
    else:
        _compute()

    @pl.when(j == nk - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, block_q: int = 128, block_k: int = 128,
                    causal: bool = True, interpret: bool = True) -> jax.Array:
    """q: [B,S,H,D]; k/v: [B,T,KV,D] -> [B,S,H,D] (causal, GQA)."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    nq = pl.cdiv(S, block_q)
    nk = pl.cdiv(T, block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_q=S, seq_k=T, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
