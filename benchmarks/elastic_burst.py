"""Bursty long-context wave: elastic rebalancing vs the static split.

The paper's headline scenario (fig6/fig7 premise): a wave of long-context
requests arrives at a pool that was provisioned for calm traffic.  With
the seed's FROZEN split the wave queues at admission while idle weight
slabs sit on device; with the elastic rebalancer (DESIGN.md §8) the
windowed Eq. (1)-(2) re-plan converts that idle arena slack into KV pages
at step boundaries and the wave is admitted at materially higher
concurrency — AT EQUAL TOTAL DEVICE BYTES (byte conservation is the
rebalancer's contract, asserted per applied move).

Both engines serve the identical burst: 12 long-prompt requests for the
MLA model (dense FFN — token streams are batch-composition independent,
so the two engines' outputs are comparable) while the two MoE models sit
registered-but-idle, which is exactly the slack a static split strands.

Recorded in BENCH_summary.json; the guarded metric is the
static/elastic peak-admitted-concurrency ratio (a deterministic integer
ratio — machine speed cancels entirely), expected well under 1.  P99
queue time and P99 TBT ride along unguarded (wall-clock, reported for
the trajectory).  A third engine re-serves the burst with
``decode_steps_per_dispatch=4``: rebalancing happens only at dispatch
boundaries, so the grow must still fire between K-token blocks with
bit-exact streams (the multi-step composition check, DESIGN.md §9).
"""
from __future__ import annotations

import numpy as np

from benchmarks._stats import percentile
from repro.configs import (ElasticConfig, EngineConfig, PAPER_COLOC_SET,
                           get_smoke_config)
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime.observe import EngineObserver
from repro.runtime.request import Request

#: the serving target (MLA, dense FFN) and the burst shape
TARGET = "minicpm3-4b"
BURST = 12
PROMPT = 32
MAX_NEW = 4
PAGE_BUDGET = 8          # calm-traffic KV provisioning: pressure on arrival
PAGE_BYTES = 4096
SLAB_BYTES = 4096


def _models():
    return {n: get_smoke_config(n).replace(dtype="float32")
            for n in PAPER_COLOC_SET}


def _engine(elastic: bool, decode_steps: int = 1) -> CrossPoolEngine:
    # every engine carries an observer: the TBT tail below is read from
    # the shared latency histograms (ISSUE 7), and the observer is pure
    # bookkeeping, so the guarded integer ratio is unaffected
    return CrossPoolEngine(
        _models(), page_budget=PAGE_BUDGET, page_bytes=PAGE_BYTES,
        slab_bytes=SLAB_BYTES, max_batch=8, max_ctx=64, seed=0,
        observer=EngineObserver(),
        config=EngineConfig(
            mode=EngineMode(pipeline=True, lowering=True,
                            decode_steps_per_dispatch=decode_steps),
            # one-jump growth (max_step_fraction >> 1): every resize
            # changes the pool SHAPE and recompiles the fused step, so a
            # burst response wants one large aligned move, not eight
            # geometric ones
            elastic=ElasticConfig(interval_steps=2, cooldown_steps=2,
                                  hysteresis=0.05, window_s=60.0,
                                  max_step_fraction=32.0,
                                  min_page_budget=PAGE_BUDGET)
            if elastic else None))


def _burst():
    rng = np.random.default_rng(7)
    cfg = get_smoke_config(TARGET)
    return [Request(i, TARGET, PROMPT, MAX_NEW, 0.0,
                    prompt_ids=rng.integers(0, cfg.vocab_size, PROMPT))
            for i in range(BURST)]


def _admitted_now(engine) -> int:
    """Requests holding pool resources right now: slotted + admitted-
    waiting (queued ones hold nothing — that is the deficit we measure)."""
    slotted = sum(1 for r in engine.runners.values()
                  for s in r.slots if s is not None)
    return slotted + len(engine.waiting)


def _serve_burst(engine):
    """Submit the whole wave at t=0 and step to completion, tracking the
    peak admitted concurrency the split allowed."""
    reqs = _burst()
    for r in reqs:
        r.arrival_time = engine.now
        engine.submit(r)
    peak = _admitted_now(engine)
    steps = 0
    while (engine.busy or engine.admission.queued_count()) and steps < 500:
        steps += 1
        events = engine.step()
        peak = max(peak, _admitted_now(engine))
        if not events and not engine.busy:
            break
    stats = engine.finalize()
    queue_waits = [r.admit_time - r.arrival_time for r in reqs
                   if r.admit_time >= r.arrival_time and r.finish_time > 0]
    return reqs, stats, peak, queue_waits


def _warmup(engine):
    """Compile the prefill/decode shapes the burst will hit, then open a
    fresh measurement window."""
    rng = np.random.default_rng(3)
    cfg = get_smoke_config(TARGET)
    reqs = [Request(10_000 + i, TARGET, PROMPT, 2, 0.0,
                    prompt_ids=rng.integers(0, cfg.vocab_size, PROMPT))
            for i in range(2)]
    engine.run(reqs)
    assert engine.stats.tokens_out > 0
    engine.reset_stats()


def run(csv=print) -> dict:
    eng_s, eng_e = _engine(False), _engine(True)
    _warmup(eng_s)
    _warmup(eng_e)
    reqs_s, stats_s, peak_s, qw_s = _serve_burst(eng_s)
    reqs_e, stats_e, peak_e, qw_e = _serve_burst(eng_e)

    # equal total device bytes, conserved across every applied move
    # (warmup may legitimately apply the first grow — the windowed
    # estimator sees demand as soon as traffic exists — so the applied
    # moves are checked over the rebalancer's LIFETIME, not the
    # measurement window)
    total_s = (eng_s.virt.page_budget * PAGE_BYTES
               + eng_s.arena.slot_budget * SLAB_BYTES)
    assert eng_e.rebalancer.total_bytes == total_s, \
        "the two engines were not provisioned with equal device bytes"
    moves = eng_e.rebalancer.events
    for d in moves:
        moved_total = (d.new_page_budget * PAGE_BYTES
                       + d.new_slot_budget * SLAB_BYTES)
        assert moved_total <= eng_e.rebalancer.total_bytes, \
            "rebalance violated byte conservation"

    # both engines must finish the whole wave with the same token volume
    assert stats_s.tokens_out == stats_e.tokens_out == BURST * MAX_NEW, \
        (stats_s.tokens_out, stats_e.tokens_out)
    # ... and identical per-request streams (dense target model)
    by_id = {r.request_id: r for r in reqs_e}
    for r in reqs_s:
        assert r.output_ids == by_id[r.request_id].output_ids, \
            f"request {r.request_id} diverged between the two splits"

    assert moves, "the elastic engine never rebalanced"
    assert any(d.new_page_budget > d.old_page_budget for d in moves), \
        "no KV grow was applied under page pressure"
    assert eng_e.virt.page_budget > PAGE_BUDGET
    # THE paper claim: strictly higher admitted concurrency at equal bytes
    assert peak_e > peak_s, (peak_e, peak_s)

    # --- multi-step composition: the same burst on an elastic K=4 engine.
    # Rebalances stay at dispatch boundaries (DESIGN.md §9), so the grow
    # must still fire between K-token blocks and the token streams must be
    # bit-exact vs the K=1 elastic engine (greedy, dense target model).
    # The K=1 pair above stays the guarded headline: K=4 finishes each
    # request in fewer steps, so its peak concurrency is a different
    # serving profile, not a stronger/weaker rebalancer.
    eng_e4 = _engine(True, decode_steps=4)
    _warmup(eng_e4)
    reqs_e4, stats_e4, peak_e4, _ = _serve_burst(eng_e4)
    assert stats_e4.tokens_out == stats_e.tokens_out
    by_id_e = {r.request_id: r for r in reqs_e}
    for r in reqs_e4:
        assert r.output_ids == by_id_e[r.request_id].output_ids, \
            f"request {r.request_id} diverged between K=1 and K=4 elastic"
    assert eng_e4.rebalancer.events, \
        "the K=4 elastic engine never rebalanced"
    assert eng_e4.virt.page_budget > PAGE_BUDGET
    assert peak_e4 > peak_s, (peak_e4, peak_s)

    q99_s, q99_e = percentile(qw_s, 99), percentile(qw_e, 99)
    # TBT tail from the shared observer histograms; they must hold exactly
    # the window the EngineStats lists recorded
    assert sorted(eng_s.observer.tbt.all_samples()) == sorted(stats_s.tbt)
    assert sorted(eng_e.observer.tbt.all_samples()) == sorted(stats_e.tbt)
    tbt99_s = eng_s.observer.tbt.percentile(99)
    tbt99_e = eng_e.observer.tbt.percentile(99)
    swap = eng_e.virt.utilization()
    csv(f"elastic_burst,peak_admitted_static={peak_s},"
        f"peak_admitted_elastic={peak_e}")
    csv(f"elastic_burst,queue_p99_static_s={q99_s:.4f},"
        f"queue_p99_elastic_s={q99_e:.4f}")
    csv(f"elastic_burst,tbt_p99_static_ms={tbt99_s * 1e3:.2f},"
        f"tbt_p99_elastic_ms={tbt99_e * 1e3:.2f}")
    csv(f"elastic_burst,rebalances={len(moves)},"
        f"final_pages={eng_e.virt.page_budget},"
        f"final_slabs={eng_e.arena.slot_budget},"
        f"swap_out={swap['swap_out_pages']},swap_in={swap['swap_in_pages']}")
    csv(f"elastic_burst,k4_peak_admitted={peak_e4},"
        f"k4_rebalances={len(eng_e4.rebalancer.events)},"
        f"k4_final_pages={eng_e4.virt.page_budget}")
    return {
        "peak_admitted_static": int(peak_s),
        "peak_admitted_elastic": int(peak_e),
        "peak_admitted_elastic_k4": int(peak_e4),
        # the guarded ratio: deterministic integers, lower is better
        "static_over_elastic_peak_admitted": peak_s / peak_e,
        "queue_p99_static_s": q99_s,
        "queue_p99_elastic_s": q99_e,
        "tbt_p99_static_s": tbt99_s,
        "tbt_p99_elastic_s": tbt99_e,
        "rebalances": len(moves),
        "final_page_budget": int(eng_e.virt.page_budget),
        "final_slot_budget": int(eng_e.arena.slot_budget),
        "swap_out_pages": int(swap["swap_out_pages"]),
        "swap_in_pages": int(swap["swap_in_pages"]),
        # device-byte utilization: mapped KV + resident slabs over total
        "device_byte_util_static": (
            (eng_s.virt.peak_mapped * PAGE_BYTES
             + eng_s.arena.resident_slabs * SLAB_BYTES) / total_s),
        "device_byte_util_elastic": (
            (eng_e.virt.peak_mapped * PAGE_BYTES
             + eng_e.arena.resident_slabs * SLAB_BYTES) / total_s),
    }


if __name__ == "__main__":
    run()
