"""Elastic pool rebalancer: online KV<->weights boundary repartitioning.

The paper's premise is that KV-cache demand is transient and
workload-determined while weights demand is stable and model-determined —
yet the seed system fixed the split between ``KVVirtualizer.pool`` and
``WeightArena`` ONCE, offline (``planner.split_device_budget``).  This
module moves that boundary ONLINE (DESIGN.md §8), the MemServe / eLLM
observation applied to our two-pool design: at session step boundaries a
windowed Eq. (1)-(2) estimate (``planner.replan_split`` over
``runtime.telemetry`` specs) re-splits the SAME total device-byte budget,
and the pools are live-resized — one grows, the other shrinks — in
page/slab-aligned increments.

Safety rules (the ordering invariants the tests enforce):

  * **byte conservation**: ``page_budget * page_bytes + slot_budget *
    slab_bytes`` never exceeds the budget captured at construction; a
    grow is only applied after the matching shrink freed the bytes;
  * **shrinks never kill in-flight work**: the KV pool shrinks through
    the virtualizer's host swap tier (coldest pages of longest-idle
    requests; protected = currently-slotted requests are exempt) and the
    arena shrinks through LRU eviction of idle unpinned models — both
    raise, leaving state consistent, if the floor is violated;
  * **damped decisions**: hysteresis (minimum fractional change),
    cooldown (minimum steps between applied moves) and a per-move rate
    limit keep a bursty signal from thrashing the boundary.  Decisions
    are DETERMINISTIC for a fixed observation stream: the Monte Carlo
    re-plan runs on a fixed seed.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ElasticConfig
from repro.core.planner import replan_split
from repro.core.virtualizer import KVVirtualizer, OutOfPagesError
from repro.core.weight_pool import OutOfSlabsError, WeightArena


@dataclass(frozen=True)
class RebalanceDecision:
    """One applied boundary move (surfaced as a session RebalanceEvent)."""

    step: int
    now: float
    old_page_budget: int
    new_page_budget: int
    old_slot_budget: int
    new_slot_budget: int
    swapped_out: int               # KV pages pushed to the host swap tier
    evicted_models: int            # arena models LRU-evicted by the shrink
    moved_pages: int               # survivors compacted by the pool gather
    moved_slabs: int
    reason: str                    # "kv_demand" | "weight_demand"

    @property
    def kv_grew(self) -> bool:
        return self.new_page_budget > self.old_page_budget

    def to_record(self) -> Dict[str, object]:
        """Stable flight-record form — the replayer compares the applied
        decision SEQUENCE across record/replay field-by-field, so this is
        schema, not convenience: keep it in sync with DESIGN.md §13."""
        return {
            "step": self.step,
            "now": self.now,
            "page_budget": [self.old_page_budget, self.new_page_budget],
            "slot_budget": [self.old_slot_budget, self.new_slot_budget],
            "swapped_out": self.swapped_out,
            "evicted_models": self.evicted_models,
            "moved_pages": self.moved_pages,
            "moved_slabs": self.moved_slabs,
            "reason": self.reason,
        }


class ElasticRebalancer:
    """Step-boundary driver of the live KV<->weights repartition."""

    def __init__(self, virt: KVVirtualizer, arena: Optional[WeightArena],
                 *, admission=None, telemetry=None,
                 cfg: Optional[ElasticConfig] = None, seed: int = 0):
        self.virt = virt
        self.arena = arena
        self.admission = admission
        self.telemetry = telemetry
        self.cfg = cfg or ElasticConfig()
        self.seed = seed
        # the conserved budget: whatever the session started with
        self.total_bytes = virt.page_budget * virt.page_bytes
        if arena is not None:
            self.total_bytes += arena.slot_budget * arena.slab_bytes
        self._step = 0
        self._last_applied = -(10 ** 9)
        self.events: List[RebalanceDecision] = []
        # decision counters (report / determinism tests)
        self.evaluations = 0
        self.skipped_hysteresis = 0
        self.skipped_cooldown = 0
        self.skipped_no_signal = 0
        self.aborted = 0
        # optional observability sink (core.hooks.CoreHooks); fires once
        # per APPLIED decision, after both pools finished resizing
        self.hooks = None
        # optional prefix cache (core.prefix_cache.PrefixCache): its
        # hit-token fraction discounts the re-plan's KV demand — cached
        # prompt tokens map shared tree pages at zero marginal cost
        # (DESIGN.md §11)
        self.cache = None

    # ------------------------------------------------------------------
    # floors and clamps
    # ------------------------------------------------------------------
    def _page_floor(self, protected) -> int:
        """Pages a shrink must retain: every protected (slotted) request's
        mapping grown to cover its REMAINING declared output — the same
        reservation admission made, so no later decode step of an
        in-flight request can exhaust the shrunk budget ("shrinks never
        kill in-flight requests" must hold for the request's whole
        lifetime, not just its next token).

        ``protected`` maps request id -> remaining output tokens (a bare
        id sequence is accepted with a 1-token reservation).
        """
        floor = self.cfg.min_page_budget
        remaining = (protected if hasattr(protected, "get")
                     else {rid: 1 for rid in protected})
        held = 0
        for rid, left in remaining.items():
            req = self.virt.requests.get(rid)
            if req is None:
                continue
            view = self.virt.views[req.model]
            if view.n_kv_layers:
                chunks = math.ceil(max(req.tokens + max(left, 1), 1)
                                   / view.tokens_per_page)
                held += chunks * view.n_kv_layers
            held += len(req.state_pages)
        return max(floor, held, 1)

    def _slot_floor(self) -> int:
        if self.arena is None:
            return 0
        return self.arena.min_slot_budget()

    def _clamp(self, target_pages: int, protected
               ) -> Optional[Tuple[int, int]]:
        """Conservation + floors + rate limit -> (pages, slots) or None."""
        pb = self.virt.page_bytes
        sb = self.arena.slab_bytes if self.arena is not None else 0
        cur_pages = self.virt.page_budget
        cur_slots = self.arena.slot_budget if self.arena is not None else 0
        page_floor = self._page_floor(protected)
        slot_floor = self._slot_floor()
        if self.arena is None or sb == 0:
            return None                     # nothing to trade against
        # rate limit BOTH pools' moves, then respect floors + conservation
        frac = self.cfg.max_step_fraction
        max_page_move = max(int(frac * cur_pages), 1)
        pages = min(max(target_pages, cur_pages - max_page_move),
                    cur_pages + max_page_move)
        page_ceiling = (self.total_bytes - slot_floor * sb) // pb
        pages = int(min(max(pages, page_floor), page_ceiling))
        if pages < page_floor:
            return None                     # floors don't fit the budget
        max_slot_move = max(int(frac * cur_slots), 1)
        slots = int((self.total_bytes - pages * pb) // sb)
        slots = min(max(slots, cur_slots - max_slot_move),
                    cur_slots + max_slot_move)
        slots = max(slots, slot_floor)
        # conservation under the (possibly slot-rate-limited) arena size;
        # min() keeps the page move inside its own rate limit too
        pages = int(min(pages, (self.total_bytes - slots * sb) // pb))
        if pages < page_floor:
            return None
        return pages, slots

    # ------------------------------------------------------------------
    # the decision
    # ------------------------------------------------------------------
    def would_evaluate(self) -> bool:
        """Whether the NEXT ``step`` call reaches the re-plan (mirrors the
        interval/cooldown gates at the top of :meth:`step` exactly — keep
        the two in sync).  Lets the engine skip assembling the protected /
        live-request views on the steps that would discard them."""
        cfg = self.cfg
        if not cfg.enabled or self.telemetry is None or self.arena is None:
            return False
        nxt = self._step + 1
        if nxt % max(cfg.interval_steps, 1) != 0:
            return False
        return nxt - self._last_applied >= cfg.cooldown_steps

    def step(self, now: float, *, protected=(),
             live_requests: Optional[Dict] = None
             ) -> Optional[RebalanceDecision]:
        """Evaluate (and maybe apply) one rebalance at a step boundary.

        ``protected`` is the slotted-request reservation — a mapping of
        request id -> remaining output tokens (or a bare id sequence for
        a 1-token reservation).  Called once per session step; the
        interval / cooldown / hysteresis dampers decide whether anything
        actually moves.  Returns the applied decision, or None.
        """
        self._step += 1
        cfg = self.cfg
        if not cfg.enabled or self.telemetry is None or self.arena is None:
            return None
        # fault-in headroom: pages in the host swap tier will need free
        # device pages on their next touch — hold that many back from
        # admission so a fresh burst cannot starve the fault path
        if self.admission is not None:
            self.admission.reserve_pages = (
                self.virt.swapped_now + max(cfg.headroom_pages, 0))
        if self._step % max(cfg.interval_steps, 1) != 0:
            return None
        if self._step - self._last_applied < cfg.cooldown_steps:
            self.skipped_cooldown += 1
            return None
        self.evaluations += 1
        specs = self.telemetry.window_specs(now, live_requests)
        if not specs:
            self.skipped_no_signal += 1
            return None
        try:
            cached_frac = 0.0
            if self.cache is not None and self.cache.prompt_tokens_seen:
                cached_frac = (self.cache.hit_tokens
                               / self.cache.prompt_tokens_seen)
            plan = replan_split(
                specs, self.total_bytes, page_bytes=self.virt.page_bytes,
                slab_bytes=self.arena.slab_bytes if self.arena else 0,
                quantile=cfg.quantile, window_s=cfg.window_s,
                seed=self.seed, cached_token_fraction=cached_frac)
        except (ValueError, ZeroDivisionError):
            self.skipped_no_signal += 1
            return None
        clamped = self._clamp(plan.page_budget, protected)
        if clamped is None:
            self.skipped_no_signal += 1
            return None
        new_pages, new_slots = clamped
        cur_pages = self.virt.page_budget
        cur_slots = self.arena.slot_budget
        rel = max(abs(new_pages - cur_pages) / max(cur_pages, 1),
                  abs(new_slots - cur_slots) / max(cur_slots, 1))
        if rel < cfg.hysteresis or (new_pages == cur_pages
                                    and new_slots == cur_slots):
            self.skipped_hysteresis += 1
            return None
        return self._apply(now, new_pages, new_slots, protected)

    def _apply(self, now: float, new_pages: int, new_slots: int,
               protected) -> Optional[RebalanceDecision]:
        """Shrink-before-grow application of one boundary move."""
        cur_pages = self.virt.page_budget
        cur_slots = self.arena.slot_budget
        swapped = evicted = moved_p = moved_s = 0
        try:
            # shrinks FIRST: the bytes must be free before either grow
            if new_pages < cur_pages:
                r = self.virt.resize(new_pages, protected=protected)
                swapped, moved_p = r["swapped_out"], r["moved"]
            if new_slots < cur_slots:
                r = self.arena.resize(new_slots)
                evicted, moved_s = r["evicted"], r["moved"]
            if new_pages > cur_pages:
                self.virt.resize(new_pages, protected=protected)
            if new_slots > cur_slots:
                self.arena.resize(new_slots)
        except (OutOfPagesError, OutOfSlabsError):
            # floors were computed optimistically and the pool disagreed
            # (e.g. protected pages grew between floor calc and apply);
            # state is still consistent — record and stand down
            self.aborted += 1
            return None
        finally:
            # a shrink may just have populated the swap tier: refresh the
            # admission reserve NOW, not at the next step's evaluation, so
            # the very next front-door drain already protects the
            # displaced requests' fault-in headroom
            if self.admission is not None:
                self.admission.reserve_pages = (
                    self.virt.swapped_now + max(self.cfg.headroom_pages, 0))
        self._last_applied = self._step
        decision = RebalanceDecision(
            step=self._step, now=now,
            old_page_budget=cur_pages, new_page_budget=new_pages,
            old_slot_budget=cur_slots, new_slot_budget=new_slots,
            swapped_out=swapped, evicted_models=evicted,
            moved_pages=moved_p, moved_slabs=moved_s,
            reason="kv_demand" if new_pages > cur_pages
            else "weight_demand")
        self.events.append(decision)
        if self.hooks is not None:
            self.hooks.rebalance(decision)
        return decision

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        return {
            "total_bytes": float(self.total_bytes),
            "rebalances": float(len(self.events)),
            "evaluations": float(self.evaluations),
            "skipped_hysteresis": float(self.skipped_hysteresis),
            "skipped_cooldown": float(self.skipped_cooldown),
            "skipped_no_signal": float(self.skipped_no_signal),
            "aborted": float(self.aborted),
            "page_budget": float(self.virt.page_budget),
            "slot_budget": float(self.arena.slot_budget
                                 if self.arena is not None else 0),
        }
