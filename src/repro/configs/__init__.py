"""Architecture registry: 10 assigned archs + the paper's own colocation set.

``get_config(name)`` returns the full literature config;
``get_smoke_config(name)`` returns a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import (  # noqa: F401  (re-export)
    DEFAULT_DECODE_STEPS_PER_DISPATCH,
    CacheConfig,
    ElasticConfig,
    EngineConfig,
    FlightRecorderConfig,
    MLAConfig,
    ModelConfig,
    SHAPES,
    SHAPES_BY_NAME,
    ShapeConfig,
    SLObjective,
    SLOConfig,
    SSMConfig,
    shape_applicable,
)

# arch id -> module name
_ARCH_MODULES: Dict[str, str] = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "qwen3-14b": "qwen3_14b",
    "gemma3-12b": "gemma3_12b",
    "llama3-405b": "llama3_405b",
    "minicpm3-4b": "minicpm3_4b",
    "zamba2-1.2b": "zamba2_1_2b",
    "mamba2-130m": "mamba2_130m",
    "llava-next-34b": "llava_next_34b",
    "whisper-small": "whisper_small",
}

ARCH_NAMES: Tuple[str, ...] = tuple(_ARCH_MODULES)

# The paper's own evaluated colocation set (§5.1): three cold MoE models.
# We map them onto reduced versions of our MoE/MLA families for the
# engine-level experiments (Fig. 6 / Fig. 7 / Table 3 reproduce at CPU scale).
PAPER_COLOC_SET: Tuple[str, ...] = (
    "qwen3-moe-235b-a22b",   # stands in for Qwen3-30B-A3B (same family)
    "moonshot-v1-16b-a3b",   # stands in for GLM-4.7-Flash (MoE)
    "minicpm3-4b",           # stands in for DeepSeek-V2-Lite (MLA)
)


def _module(name: str):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}
