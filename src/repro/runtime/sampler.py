"""Token sampling: greedy / temperature / top-k, jit-friendly."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, key: Optional[jax.Array] = None, *,
           temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits [B,V] -> token ids [B]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jnp.sort(scaled, axis=-1)[:, -top_k][:, None]
        scaled = jnp.where(scaled >= kth, scaled, -jnp.inf)
    assert key is not None, "temperature sampling needs a PRNG key"
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
