"""Public jit'd wrappers for the kernel layer.

Implementation selection:
  * ``xla``     — pure-jnp reference (ref.py).  Default; used by the
                  distributed dry-run so cost_analysis sees real FLOPs.
  * ``pallas``  — pl.pallas_call TPU kernels, run in interpret mode on CPU.

Select globally via :func:`set_default_impl` or per-call via ``impl=``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref

_DEFAULT_IMPL = "xla"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas")
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


def _resolve(impl: Optional[str]) -> str:
    return impl or _DEFAULT_IMPL


# --- flash attention -------------------------------------------------------

def flash_attention(q, k, v, *, scale: float, impl: Optional[str] = None):
    if _resolve(impl) == "pallas":
        from repro.kernels import flash_attention as fk
        return fk.flash_attention(q, k, v, scale=scale)
    return ref.flash_attention(q, k, v, scale)


# --- decode attention ------------------------------------------------------

def decode_attention(q, cache_k, cache_v, lengths, *, scale: float,
                     impl: Optional[str] = None):
    if _resolve(impl) == "pallas":
        from repro.kernels import paged_attention as pk
        return pk.contiguous_decode_attention(q, cache_k, cache_v, lengths,
                                              scale=scale)
    return ref.decode_attention(q, cache_k, cache_v, lengths, scale)


def paged_decode_attention(q, kv_pages, page_table, lengths, *, scale: float,
                           impl: Optional[str] = None):
    if _resolve(impl) == "pallas":
        from repro.kernels import paged_attention as pk
        return pk.paged_decode_attention(q, kv_pages, page_table, lengths,
                                         scale=scale)
    return ref.paged_decode_attention(q, kv_pages, page_table, lengths, scale)


def paged_mla_decode_attention(q, kv_pages, page_table, lengths, *,
                               latent_dim: int, scale: float,
                               impl: Optional[str] = None):
    if _resolve(impl) == "pallas":
        from repro.kernels import paged_attention as pk
        return pk.paged_mla_decode_attention(
            q, kv_pages, page_table, lengths, latent_dim=latent_dim,
            scale=scale)
    return ref.paged_mla_decode_attention(q, kv_pages, page_table, lengths,
                                          latent_dim, scale)


# --- paged KV write (pool scatter; pure-jnp, no Pallas variant) ------------

def paged_kv_write(pool, kv_flat, pages, slots):
    """Scatter per-token KV rows into the flat page pool.

    pool:    [n_pages, page_elems]  the shared physical pool
    kv_flat: [n, per_token_elems]   one row per token (one layer's K+V,
                                    or MLA latent+rope)
    pages:   [n] int32 physical page ids (< 0 = drop the row)
    slots:   [n] int32 token slot within the page

    Returns the updated pool.  Rows whose page id is negative (unmapped /
    inactive batch slots) are dropped by the scatter, so callers can pass
    a full fixed-size batch without masking on the host.  One XLA scatter;
    jit- and donation-friendly (the pool aliases in place under jit).

    Indices are 2-D (page row, element column) rather than flattened, so
    they stay far inside int32 range even for pools past 2^31 elements.
    """
    n_pages, page_elems = pool.shape
    e = kv_flat.shape[-1]
    rows = pages.astype(jnp.int32)
    # out-of-range sentinel for unmapped rows -> dropped by mode="drop"
    rows = jnp.where(rows >= 0, rows, n_pages)
    cols = ((slots.astype(jnp.int32) * e)[:, None]
            + jnp.arange(e, dtype=jnp.int32)[None, :])
    return pool.at[rows[:, None], cols].set(
        kv_flat.astype(pool.dtype), mode="drop")


def donate_argnums(*argnums):
    """Donation argnums, disabled on CPU where XLA cannot alias buffers."""
    return () if jax.default_backend() == "cpu" else argnums


# --- grouped expert GEMM ---------------------------------------------------

def moe_gemm(x, w, group_sizes, *, impl: Optional[str] = None):
    if _resolve(impl) == "pallas":
        from repro.kernels import moe_gemm as mk
        return mk.moe_gemm(x, w, group_sizes)
    return ref.moe_gemm(x, w, group_sizes)


# --- Mamba2 SSD ------------------------------------------------------------

def ssd_scan(x, dt, A, B_, C_, *, chunk: int = 64, h0=None,
             impl: Optional[str] = None):
    if _resolve(impl) == "pallas":
        from repro.kernels import ssd_scan as sk
        return sk.ssd_scan(x, dt, A, B_, C_, chunk=chunk, h0=h0)
    from repro.kernels.ssd_chunked import ssd_scan_chunked
    return ssd_scan_chunked(x, dt, A, B_, C_, chunk=chunk, h0=h0)
