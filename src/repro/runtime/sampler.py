"""Token sampling: greedy / temperature / top-k, jit-friendly.

This module is the SINGLE sampling entry point for the whole runtime:

* ``sample`` — host-visible path: the engine's prefill first-token pick,
  the host-driven (lowering=OFF) decode commit and the pipeline
  scheduler's write-back all route through it, so there is exactly one
  greedy/temperature implementation to keep bit-exact.
* ``sample_on_device`` — the fused multi-step decode path: the same
  policy compiled INTO the device program (``control.MultiStepFusedStep``
  closes over it), with the inner-step index folded into the PRNG key so
  the K tokens of one dispatch draw independent samples while staying a
  pure function of ``(key, step)`` — logits never leave the device.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, key: Optional[jax.Array] = None, *,
           temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits [B,V] -> token ids [B]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jnp.sort(scaled, axis=-1)[:, -top_k][:, None]
        scaled = jnp.where(scaled >= kth, scaled, -jnp.inf)
    assert key is not None, "temperature sampling needs a PRNG key"
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def sample_on_device(logits: jax.Array, key: Optional[jax.Array], step,
                     *, temperature: float = 0.0, top_k: int = 0
                     ) -> jax.Array:
    """Jittable in-program sampling for the multi-step fused decode.

    ``step`` is the inner scan index (a traced int32 scalar is fine):
    it is folded into ``key`` so each of the K inner steps of one
    dispatch draws an independent sample, deterministically — replaying
    a dispatch with the same key reproduces the same K tokens.  Greedy
    (``temperature<=0``) never touches the key, so the fused program
    can pass a dummy key without tracing any PRNG ops.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert key is not None, "temperature sampling needs a PRNG key"
    return sample(logits, jax.random.fold_in(key, step),
                  temperature=temperature, top_k=top_k)
