"""Request lifecycle objects shared by the engine and the simulator."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional


class Phase(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    FINISHED = "finished"
    REJECTED = "rejected"
    CANCELLED = "cancelled"


@dataclass
class Request:
    request_id: int
    model: str
    prompt_tokens: int
    max_new_tokens: int
    arrival_time: float
    prompt_ids: Optional[object] = None      # jax/np array when real tokens
    eos_id: Optional[int] = None             # None disables EOS stopping
    # prefix-cache opt-out (DESIGN.md §11): True lets the engine reuse /
    # index this prompt's KV.  Only requests with real ``prompt_ids`` ever
    # participate — synthetic prompts are silently cache-cold.
    cache: bool = True
    phase: Phase = Phase.QUEUED
    # --- progress -------------------------------------------------------
    generated: int = 0
    output_ids: List[int] = field(default_factory=list)
    eos_seen: bool = False
    # --- latency bookkeeping ---------------------------------------------
    admit_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0
    token_times: List[float] = field(default_factory=list)

    @property
    def context_length(self) -> int:
        return self.prompt_tokens + self.generated

    def tbt_samples(self) -> List[float]:
        """Time-between-tokens gaps (the paper's decode latency metric)."""
        return [b - a for a, b in zip(self.token_times, self.token_times[1:])]

    @property
    def done(self) -> bool:
        return self.eos_seen or self.generated >= self.max_new_tokens
