"""llava-next-34b — VLM backbone [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Assigned config: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
The transformer BACKBONE only: the anyres vision tiling frontend is a STUB —
``input_specs()`` provides precomputed patch embeddings (anyres tiling of a
672x672 image at 14px patches ≈ 2880 image tokens) that are prepended to the
text sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    vocab_size=64_000,
    attention="gqa",
    frontend="vision_patches",
    frontend_tokens=2880,
    rope_theta=5_000_000.0,
    max_position=131_072,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (backbone scaled per assignment); unverified",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8, d_ff=128,
    vocab_size=256, frontend_tokens=16, max_position=512,
)
