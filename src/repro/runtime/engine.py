"""CrossPool serving engine: an online, continuously-batched session API.

End-to-end path (paper §3/§4), now event-driven (DESIGN.md §7):

  submit(request) -> AdmissionController verdict (planner budget,
           queue-or-reject) surfaced on the returned RequestHandle
  step(now)
        -> drain the front-door queue (requests whose resources freed)
        -> PrefillBatcher: coalesce admitted same-model arrivals into ONE
           [B, S] StreamingPrefill pass per (model, prompt-bucket) group;
           prompt KV is scattered into the SHARED paged pool pages mapped
           at admission
        -> decode: one dispatch per active model over the pool
             lowering=fused : one compiled paged step per model committing
                              K tokens with on-device sampling
                              ("persistent kernel" analogue,
                              ``MultiStepFusedStep``; DESIGN.md §9)
             lowering=host  : per-layer attention/FFN dispatches across
                              the disaggregated pools
             pipeline=True  : the active models' batches kept in flight so
                              attention and FFN overlap (paper Fig. 4)
        -> completions: release slot + pages + weight pin, so the NEXT
           step's drain can admit what was queued behind them
        -> list[TokenEvent] (per-token streaming callbacks fire inline)
  cancel(handle) -> atomically frees KV pages and drops the weight pin
  drain() -> step until quiescent

Requests join and leave decode batches BETWEEN steps — there is no
global barrier and no offline trace: ``run(requests)`` survives only as
a thin compatibility wrapper that submits arrivals when due and calls
``step``.

The virtualizer's device page pool is the SINGLE source of KV truth for
every dense/moe/vlm model: total device KV bytes are fixed by
``page_budget`` alone, independent of how many models are colocated.
Families outside split execution (SSM/hybrid/enc-dec/SWA) fall back to a
fused dense-cache path; their pool pages are accounting-only.

With ``elastic=ElasticConfig(...)`` the KV/weights split is no longer
frozen: per-step telemetry feeds a windowed Eq. (1)-(2) re-plan and the
two pools are live-repartitioned at step boundaries — the KV pool
shrinks through a host swap tier (in-flight requests' cold pages fault
back on next touch), the arena shrinks by LRU-evicting idle models, and
total device bytes are conserved (DESIGN.md §8).

The weights side is symmetric (PR 2/3): FFN/MoE weights live in ONE
shared slab arena whose device bytes are fixed by ``slot_budget`` alone;
prefill streams each layer's slabs in behind the previous layer's
attention, so a cold model's first token overlaps its own upload in BOTH
lowering modes, and ``ModelRunner`` holds NO full param tree.  Admission
is arena-aware: a cold-model request whose slabs are not reachable
without revoking another admitted model's weights queues at the front
door instead of thrashing the LRU.

Engine-scale model set = the paper's colocation trio at smoke scale; the
production-mesh behaviour of the same code paths is proven by the dry-run.
"""
from __future__ import annotations

import collections
import math
import os
import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (DEFAULT_DECODE_STEPS_PER_DISPATCH,
                                CacheConfig, ElasticConfig, EngineConfig,
                                ModelConfig)
from repro.core.admission import (AdmissionController, AdmissionStats,
                                  PendingRequest)
from repro.core.control import (HostDrivenStep, MultiStepFusedStep,
                                StreamingPrefill)
from repro.analysis.sanitizer import PoolSanitizer, PoolSanitizerError
from repro.core.elastic import ElasticRebalancer
from repro.core.errors import PoolAccountingError
from repro.core.hooks import CompositeHooks
from repro.core.pipeline import InflightBatch, LayerPipelineScheduler
from repro.core import split_exec
from repro.core.pools import build_pools
from repro.core.prefix_cache import PrefixCache
from repro.core.virtualizer import (DEFAULT_PAGE_BYTES, KVVirtualizer,
                                    OutOfPagesError)
from repro.core.weight_pool import DEFAULT_SLAB_BYTES, OutOfSlabsError
from repro.models import build_model
from repro.models.moe import expert_capacity
from repro.runtime.flightrec import (FlightRecorder, ReplayDivergence,
                                     engine_header, pool_snapshot)
from repro.runtime.observe import EngineObserver, MetricsRegistry, SLOMonitor
from repro.runtime.request import Phase, Request
from repro.runtime.sampler import sample
from repro.runtime.session import (HandleState, PrefillBatcher, PrefillGroup,
                                   RebalanceEvent, RequestHandle, TokenEvent,
                                   prompt_bucket)
from repro.runtime.telemetry import DemandTelemetry


@dataclass
class EngineMode:
    pipeline: bool = True
    lowering: bool = True          # fused step vs host-driven per-layer
    # decode tokens committed per host dispatch (persistent multi-step
    # decode, DESIGN.md §9).  Only the fused lowering can run K>1 — one
    # ``MultiStepFusedStep`` dispatch samples on device and returns
    # [K, B] token ids; host-driven mode and fallback families silently
    # clamp to 1 so the ablation baseline keeps its per-token dispatch
    # train and both lowering modes still gate parity.
    decode_steps_per_dispatch: int = DEFAULT_DECODE_STEPS_PER_DISPATCH


@dataclass
class EngineStats:
    tokens_out: int = 0
    wall_s: float = 0.0
    tbt: List[float] = field(default_factory=list)
    ttft: List[float] = field(default_factory=list)
    step_times: Dict[str, List[float]] = field(default_factory=dict)
    slow_steps: int = 0            # straggler-mitigation counter
    cancelled: int = 0             # requests cancelled through the session
    # batch size of every executed prefill pass (B > 1 = coalesced)
    prefill_batch_sizes: List[int] = field(default_factory=list)
    # live view of the admission controller's counters (global + per model)
    admission: Optional[AdmissionStats] = None
    # weights-arena counters (activations/evictions/uploads)
    weights_pool: Dict[str, float] = field(default_factory=dict)
    # applied elastic boundary moves (empty when elastic is off)
    rebalance_events: List[RebalanceEvent] = field(default_factory=list)
    # telemetry + rebalancer snapshot folded in by finalize()
    elastic: Dict[str, float] = field(default_factory=dict)

    @property
    def throughput(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s else 0.0


class ModelRunner:
    """Per-model batch slots + compiled prefill/decode programs.

    ``paged=True`` (dense/moe/vlm): NO per-model KV allocation AND no
    per-model param tree — prefill streams prompt KV into the
    virtualizer's pool pages layer by layer while FFN weights are gathered
    from the shared arena (``prefill_step``); decode steps read and write
    through page tables.  ``params`` must be ``None``: the only full
    copies are the pooled kv_params (non-FFN) and the arena's packed host
    masters.  ``paged=False`` (fused fallback families): a contiguous
    per-model cache and a device-resident ``params`` tree as before.

    Prefill consumes :class:`~repro.runtime.session.PrefillGroup`s — one
    ``[B, S]`` pass per same-model same-bucket group, committing each row
    into its own batch slot.
    """

    def __init__(self, name: str, cfg: ModelConfig, params,
                 virt: KVVirtualizer, *, max_batch: int, max_ctx: int,
                 mode: EngineMode, pooled=None,
                 prefill_step: Optional[StreamingPrefill] = None):
        self.name = name
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_ctx = max_ctx
        self.mode = mode
        self.virt = virt
        self.pooled = pooled
        self.paged = pooled is not None and pooled.stage_fns is not None
        self.lengths = np.zeros(max_batch, np.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.next_tokens = np.zeros(max_batch, np.int32)
        # prefix cache wiring (DESIGN.md §11), set by the engine after
        # construction: the shared tree and the engine's live
        # request_id -> (fork, prefix_routes) admission outcomes.
        # NOT named ``cache``: that attribute is the dense-KV fallback
        # slot, and its absence is the paged path's acceptance gate
        self.prefix_cache: Optional[PrefixCache] = None
        self.prefix_info: Dict[int, Tuple[int, Optional[np.ndarray]]] = {}

        if self.paged:
            assert params is None, \
                f"{name}: paged models must not hold a full param tree"
            assert prefill_step is not None
            self.params = None
            self.prefill_step = prefill_step
            self.view = virt.views[name]
            self.max_pages = max(
                1, math.ceil(max_ctx / self.view.tokens_per_page))
            # K decode tokens per dispatch; host-driven lowering keeps the
            # per-token dispatch train, so K>1 is fused-only
            self.decode_steps = (max(1, int(mode.decode_steps_per_dispatch))
                                 if mode.lowering else 1)
            self.fused: Optional[MultiStepFusedStep] = (
                MultiStepFusedStep(pooled, k=self.decode_steps)
                if mode.lowering else None)
        else:
            self.params = params
            self.decode_steps = 1          # dense-cache fallback stays K=1
            mdl = build_model(cfg)
            self.cache = mdl.init_cache(max_batch, max_ctx)

            def _prefill_dense(params, tokens, cache, slot, true_len):
                one = jax.tree.map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1),
                    cache)
                logits, one = mdl.prefill(params, tokens, one,
                                          logit_index=true_len - 1)
                cache = jax.tree.map(
                    lambda c, o: jax.lax.dynamic_update_slice_in_dim(
                        c, o.astype(c.dtype), slot, axis=1),
                    cache, one)
                return logits, cache

            self._prefill = jax.jit(_prefill_dense)

            def _decode(params, tokens, cache, lengths):
                logits, cache = mdl.decode_step(params, tokens, cache, lengths)
                return sample(logits), cache

            self._decode = jax.jit(_decode, donate_argnums=(2,))

    # ------------------------------------------------------------------
    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    @property
    def active(self) -> bool:
        return any(s is not None for s in self.slots)

    def _active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    # ------------------------------------------------------------------
    # prefill: one [B, S] pass per coalesced group
    # ------------------------------------------------------------------
    def _group_writer(self, group: PrefillGroup):
        """Per-layer pool writer scattering EVERY row's prompt KV to its
        own request's pages (the writer threads the donated pool buffer
        through B scatters per layer).  A suffix group's rows land at
        absolute positions starting at the fork (``group.fork`` is 0 for
        full-prompt groups)."""

        def writer(layer, layer_kv, pool):
            for i, (req, n_w) in enumerate(zip(group.requests,
                                               group.n_writes)):
                pool = self.virt.write_prompt_layer(
                    pool, self.name, req.request_id, layer, layer_kv, n_w,
                    batch_index=i, start=group.fork)
            return pool

        return writer

    def _commit_prefill(self, req: Request, tok: int) -> int:
        slot = self.free_slot()
        assert slot is not None
        self.slots[slot] = req
        self.lengths[slot] = req.prompt_tokens
        self.next_tokens[slot] = tok
        req.phase = Phase.DECODE
        req.output_ids.append(tok)       # the prefill-sampled first token
        return slot

    def _commit_group(self, group: PrefillGroup, logits: jax.Array
                      ) -> List[int]:
        toks = np.asarray(sample(logits))
        return [self._commit_prefill(req, int(toks[i]))
                for i, req in enumerate(group.requests)]

    def cache_insert_candidate(self, group: PrefillGroup) -> bool:
        """Whether this group's committed prompt should be indexed in the
        prefix tree.  Insertion is restricted to B=1 streaming groups with
        REAL untruncated prompt ids: coalesced rows run under a vmapped
        MoE whose captured routing is not guaranteed bit-identical to the
        B=1 replay, and synthetic prompts are silently cache-cold."""
        cache = self.prefix_cache
        if cache is None or self.name not in cache.models:
            return False
        if group.batch_size != 1:
            return False
        req = group.requests[0]
        return (req.cache and req.prompt_ids is not None
                and 0 < req.prompt_tokens <= group.bucket)

    def _prefill_suffix(self, group: PrefillGroup, capture: bool):
        """Run the uncached-suffix pass of a prefix-cache hit: the cached
        KV rows are gathered through the request's (shared) page table and
        the suffix executes at absolute positions ``[fork, prompt)`` with
        the producing pass's KV extent and (MoE) expert-capacity slots, so
        every written row is bit-exact with a cold full pass."""
        req = group.requests[0]
        fork = group.fork
        prefix_rows = self.virt.gather_prompt_rows(
            self.name, req.request_id, fork)
        slot_offsets, capacity = None, 0
        if self.cfg.is_moe:
            routes = self.prefix_info.get(req.request_id, (0, None))[1]
            assert routes is not None and len(routes) >= fork, \
                "MoE suffix prefill needs the prefix's captured routing"
            E = self.cfg.n_experts
            # per-layer routed-pair counts of the prefix tokens: the
            # suffix tokens' dispatch slots start BEHIND them, exactly
            # where the producing full pass's cumsum placed them
            slot_offsets = np.stack([
                np.bincount(np.asarray(routes[:fork, l, :],
                                       np.int64).ravel(),
                            minlength=E).astype(np.int32)
                for l in range(self.cfg.n_layers)])
            capacity = expert_capacity(group.bucket, self.cfg)
        return self.prefill_step.suffix(
            jnp.asarray(group.tokens()), group.true_lens(), fork,
            group.bucket, prefix_rows, self.virt.pool,
            self._group_writer(group), slot_offsets, capacity,
            capture_routes=capture)

    def _cache_insert(self, group: PrefillGroup) -> None:
        """Index a just-committed prompt in the prefix tree: the request's
        page-table entries become shared chunk pages (refcount +1 each via
        ``insert``), with the captured MoE routing attached so later
        suffix passes can replay dispatch exactly."""
        req = group.requests[0]
        routes = None
        if self.cfg.is_moe:
            cap = self.prefill_step.captured_routes
            if cap is None:
                return
            if group.fork > 0:
                pre = self.prefix_info.get(req.request_id, (0, None))[1]
                if pre is None:
                    return
                routes = np.concatenate(
                    [np.asarray(pre[:group.fork]),
                     cap[:req.prompt_tokens - group.fork]], axis=0)
            else:
                routes = cap[:req.prompt_tokens]
        ids = np.asarray(req.prompt_ids,
                         np.int32).reshape(-1)[:req.prompt_tokens]
        rp = self.virt.requests[req.request_id]
        L = self.view.n_kv_layers
        n_chunks = math.ceil(req.prompt_tokens / self.view.tokens_per_page)
        chunk_pages = [[rp.tables[layer][c] for layer in range(L)]
                       for c in range(n_chunks)]
        self.prefix_cache.insert(self.name, group.bucket, ids, chunk_pages,
                                 routes)

    def prefill_group(self, group: PrefillGroup) -> List[int]:
        """Execute one coalesced prompt pass and commit each row to a
        batch slot; returns the slots in row order."""
        # check BEFORE any device work: a full batch must fail here, not
        # after the prompt KV has already been scattered into the pool
        free = sum(1 for s in self.slots if s is None)
        assert group.batch_size <= free, (group.batch_size, free)
        if self.paged:
            for req in group.requests:
                # admission-mapped pages may have been swapped while the
                # request waited for a slot; prompt-KV scatters need them
                # device-resident (their contents are still unwritten)
                self.virt.ensure_resident(req.request_id)
            insert = self.cache_insert_candidate(group)
            if group.fork > 0:
                # prefix-cache hit: prefill ONLY the uncached suffix
                logits, self.virt.pool = self._prefill_suffix(group, insert)
            else:
                # streaming prompt phase: per-layer attention with the next
                # layer's arena slabs uploading behind it; every row's
                # prompt KV is scattered into pool pages as each layer
                # completes
                logits, self.virt.pool = self.prefill_step(
                    jnp.asarray(group.tokens()), group.true_lens(),
                    self.virt.pool, self._group_writer(group),
                    capture_routes=insert)
            if insert:
                self._cache_insert(group)
            return self._commit_group(group, logits)
        # fallback families: per-slot dense prefill, one row at a time
        slots = []
        for ids, req in zip(group.ids, group.requests):
            slot = self.free_slot()
            assert slot is not None
            logits, self.cache = self._prefill(
                self.params, jnp.asarray(ids[None, :]), self.cache,
                jnp.int32(slot), jnp.int32(req.prompt_tokens))
            slots.append(self._commit_prefill(
                req, int(sample(logits)[0])))
        return slots

    def make_prefill_batch(self, group: PrefillGroup,
                           batch_id: int) -> InflightBatch:
        """Package one group's prompt phase for the layer-wise scheduler
        (interleaves with other models' prefill/decode stages)."""
        if self.paged:
            for req in group.requests:
                self.virt.ensure_resident(req.request_id)
        return InflightBatch(
            batch_id=batch_id, model=self.name,
            tokens=jnp.asarray(group.tokens()), prefill=True,
            true_len=group.true_lens(), kv_writer=self._group_writer(group))

    def apply_prefill_result(self, batch: InflightBatch,
                             group: PrefillGroup) -> List[int]:
        return self._commit_group(group, batch.logits)

    # ------------------------------------------------------------------
    # decode: issue (non-blocking dispatch) / commit (block + bookkeeping)
    # ------------------------------------------------------------------
    def _reserve_decode_block(self) -> Tuple[List[int], np.ndarray]:
        """Pre-map every active request's pages for this dispatch's token
        block (paged models map BEFORE the step; DESIGN.md §9).

        Per active row the block is ``min(decode_steps, remaining declared
        output, context headroom)`` tokens — never more than admission
        reserved, so the PR-5 ``reserve_pages`` pressure accounting still
        bounds decode-time needs.  Ordering: swapped pages fault back in
        (``ensure_resident``) FIRST, then the block is reserved, then the
        batch tables are built — the device program indexes into the
        pre-extended table, so no host table mutation happens
        mid-dispatch.  ``req.tokens`` is NOT advanced here: the commit
        after the dispatch advances it by the tokens actually emitted and
        returns unused reserved pages.

        Atomic across the batch: the total page need is checked up front,
        so a pool exhausted mid-serve raises with NO per-request token
        drift (active pages are never revoked — paper §3.1; with the
        admission controller's output reservation this is unreachable
        unless the budget is under-planned).
        """
        act = self._active_slots()
        steps = np.zeros(self.max_batch, np.int32)
        for i in act:
            # the swap tier's "next touch": pages a shrink pushed to the
            # host fault back in before this step's tables are built
            self.virt.ensure_resident(self.slots[i].request_id)
        for i in act:
            req = self.slots[i]
            steps[i] = max(1, min(self.decode_steps,
                                  req.max_new_tokens - req.generated,
                                  self.max_ctx - int(self.lengths[i])))
        need = sum(self.virt.pages_needed_for_extend(
            self.slots[i].request_id, int(steps[i])) for i in act)
        if need > self.virt.free_pages:
            raise OutOfPagesError(
                f"{self.name}: decode block needs {need} pages, "
                f"{self.virt.free_pages} free — raise page_budget or plan "
                f"with a higher quantile")
        for i in act:
            self.virt.reserve_decode_block(self.slots[i].request_id,
                                           int(steps[i]))
        return act, steps

    def prepare_step(self) -> Tuple[jax.Array, jax.Array, jax.Array,
                                    List[int], np.ndarray]:
        """(tokens, page_tables [L,B,P], lengths, active slots,
        per-slot step budget [max_batch])."""
        act, steps = self._reserve_decode_block()
        rids = [s.request_id if s is not None else None for s in self.slots]
        tables = self.virt.batch_tables(self.name, rids, self.max_pages)
        return (jnp.asarray(self.next_tokens), tables,
                jnp.asarray(self.lengths), act, steps)

    def _eos_ids(self) -> np.ndarray:
        eos = np.full(self.max_batch, -1, np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.eos_id is not None:
                eos[i] = req.eos_id
        return eos

    def issue_decode(self, host_step: Optional[HostDrivenStep] = None
                     ) -> Tuple[jax.Array, List[int], np.ndarray]:
        """Dispatch one decode block for all slots; returns
        (token ids [K, B] — still lazy, not blocked on — active slots,
        per-slot step budgets)."""
        if self.paged:
            tokens, tables, lengths, act, steps = self.prepare_step()
            if host_step is not None:
                # ablation baseline: per-layer host dispatches, K=1, with
                # logits returned to the host and sampled there
                logits, pool = host_step(tokens, self.virt.pool, tables,
                                         lengths)
                toks = sample(logits)[None, :]
            else:
                toks, pool = self.fused(
                    tokens, self.virt.pool, tables, lengths,
                    jnp.asarray(steps), jnp.asarray(self._eos_ids()))
            self.virt.pool = pool
            return toks, act, steps
        act = self._active_slots()
        toks, self.cache = self._decode(
            self.params, jnp.asarray(self.next_tokens), self.cache,
            jnp.asarray(self.lengths))
        steps = np.zeros(self.max_batch, np.int32)
        steps[act] = 1
        return toks[None, :], act, steps

    def commit_decode(self, pending: Tuple[jax.Array, List[int], np.ndarray]
                      ) -> Tuple[np.ndarray, np.ndarray, List[int]]:
        """Block on a dispatched block and commit it: token/length state,
        page-table commit (unused reserved pages return to the pool).
        Returns (tokens [B, K], per-slot valid counts, active slots) —
        valid tokens are a strict prefix of each row; -1 marks the tail
        of a row frozen early (EOS / per-row budget)."""
        toks_dev, act, steps = pending
        toks = np.asarray(jax.block_until_ready(toks_dev)).T   # [B, K]
        counts = np.zeros(self.max_batch, np.int64)
        for i in act:
            row = toks[i]
            n = int((row >= 0).sum())
            counts[i] = n
            if n:
                self.lengths[i] += n
                self.next_tokens[i] = row[n - 1]
            rid = self.slots[i].request_id
            if self.paged:
                self.virt.commit_decode_block(rid, n)
            else:
                # fallback families: page accounting AFTER the step (their
                # KV lives in the dense cache; pages track budget only)
                self.virt.extend_request(rid, n)
        return toks, counts, act

    def decode_once(self, host_step: Optional[HostDrivenStep] = None
                    ) -> Tuple[np.ndarray, np.ndarray, List[int]]:
        """One decode dispatch for all active slots; returns
        (tokens [B, K], valid counts, slots)."""
        return self.commit_decode(self.issue_decode(host_step))

    # ------------------------------------------------------------------
    def make_inflight_batch(self, batch_id: int) -> Tuple[InflightBatch, List[int]]:
        """Package this model's slots for the layer-wise scheduler."""
        tokens, tables, lengths, act, _ = self.prepare_step()
        return InflightBatch(
            batch_id=batch_id, model=self.name, tokens=tokens,
            page_tables=tables, lengths=lengths), act

    def apply_pipeline_result(self, batch: InflightBatch, act: List[int]
                              ) -> Tuple[np.ndarray, np.ndarray, List[int]]:
        """Write back an InflightBatch completed by the scheduler (KV is
        already in the pool; only token/length state lives here).  The
        layer-wise scheduler is host-driven and therefore always K=1."""
        toks = np.asarray(sample(batch.logits))
        counts = np.zeros(self.max_batch, np.int64)
        for i in act:
            self.lengths[i] += 1
            self.next_tokens[i] = toks[i]
            counts[i] = 1
            self.virt.commit_decode_block(self.slots[i].request_id, 1)
        return toks[:, None], counts, act

    def release(self, slot: int) -> Request:
        req = self.slots[slot]
        self.slots[slot] = None
        return req


class CrossPoolEngine:
    """The serving session: ``submit`` / ``step`` / ``cancel`` / ``drain``.

    One engine instance IS one continuously-batched serving session over
    the shared pools.  ``run(requests)`` remains as a thin offline
    wrapper that submits arrivals when due and steps to completion.
    """

    def __init__(self, models: Dict[str, ModelConfig], *,
                 page_budget: int, page_bytes: int = DEFAULT_PAGE_BYTES,
                 slot_budget: Optional[int] = None,
                 slab_bytes: int = DEFAULT_SLAB_BYTES,
                 max_batch: int = 4, max_ctx: int = 256,
                 config: Optional[EngineConfig] = None,
                 mode: Optional[EngineMode] = None, seed: int = 0,
                 slow_step_factor: float = 4.0,
                 elastic: Optional[ElasticConfig] = None,
                 observer: Optional[EngineObserver] = None):
        # ``config=EngineConfig(...)`` is the canonical construction
        # surface; the loose ``mode=`` / ``elastic=`` kwargs that accreted
        # across PRs remain as deprecated aliases for one release
        cache_cfg: Optional[CacheConfig] = None
        slo_cfg = None
        rec_cfg = None
        if config is not None:
            if mode is not None or elastic is not None:
                raise TypeError(
                    "pass mode/elastic inside config=EngineConfig(...); "
                    "the loose kwargs are aliases, not overrides")
            mode = config.mode
            elastic = config.elastic
            cache_cfg = config.cache
            slo_cfg = config.slo
            rec_cfg = config.flightrec
        elif mode is not None or elastic is not None:
            warnings.warn(
                "CrossPoolEngine(mode=..., elastic=...) is deprecated; "
                "pass config=EngineConfig(mode=..., elastic=..., "
                "cache=...) instead",
                DeprecationWarning, stacklevel=2)
        self.models = models
        self.mode = mode or EngineMode()
        self.max_ctx = max_ctx
        self.rng = np.random.default_rng(seed)
        devs = jax.devices()
        self.kv_device, self.w_device = devs[0], devs[-1]

        params = {n: build_model(c).init(jax.random.PRNGKey(i))
                  for i, (n, c) in enumerate(models.items())}
        # the pool dtype is the lowest common denominator of the colocated
        # models (heterogeneous models reinterpret the same untyped pages)
        pool_dtype = (jnp.float32
                      if any(c.dtype == "float32" for c in models.values())
                      else jnp.bfloat16)
        # a live device pool is only needed when some model decodes through
        # it; an all-fallback engine keeps host-side page accounting only
        any_split = any(split_exec.supports_split(c) for c in models.values())
        self.kv_pool, self.w_pool, self.pooled = build_pools(
            models, params, kv_device=self.kv_device, w_device=self.w_device,
            page_budget=page_budget, page_bytes=page_bytes,
            pool_dtype=pool_dtype, allocate_device_pool=any_split,
            slot_budget=slot_budget, slab_bytes=slab_bytes,
            # the fused step is ONE program with a single placement, so the
            # arena must be colocated with the KV pool when lowering is on;
            # host-driven mode keeps it in the weights pool, where FFN runs
            arena_device=(self.kv_device if self.mode.lowering
                          else self.w_device),
            # engine-managed activation: models become resident when their
            # first request reaches a batch slot (cold-model activation)
            activate_resident=False)
        self.virt = self.kv_pool.virtualizer
        self.arena = self.w_pool.arena if any_split else None
        # arena-aware admission: cold-model bursts queue at the front door
        # instead of thrashing the arena LRU between admitted models
        self.admission = AdmissionController(self.virt, arena=self.arena)
        # radix-tree prefix cache over the shared pool (DESIGN.md §11) —
        # OFF by default; cacheable models are the split-execution subset
        # (fallback families' pool pages are accounting-only, there is no
        # KV to share).  The tree registers itself as the virtualizer's
        # cache_provider so elastic shrink/compaction see its pages.
        self.cache: Optional[PrefixCache] = None
        if cache_cfg is not None and cache_cfg.enabled and any_split:
            cacheable = [n for n in models
                         if self.pooled[n].stage_fns is not None]
            self.cache = PrefixCache(self.virt, cache_cfg,
                                     models=cacheable)
            self.admission.cache = self.cache
        # observability (DESIGN.md §10): the observer is OPTIONAL — every
        # step-loop site is guarded by ``observer is not None`` so the
        # disabled path allocates and calls nothing — but a lightweight
        # metrics registry is always on (it backs ``report()``'s
        # structured-event lines); with an observer the engine shares its
        # registry, so /metrics and report() read the same counters.
        self.observer = observer
        self.metrics = (observer.metrics if observer is not None
                        else MetricsRegistry())
        # pool shadow-sanitizer (DESIGN.md §12): pure checking, attached
        # only on request — ``EngineConfig(sanitize=True)`` or the
        # ``CROSSPOOL_SANITIZE=1`` env var (CI's sanitized tier-1 leg).
        # It rides the same hook stream as the observer (CompositeHooks
        # fans out, sanitizer last so the observer sees the event even
        # when the sanitizer raises) and audits at step boundaries.
        self.sanitizer: Optional[PoolSanitizer] = None
        want_sanitize = ((config is not None and config.sanitize)
                         or os.environ.get("CROSSPOOL_SANITIZE", "") == "1")
        if want_sanitize:
            self.sanitizer = PoolSanitizer(
                self.virt, arena=self.arena, admission=self.admission,
                cache=self.cache)
        # SLO engine (DESIGN.md §13): declarative burn-rate objectives,
        # evaluated once per step over engine-virtual-time samples.  It
        # shares the engine registry, so breach counters/events land next
        # to the latency histograms they judge.
        self.slo: Optional[SLOMonitor] = None
        if slo_cfg is not None and slo_cfg.objectives:
            self.slo = SLOMonitor(slo_cfg, registry=self.metrics)
        # flight recorder (DESIGN.md §13): the session black box.  Built
        # AFTER the pools (its dumps snapshot final accounting) and wired
        # into the hook stream between the observer and the sanitizer, so
        # a raising audit cannot hide the event that tripped it.
        self.recorder: Optional[FlightRecorder] = None
        if rec_cfg is not None and rec_cfg.enabled:
            self.recorder = FlightRecorder(
                rec_cfg,
                header=engine_header(
                    models=models, page_budget=page_budget,
                    page_bytes=page_bytes, slot_budget=slot_budget,
                    slab_bytes=slab_bytes, max_batch=max_batch,
                    max_ctx=max_ctx, seed=seed, mode=self.mode,
                    elastic=elastic, cache=cache_cfg,
                    sanitize=want_sanitize, slo=slo_cfg,
                    flightrec=rec_cfg),
                virt=self.virt, arena=self.arena, cache=self.cache)
        sinks = [s for s in (observer, self.recorder, self.sanitizer)
                 if s is not None]
        sink = (sinks[0] if len(sinks) == 1
                else CompositeHooks(*sinks) if sinks else None)
        # the fan-out target for engine-originated events too (SLO
        # breaches), so observer/recorder/sanitizer see one stream
        self._sink = sink
        if sink is not None:
            self.virt.hooks = sink
            if self.arena is not None:
                self.arena.hooks = sink
            self.admission.hooks = sink
            if self.cache is not None:
                self.cache.hooks = sink
        # elastic boundary (DESIGN.md §8): windowed demand telemetry +
        # step-boundary KV<->weights repartitioning.  Telemetry observes
        # even with rebalancing disabled IF a config is passed; both stay
        # None on the default (frozen-split) path.
        self.telemetry: Optional[DemandTelemetry] = None
        self.rebalancer: Optional[ElasticRebalancer] = None
        if elastic is not None and self.arena is not None:
            self.telemetry = DemandTelemetry(models, elastic,
                                             gauges=observer)
            self.rebalancer = ElasticRebalancer(
                self.virt, self.arena, admission=self.admission,
                telemetry=self.telemetry, cfg=elastic, seed=seed)
            # cache-aware re-plan: the tree's hit-token fraction
            # discounts windowed KV demand (shared pages map free)
            self.rebalancer.cache = self.cache
            if sink is not None:
                self.rebalancer.hooks = sink

        self.host_steps = None
        self.scheduler = None
        if not self.mode.lowering:
            self.host_steps = {
                n: HostDrivenStep(self.pooled[n], self.kv_device,
                                  self.w_device)
                for n in models if self.pooled[n].stage_fns is not None
            }
            self.scheduler = LayerPipelineScheduler(
                self.pooled, self.kv_device, self.w_device,
                steps=self.host_steps)
        # streaming prompt-phase executors (per-layer transfers follow the
        # arena's placement: colocated with the KV pool under lowering=ON);
        # in host mode they SHARE the HostDrivenStep's jitted stage
        # programs — one trace/compile cache per model
        prefill_steps = {
            n: StreamingPrefill(
                self.pooled[n], kv_device=self.kv_device,
                w_device=self.w_pool.arena.device,
                share=None if self.host_steps is None
                else self.host_steps.get(n))
            for n in models if self.pooled[n].stage_fns is not None
        }
        # paged models hold NO full param tree: the init-time tree is split
        # into pooled kv_params + the arena's packed host masters, and the
        # full copy is dropped here (fallback families keep theirs)
        self.runners = {
            n: ModelRunner(
                n, c,
                None if n in prefill_steps else params[n], self.virt,
                max_batch=max_batch, max_ctx=max_ctx,
                mode=self.mode, pooled=self.pooled[n],
                prefill_step=prefill_steps.get(n))
            for n, c in models.items()
        }
        self.stats = EngineStats(step_times={n: [] for n in models},
                                 admission=self.admission.stats)
        # admission-time prefix-cache outcomes for live requests:
        # request_id -> (fork, captured prefix routes) — the batcher's
        # fork map and the suffix pass's dispatch replay read this
        self._prefix_info: Dict[int, Tuple[int, Optional[np.ndarray]]] = {}
        for r in self.runners.values():
            r.prefix_cache = self.cache
            r.prefix_info = self._prefix_info

        # --- session state -------------------------------------------------
        self.now = 0.0
        self.batcher = PrefillBatcher(observer=observer)
        self.handles: Dict[int, RequestHandle] = {}
        self.waiting: List[Request] = []     # admitted, no batch slot yet
        self._submitted: Dict[int, Request] = {}
        self._window: set = set()            # request ids in the stats window
        self._events: List[TokenEvent] = []
        self._in_step = False
        self._deferred_cancels: List[RequestHandle] = []
        self._step_index = 0               # monotone step counter
        # replay clock (flightrec): when attached, dispatch dt comes from
        # the recorded stream instead of time.perf_counter — the ONLY
        # nondeterministic input the engine folds into virtual time
        self._replay_dts: Optional[collections.deque] = None

    # ------------------------------------------------------------------
    # the session API
    # ------------------------------------------------------------------
    def advance(self, now: float) -> float:
        """Move the session clock forward (it never runs backwards).
        External drivers advance to an arrival's due time BEFORE
        submitting it, so admission/queue-wait bookkeeping is stamped
        with the arrival clock — exactly as the ``run()`` wrapper does."""
        self.now = max(self.now, float(now))
        if self.recorder is not None:
            self.recorder.record_op("advance", now=self.now)
        return self.now

    def submit(self, req: Request, on_token=None) -> RequestHandle:
        """Offer one request to the front door at the engine's current
        time; the admission verdict is on the returned handle."""
        assert req.request_id not in self._submitted, \
            f"request id {req.request_id} already submitted"
        if self.recorder is not None:
            # recorded BEFORE any mutation: the op is the causal input,
            # whatever verdict admission hands back
            self.recorder.record_submit(req, self.now)
        self._submitted[req.request_id] = req
        self._window.add(req.request_id)
        if self.telemetry is not None:
            self.telemetry.note_arrival(req.model, self.now)
        outcome = self._admit(req, self.now)
        if outcome == "admitted":
            req.admit_time = self.now
            self.waiting.append(req)
            state = HandleState.ADMITTED
        elif outcome == "queued":
            state = HandleState.QUEUED
        else:
            state = HandleState.REJECTED
        info = self._prefix_info.get(req.request_id)
        handle = RequestHandle(request=req, admission=outcome, state=state,
                               on_token=on_token,
                               cached_tokens=info[0] if info else 0,
                               cache_hit=bool(info and info[0] > 0),
                               _engine=self)
        self.handles[req.request_id] = handle
        if self.observer is not None:
            self.observer.request_submitted(req, outcome)
        if self.slo is not None and outcome == "admitted":
            # immediate admissions are zero-wait queue samples: without
            # them one slow drain would read as a 100% bad window
            self.slo.note("queue_wait", req.model, 0.0, self.now)
        if self.sanitizer is not None and not self._in_step:
            try:
                self.sanitizer.audit()  # admission mapping is quiescent too
            except (PoolSanitizerError, PoolAccountingError) as err:
                if self.recorder is not None:
                    self.recorder.note_failure(self._step_index, err)
                raise
        return handle

    def step(self, now: Optional[float] = None) -> List[TokenEvent]:
        """One engine step: drain -> batched prefill -> decode ->
        completions.  Returns the tokens generated this step (streaming
        callbacks fire inline as each batch commits)."""
        if now is not None:
            self.now = max(self.now, float(now))
        self._step_index += 1
        rec = self.recorder
        if rec is not None:
            rec.record_step(self._step_index, self.now)
        self._events = []
        self._in_step = True
        obs = self.observer
        if obs is not None:
            obs.step_begin(self.now)
        try:
            try:
                self._step_phases()
            finally:
                if obs is not None:
                    obs.step_end()
                self._in_step = False
                deferred, self._deferred_cancels = \
                    self._deferred_cancels, []
                for handle in deferred:     # reentrant cancels, now safe
                    self.cancel(handle, _deferred=True)
            if self.sanitizer is not None:
                # quiescent point: no cross-object handoff is mid-flight
                # here, so the full structural walk (SAN01..SAN08) is sound
                self.sanitizer.audit()
        except (PoolSanitizerError, PoolAccountingError) as err:
            # black-box the incident before surfacing it: the dumped
            # record replays to this same failing step (DESIGN.md §13)
            if rec is not None:
                rec.note_failure(self._step_index, err)
            raise
        if rec is not None:
            # breach auto-dumps land HERE, not at the breach itself: the
            # step has fully retired, so the record's final accounting is
            # a state replay can reproduce (DESIGN.md §13)
            rec.maybe_breach_dump()
        return self._events

    def _drain_front_door(self) -> None:
        obs = self.observer
        for p in self.admission.drain(self.now):
            req = self._submitted[p.request_id]
            req.admit_time = self.now
            if self.slo is not None:
                self.slo.note("queue_wait", p.model,
                              self.now - p.enqueue_time, self.now)
            handle = self.handles[req.request_id]
            handle.state = HandleState.ADMITTED
            if self.cache is not None:
                self._prefix_info[p.request_id] = (p.cached_tokens,
                                                   p.prefix_routes)
                handle.cached_tokens = p.cached_tokens
                handle.cache_hit = p.cached_tokens > 0
            self.waiting.append(req)
            if obs is not None:
                obs.request_admitted(req)

    def _step_phases(self) -> None:
        obs = self.observer
        # --- drain the front-door queue (resources freed last step) ------
        if obs is not None:
            obs.phase_begin("admission_drain")
        self._drain_front_door()
        if obs is not None:
            obs.phase_end("admission_drain")
            obs.phase_begin("batcher")

        # --- prefill: coalesce admitted arrivals into [B, S] groups ------
        forks = None
        if self.cache is not None:
            forks = {rid: info[0] for rid, info in self._prefix_info.items()
                     if info[0] > 0}
        groups, self.waiting = self.batcher.plan(
            self.waiting, self.runners, self.rng, self._try_activate, forks)
        if obs is not None:
            obs.phase_end("batcher")
        if groups:
            if obs is not None:
                obs.phase_begin("prefill")
            self.now = self._prefill_groups(groups, self.now)
            if obs is not None:
                obs.phase_end("prefill")

        # --- decode: one step per active model ---------------------------
        active = [n for n, r in self.runners.items() if r.active]
        if self.mode.pipeline and len(active) >= 2:
            self.now = self._decode_pipelined(active, self.now)
        else:
            for n in active:
                self.now = self._decode_model(n, self.now)

        # --- completions -------------------------------------------------
        if obs is not None:
            obs.phase_begin("completions")
        for n, runner in self.runners.items():
            for slot, req in enumerate(runner.slots):
                if req is not None and req.done:
                    runner.release(slot)
                    self._finish(req, self.now)
        if obs is not None:
            obs.phase_end("completions")
            obs.phase_begin("rebalance")

        # --- elastic boundary (step-boundary ONLY: no batch is in flight,
        #     so page tables and slot tables can remap atomically) --------
        self._observe_and_rebalance()
        if obs is not None:
            obs.phase_end("rebalance")

        # --- SLO burn-rate scan + pool timelines/snapshots ---------------
        # (after rebalance so breaches and snapshots see the step's final
        # pool shape; all guarded — observer=None + recorder-off pays two
        # ``is not None`` checks and allocates nothing)
        if self.slo is not None:
            for breach in self.slo.evaluate(self.now):
                if self._sink is not None:
                    self._sink.slo_breach(breach)
        rec = self.recorder
        snap_due = rec is not None and rec.snapshot_due(self._step_index)
        if obs is not None or snap_due:
            snap = pool_snapshot(self.virt, self.arena, self.cache)
            if obs is not None:
                obs.pool_counters(snap)
            if snap_due:
                rec.snapshot(self._step_index, self.now, snap)

    def _observe_and_rebalance(self) -> None:
        """Fold this step into the telemetry window and let the
        rebalancer repartition the device-byte boundary if the windowed
        Eq. (1)-(2) estimate says so (DESIGN.md §8)."""
        if self.observer is not None:
            # gauges refresh BEFORE telemetry folds its EWMAs, so the
            # gauge-fed fold sees THIS step's occupancy/queue values
            self.observer.sample(self.virt, self.arena, self.admission,
                                 len(self.waiting))
        if self.telemetry is None:
            return
        self.telemetry.observe(self.now, self.virt, self.arena,
                               self.admission)
        if self.rebalancer is None:
            return
        protected: Dict[int, int] = {}
        live: Optional[Dict[str, list]] = None
        if self.rebalancer.would_evaluate():
            # slotted requests with their REMAINING declared output: the
            # KV shrink floor reserves their whole lifetime, same as
            # admission did.  Assembled only on re-plan steps — the common
            # step pays one counter check, not an O(slots+queued) walk.
            protected = {
                req.request_id: max(req.max_new_tokens - req.generated, 1)
                for runner in self.runners.values()
                for req in runner.slots if req is not None}
            live = {}
            for req in self.waiting:
                live.setdefault(req.model, []).append(
                    (req.prompt_tokens, req.max_new_tokens))
            for runner in self.runners.values():
                for req in runner.slots:
                    if req is not None:
                        live.setdefault(req.model, []).append(
                            (req.prompt_tokens, req.max_new_tokens))
            # queued requests are the clearest demand signal of all —
            # they are EXACTLY what the old split could not admit
            for q in self.admission.queues.values():
                for p in q:
                    live.setdefault(p.model, []).append(
                        (p.prompt_tokens, p.expected_output))
        decision = self.rebalancer.step(self.now, protected=protected,
                                        live_requests=live)
        if decision is not None:
            # the budgets just changed: re-drain the front door NOW, so a
            # session where everything was queued behind the old split
            # makes progress this step (run()/drain() exit when a step
            # produces no events and nothing is admitted — without this,
            # a grow that frees room for queued-only load would be
            # followed by the loop breaking before its next drain)
            self._drain_front_door()
            # the registry's bounded event log is report()'s ONLY source
            # for move lines, so text report and exported metrics agree
            self.metrics.log_event(
                "rebalance", step=decision.step, time=decision.now,
                page_budget=(decision.old_page_budget,
                             decision.new_page_budget),
                slot_budget=(decision.old_slot_budget,
                             decision.new_slot_budget),
                swapped_out=decision.swapped_out,
                evicted_models=decision.evicted_models,
                reason=decision.reason)
            self.stats.rebalance_events.append(RebalanceEvent(
                step=decision.step, time=decision.now,
                page_budget=(decision.old_page_budget,
                             decision.new_page_budget),
                slot_budget=(decision.old_slot_budget,
                             decision.new_slot_budget),
                kv_delta_bytes=(decision.new_page_budget
                                - decision.old_page_budget)
                * self.virt.page_bytes,
                swapped_out=decision.swapped_out,
                evicted_models=decision.evicted_models,
                reason=decision.reason))

    def cancel(self, handle: Union[RequestHandle, int], *,
               _deferred: bool = False) -> bool:
        """Abort a submitted request, atomically returning its resources.

        Unpins weight slabs and frees KV pages in one host-side
        transaction (no device work, nothing can fail part-way):
        queued requests hold nothing and just leave the queue; admitted
        requests release their admission-time pages and drop the arena
        pin via ``AdmissionController.finish`` — the same teardown a
        natural completion uses — whether they are still waiting for a
        slot (mid-prefill) or already decoding.

        Reentrancy: a cancel issued from inside an ``on_token`` callback
        (the "stop at token X" pattern) lands while the step's commit
        loops are mid-flight, so it is DEFERRED to the step boundary —
        the request may emit the rest of this step's tokens first, and a
        request that completes within the same step stays FINISHED.
        """
        if isinstance(handle, int):
            handle = self.handles[handle]
        if self.recorder is not None and (_deferred or not self._in_step):
            # ringed at APPLICATION time, not request time: a mid-step
            # cancel is deferred to the step boundary, and recording it
            # there keeps the ring position one a replayed session (which
            # applies the op after the step retires) lands on exactly
            self.recorder.record_cancel(handle.request.request_id,
                                        self.now, in_step=self._in_step)
        if handle.state.terminal:
            return False
        if self._in_step:
            if handle not in self._deferred_cancels:
                self._deferred_cancels.append(handle)
            return True
        req = handle.request
        if handle.state is HandleState.QUEUED:
            self.admission.cancel_queued(req.request_id)
        else:
            if handle.state is HandleState.DECODING:
                runner = self.runners[req.model]
                for slot, r in enumerate(runner.slots):
                    if r is req:
                        runner.release(slot)
                        break
            else:                            # ADMITTED: waiting for a slot
                self.waiting = [r for r in self.waiting
                                if r.request_id != req.request_id]
            # pages + pin go back together: the KV release and the
            # admission-side unpin are both pure bookkeeping, so there is
            # no window in which a cancelled request still holds memory
            self.virt.release_request(req.request_id)
            self.admission.finish(req.model)
            self._prefix_info.pop(req.request_id, None)
        req.phase = Phase.CANCELLED
        req.finish_time = self.now
        handle.state = HandleState.CANCELLED
        self.stats.cancelled += 1
        if self.observer is not None:
            self.observer.request_cancelled(req)
        return True

    def drain(self, *, max_steps: int = 10_000) -> EngineStats:
        """Step until every submitted request finished (or nothing can
        make progress / ``max_steps``); returns the finalized stats."""
        steps = 0
        while (self.waiting or self.admission.queued_count()
               or self._any_active()):
            if steps >= max_steps:
                break
            steps += 1
            events = self.step()
            if not events and not self.waiting and not self._any_active():
                # only queued requests remain and the pools are at rest:
                # nothing in flight can free pages/slabs, so drain() can
                # never make progress — exit instead of spinning
                break
        return self.finalize()

    def finalize(self) -> EngineStats:
        """Fold per-request latency samples into the stats snapshot."""
        self.stats.wall_s = self.now
        self.stats.tbt = [t for rid in self._window
                          for t in self._submitted[rid].tbt_samples()]
        if self.arena is not None:
            self.stats.weights_pool = self.arena.utilization()
        if self.telemetry is not None:
            self.stats.elastic = self.telemetry.snapshot()
            if self.rebalancer is not None:
                self.stats.elastic.update(self.rebalancer.snapshot())
        return self.stats

    def reset_stats(self) -> EngineStats:
        """Open a fresh measurement window on a live session (long-running
        sessions measure in windows: warmup/steady-state, per-tenant
        SLOs).  Step-time logs, token counters and per-request latency
        folds restart; the admission controller's lifetime counters keep
        accumulating and stay visible on the new snapshot.  Terminal
        requests and their handles are PRUNED here — this is the point
        that bounds a long-lived session's memory — so a session that
        never resets retains every handle it ever created."""
        if self.recorder is not None:
            # causal: pruning changes later admission-assert behavior and
            # the stats window, so a replay must reset at the same point
            self.recorder.record_op("reset_stats", now=self.now)
        self.stats = EngineStats(step_times={n: [] for n in self.models},
                                 admission=self.admission.stats)
        for rid, handle in list(self.handles.items()):
            if handle.state.terminal:
                del self.handles[rid]
                del self._submitted[rid]
        self._window.clear()
        if self.observer is not None:
            self.observer.reset_window()
        if self.slo is not None:
            # windowed SLO state follows the windowed histograms
            self.slo.reset()
        return self.stats

    # ------------------------------------------------------------------
    # offline compatibility wrapper
    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *,
            max_steps: int = 10_000) -> EngineStats:
        """Serve a pre-generated trace to completion (or max_steps): a
        thin wrapper that submits arrivals when due and calls ``step`` —
        there is no second serving loop."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        steps = 0
        while (pending or self.waiting or self.admission.queued_count()
               or self._any_active()):
            if steps >= max_steps:
                break
            steps += 1
            # jump virtual time to the next arrival if idle
            if not self.waiting and not self._any_active() and pending:
                self.advance(pending[0].arrival_time)
            due = [r for r in pending if r.arrival_time <= self.now]
            pending = [r for r in pending if r.arrival_time > self.now]
            for r in due:
                self.submit(r)
            events = self.step()
            if (not events and not self.waiting and not pending
                    and not self._any_active()):
                # only queued requests remain (see ``drain``)
                break
        return self.finalize()

    # ------------------------------------------------------------------
    @property
    def busy(self) -> bool:
        """Whether stepping can make progress right now: requests are in
        batch slots or admitted-waiting (queued-only backpressure is
        visible via ``admission.queued_count()`` instead)."""
        return bool(self.waiting) or self._any_active()

    def _any_active(self) -> bool:
        return any(r.active for r in self.runners.values())

    def _activate_model(self, name: str) -> None:
        """Map a cold model's slabs before its first prefill — WITHOUT
        uploading: the streaming prompt phase prefetches layer L+1's slabs
        behind layer L's attention in BOTH lowering modes, so by the first
        decode step every layer is resident and the fused step's
        ``acquire`` has zero upload work left.  The per-request PIN was
        already taken at ADMISSION (``AdmissionController.try_admit``) and
        is released by ``admission.finish`` — so LRU eviction (triggered
        by some OTHER model's activation under slab pressure) can never
        revoke weights an admitted request still needs, even in the
        window before this activation makes the model resident.
        """
        if self.arena is None or not self.runners[name].paged:
            return
        self.arena.activate(name, upload=False)

    def _try_activate(self, req: Request) -> bool:
        """Residency gate for the prefill batcher: False keeps the
        request waiting (resident models' pins drop as they finish)."""
        name = req.model
        try:
            self._activate_model(name)
        except OutOfSlabsError:
            # every resident model is pinned by in-flight requests; those
            # pins drop as they finish, so the request stays waiting —
            # UNLESS the model can never fit even an empty arena
            if self.arena.views[name].total_slabs > self.arena.slot_budget:
                raise
            return False
        if self.runners[name].paged:
            try:
                # pages swapped to the host tier while the request waited
                # fault back in HERE, where deferral is graceful — inside
                # prefill_group a failed fault would abort the whole step
                self.virt.ensure_resident(req.request_id)
            except OutOfPagesError:
                return False
        return True

    # ------------------------------------------------------------------
    def _admit(self, req: Request, now: float) -> str:
        pending = PendingRequest(req.request_id, req.model,
                                 req.prompt_tokens, req.max_new_tokens, now)
        if self.cache is not None:
            if req.prompt_ids is not None:
                pending.prompt_ids = np.asarray(req.prompt_ids,
                                                np.int32).reshape(-1)
            pending.cache = req.cache
            pending.bucket = prompt_bucket(req.prompt_tokens, self.max_ctx)
        outcome = self.admission.offer(pending, now)
        if outcome == "admitted" and self.cache is not None:
            self._prefix_info[req.request_id] = (pending.cached_tokens,
                                                 pending.prefix_routes)
        if outcome == "rejected":
            req.phase = Phase.REJECTED
        return outcome

    def _finish(self, req: Request, now: float) -> None:
        req.phase = Phase.FINISHED
        req.finish_time = now
        if self.telemetry is not None:
            self.telemetry.note_finish(
                req.model, req.prompt_tokens, req.generated,
                req.admit_time, now)
        self.virt.release_request(req.request_id)
        # drops the admission-time pin too: idle models become evictable
        self.admission.finish(req.model)
        self._prefix_info.pop(req.request_id, None)
        handle = self.handles.get(req.request_id)
        if handle is not None:
            handle.state = HandleState.FINISHED
        if self.observer is not None:
            self.observer.request_finished(req)

    # ------------------------------------------------------------------
    def report(self) -> str:
        """Human-readable serving report: throughput, per-model admission
        outcomes, KV-pool and weights-arena utilization."""
        s = self.stats
        lines = [f"tokens={s.tokens_out} wall={s.wall_s:.2f}s "
                 f"throughput={s.throughput:.1f} tok/s "
                 f"slow_steps={s.slow_steps}"]
        adm = self.admission.stats
        lines.append(f"admission: admitted={adm.admitted} "
                     f"queued={adm.queued} rejected={adm.rejected} "
                     f"(pressure: pages={adm.page_pressure_queued} "
                     f"weights={adm.weight_pressure_queued}, "
                     f"reserve={self.admission.reserve_pages} pages)")
        for name in self.models:
            m = adm.per_model.get(name)
            if m is not None:
                lines.append(f"  {name}: admitted={m.admitted} "
                             f"queued={m.queued} rejected={m.rejected}")
        coalesced = [b for b in s.prefill_batch_sizes if b > 1]
        lines.append(f"prefill: {len(s.prefill_batch_sizes)} passes, "
                     f"{len(coalesced)} coalesced "
                     f"(max B = {max(s.prefill_batch_sizes, default=0)})")
        u = self.virt.utilization()
        lines.append(f"kv pool: peak {u['peak_mapped']}/"
                     f"{self.virt.page_budget} pages, "
                     f"frag {u['internal_frag_bytes'] / 1024:.1f} KiB, "
                     f"swap {u['swap_out_pages']} out / "
                     f"{u['swap_in_pages']} in "
                     f"({u['swapped_pages']} held), "
                     f"{u['resizes']} resizes")
        if self.cache is not None:
            c = self.cache.snapshot()
            lines.append(
                f"prefix cache: {int(c['hits'])} hits / "
                f"{int(c['misses'])} misses "
                f"({c['hit_token_fraction']:.1%} of prompt tokens cached), "
                f"{int(c['device_pages_held'])} pages held, "
                f"{int(c['shed_pages'])} shed / {int(c['faulted_pages'])} "
                f"re-faulted, {int(c['evicted_pages'])} evicted")
        if self.telemetry is not None:
            t = self.telemetry.snapshot()
            lines.append(
                f"elastic: occupancy EWMA kv={t['kv_occupancy_ewma']:.3f} "
                f"slabs={t['slab_occupancy_ewma']:.3f} "
                f"queue={t['queue_depth_ewma']:.2f}")
            if self.rebalancer is not None:
                r = self.rebalancer.snapshot()
                lines.append(
                    f"  rebalancer: {int(r['rebalances'])} applied / "
                    f"{int(r['evaluations'])} evaluated "
                    f"(hysteresis skips {int(r['skipped_hysteresis'])}, "
                    f"cooldown {int(r['skipped_cooldown'])}, "
                    f"aborted {int(r['aborted'])}); live split "
                    f"{int(r['page_budget'])} pages / "
                    f"{int(r['slot_budget'])} slabs")
                # rendered from the registry's event log (NOT EngineStats
                # lists), so this text can never disagree with /metrics
                for e in self.metrics.recent_events("rebalance", 3):
                    lines.append(
                        f"  move @step {e['step']}: pages "
                        f"{e['page_budget'][0]}->{e['page_budget'][1]}, "
                        f"slabs {e['slot_budget'][0]}->"
                        f"{e['slot_budget'][1]} "
                        f"({e['reason']}, swapped {e['swapped_out']}, "
                        f"evicted {e['evicted_models']})")
        if self.arena is not None:
            w = self.arena.utilization()
            lines.append(
                f"weights arena: {w['resident_slabs']}/{w['slot_budget']} "
                f"slabs resident ({w['resident_models']} models), "
                f"{w['activations']} activations, {w['evictions']} "
                f"evictions, {w['layer_uploads']} layer uploads")
            lines.append(
                f"  device FFN bytes (prefill AND decode): "
                f"{w['device_bytes'] / 2 ** 20:.1f} MiB — slot_budget x "
                f"slab_bytes, no full-tree phase remains")
        if self.slo is not None:
            lines.append(self.slo.report_line(self.now))
            for e in self.metrics.recent_events("slo_breach", 3):
                lines.append(
                    f"  breach @{e['time']:.2f}s: {e['model']} "
                    f"{e['metric']} > {e['threshold_ms']:g}ms "
                    f"(burn {e['long_burn']:.1f}x long / "
                    f"{e['short_burn']:.1f}x short, "
                    f"window value {e['window_value_ms']:.1f}ms)")
        dropped = self.metrics.events_dropped()
        if dropped:
            # the event log is bounded: consumers of recent_events() must
            # be able to see that the lines above may be truncated
            lines.append("event log overflow: " + ", ".join(
                f"{kind} dropped {n}"
                for kind, n in sorted(dropped.items())))
        if self.recorder is not None:
            lines.append(
                f"flight recorder: {len(self.recorder.ring)} events "
                f"ringed, {len(self.recorder.snapshots)} snapshots, "
                f"{self.recorder.dumps} dumps")
        return "\n".join(lines)

    # ------------------------------------------------------------------
    # virtual-clock folding (the engine's ONLY nondeterministic input)
    # ------------------------------------------------------------------
    def attach_replay_clock(self, entries) -> None:
        """Replay mode: dispatch durations come from this recorded
        ``(tag, dt)`` stream instead of the host clock.  Everything else
        in the engine is deterministic, so consuming the stream in order
        reproduces the original session bit-exactly (flightrec/replay)."""
        self._replay_dts = collections.deque(entries)

    def _clocked(self, tag: str, t0: float) -> float:
        """Wall duration of one dispatch since ``t0``: replay-injectable
        (a replaying engine consumes the recorded stream) and recorded
        (a recording engine logs the dt ACTUALLY USED, post-injection,
        so a replay re-records a bit-identical clock stream)."""
        dt = time.perf_counter() - t0  # cp: allow(CP006) real dispatch duration
        if self._replay_dts is not None:
            dt = self._next_replay_dt(tag)
        if self.recorder is not None:
            self.recorder.record_dt(tag, dt)
        return dt

    def _next_replay_dt(self, tag: str) -> float:
        if not self._replay_dts:
            raise ReplayDivergence(
                f"clock stream exhausted at '{tag}' "
                f"(step {self._step_index}): the replay dispatched more "
                f"work than the record")
        rec_tag, dt = self._replay_dts.popleft()
        if rec_tag != tag:
            raise ReplayDivergence(
                f"clock stream diverged at step {self._step_index}: "
                f"recorded '{rec_tag}', live '{tag}'")
        return float(dt)

    def _record_step(self, name: str, dt: float) -> None:
        log = self.stats.step_times[name]
        if len(log) > 8 and dt > np.median(log) * 4.0:
            self.stats.slow_steps += 1     # straggler flag
        log.append(dt)
        if self.observer is not None:
            # same per-model attribution as step_times, so the exported
            # dispatch histogram mirrors the stats log exactly
            self.observer.decode_dispatch(name, dt)

    def _host_step(self, name: str) -> Optional[HostDrivenStep]:
        if self.host_steps is None:
            return None
        return self.host_steps.get(name)

    def _emit(self, event: TokenEvent) -> None:
        self._events.append(event)
        handle = self.handles.get(event.request_id)
        if handle is not None and handle.on_token is not None:
            handle.on_token(event)

    def _book_tokens(self, runner: ModelRunner, toks: np.ndarray,
                     counts: np.ndarray, act: List[int], start: float,
                     dt: float) -> None:
        """Fan one committed decode block out into per-token events.

        ``toks`` is [B, K] with each row's valid tokens a strict prefix
        of length ``counts[i]``.  The dispatch's wall time ``dt`` is
        interpolated across a row's tokens (token t of n stamps at
        ``start + dt*(t+1)/n``) so TBT reflects the amortised per-token
        cost — at K=1 this degenerates to the seed's ``start + dt``.
        Streaming callbacks fire per token, preserving the K=1 contract.
        """
        obs = self.observer
        rec = self.recorder
        slo = self.slo
        for i in act:
            req = runner.slots[i]
            n = int(counts[i])
            if n:
                if obs is not None:
                    obs.decode_block(req, n, dt)
                if rec is not None:
                    rec.record_commit(req.request_id, req.model, n, dt)
            for t in range(n):
                tok = int(toks[i, t])
                req.generated += 1
                req.output_ids.append(tok)
                when = start + dt * (t + 1) / n
                # the same pairwise gap tbt_samples() reconstructs — the
                # shared TBT histogram, EngineStats.tbt and the SLO
                # window all hold identical values
                gap = when - req.token_times[-1]
                if obs is not None:
                    obs.token(req, gap)
                if slo is not None:
                    slo.note("tbt", req.model, gap, when)
                req.token_times.append(when)
                if rec is not None:
                    rec.note_token(req.request_id, req.model, tok, when)
                self.stats.tokens_out += 1
                if req.eos_id is not None and tok == req.eos_id:
                    req.eos_seen = True
                self._emit(TokenEvent(
                    request_id=req.request_id, model=req.model,
                    token=tok, index=req.generated - 1, time=when,
                    done=req.done))

    def _book_first_token(self, req: Request, now: float) -> None:
        req.first_token_time = now
        req.token_times.append(now)
        req.generated += 1
        self.stats.tokens_out += 1
        self.stats.ttft.append(now - req.arrival_time)
        if self.observer is not None:
            self.observer.first_token(req, now - req.arrival_time)
        if self.slo is not None:
            self.slo.note("ttft", req.model, now - req.arrival_time, now)
        if self.recorder is not None:
            self.recorder.record_commit(req.request_id, req.model, 1,
                                        0.0, first=True)
            self.recorder.note_token(req.request_id, req.model,
                                     req.output_ids[-1], now)
        handle = self.handles.get(req.request_id)
        if handle is not None:
            handle.state = HandleState.DECODING
        self._emit(TokenEvent(
            request_id=req.request_id, model=req.model,
            token=req.output_ids[-1], index=0, time=now, first=True,
            done=req.done))

    # ------------------------------------------------------------------
    # prefill phase
    # ------------------------------------------------------------------
    def _prefill_groups(self, groups: List[PrefillGroup],
                        now: float) -> float:
        """Execute the coalesced groups.  In host-driven pipeline mode,
        distinct models' prompt phases interleave through the layer-wise
        scheduler (model A's layer-L attention overlaps model B's FFN and
        each model's own layer-L+1 slab upload); everything else runs the
        sequential streaming path — one [B, S] pass per group."""
        self.stats.prefill_batch_sizes.extend(g.batch_size for g in groups)
        if self.scheduler is not None and self.mode.pipeline:
            first: Dict[str, PrefillGroup] = {}
            rest: List[PrefillGroup] = []
            for g in groups:
                runner = self.runners[g.model]
                # suffix groups and tree-insert candidates stay on the
                # sequential streaming path: the scheduler has no suffix
                # stage and no route capture
                if (runner.paged and g.model not in first and g.fork == 0
                        and not runner.cache_insert_candidate(g)):
                    first[g.model] = g
                else:
                    rest.append(g)
            if len(first) >= 2:
                now = self._prefill_pipelined(list(first.values()), now)
                groups = rest
        for g in groups:
            runner = self.runners[g.model]
            t0 = time.perf_counter()  # cp: allow(CP006) real dispatch duration
            runner.prefill_group(g)
            dt = self._clocked("prefill", t0)
            now += dt
            if self.observer is not None:
                self.observer.prefill(g.model, g.batch_size, dt)
            for req in g.requests:
                self._book_first_token(req, now)
        return now

    def _prefill_pipelined(self, groups: List[PrefillGroup],
                           now: float) -> float:
        """Concurrent cold-model prompt phases through the scheduler."""
        t0 = time.perf_counter()  # cp: allow(CP006) real dispatch duration
        batches = [self.runners[g.model].make_prefill_batch(g, i)
                   for i, g in enumerate(groups)]
        done, pool = self.scheduler.run(batches, self.virt.pool,
                                        max_inflight=2)
        self.virt.pool = pool
        dt = self._clocked("prefill_pipe", t0)
        now += dt
        by_model = {g.model: g for g in groups}
        for b in done:
            g = by_model[b.model]
            self.runners[b.model].apply_prefill_result(b, g)
            if self.observer is not None:
                self.observer.prefill(g.model, g.batch_size, dt)
            for req in g.requests:
                self._book_first_token(req, now)
        return now

    # ------------------------------------------------------------------
    # decode phase
    # ------------------------------------------------------------------
    def _decode_model(self, name: str, now: float) -> float:
        runner = self.runners[name]
        obs = self.observer
        t0 = time.perf_counter()  # cp: allow(CP006) real dispatch duration
        if obs is not None:
            obs.phase_begin("dispatch")
        pending = runner.issue_decode(self._host_step(name))
        if obs is not None:
            obs.phase_end("dispatch")
            obs.phase_begin("commit")
        toks, counts, act = runner.commit_decode(pending)
        if obs is not None:
            obs.phase_end("commit")
        dt = self._clocked("decode", t0)
        self._record_step(name, dt)
        self._book_tokens(runner, toks, counts, act, now, dt)
        return now + dt

    def _decode_pipelined(self, active: List[str], now: float) -> float:
        """Two (or more) models stepped with overlapping execution.

        lowering=ON : every model's fused paged step is ISSUED before any
        is blocked on — async dispatch overlaps the programs (the shared
        pool buffer is threaded through the dispatch chain).
        lowering=OFF: the layer-wise pipeline scheduler interleaves the
        models' attention/FFN stages across the two pools (paper Fig. 4)."""
        if not self.mode.lowering:
            return self._decode_pipelined_host(active, now)
        obs = self.observer
        t0 = time.perf_counter()  # cp: allow(CP006) real dispatch duration
        if obs is not None:
            obs.phase_begin("dispatch")
        issued = [(n, self.runners[n].issue_decode(None)) for n in active]
        if obs is not None:
            obs.phase_end("dispatch")
            obs.phase_begin("commit")
        dt_all = 0.0
        for n, pending in issued:
            runner = self.runners[n]
            toks, counts, act = runner.commit_decode(pending)
            # one clock read per model commit: each is a replay-injection
            # point, consumed in model order
            dt_all = self._clocked("decode_pipe", t0)
            self._book_tokens(runner, toks, counts, act, now, dt_all)
        if obs is not None:
            obs.phase_end("commit")
        for n in active:
            self._record_step(n, dt_all / len(active))
        return now + dt_all

    def _decode_pipelined_host(self, active: List[str], now: float) -> float:
        """Layer-wise two-batch pipeline over the disaggregated pools."""
        obs = self.observer
        t0 = time.perf_counter()  # cp: allow(CP006) real dispatch duration
        if obs is not None:
            obs.phase_begin("dispatch")
        paged = [n for n in active if self.runners[n].paged]
        fallback = [n for n in active if not self.runners[n].paged]
        batches, acts = [], {}
        for i, n in enumerate(paged):
            batch, act = self.runners[n].make_inflight_batch(i)
            batches.append(batch)
            acts[n] = act
        done, pool = self.scheduler.run(batches, self.virt.pool,
                                        max_inflight=2)
        self.virt.pool = pool
        dt_all = self._clocked("decode_host", t0)
        if obs is not None:
            obs.phase_end("dispatch")
            obs.phase_begin("commit")
        for b in done:
            runner = self.runners[b.model]
            toks, counts, act = runner.apply_pipeline_result(b, acts[b.model])
            self._book_tokens(runner, toks, counts, act, now, dt_all)
            self._record_step(b.model, dt_all / max(len(paged), 1))
        if obs is not None:
            obs.phase_end("commit")
        now += dt_all
        for n in fallback:          # families outside split execution
            now = self._decode_model(n, now)
        return now


#: Back-compat alias: the ISSUE's name for the session-capable engine.
ServingSession = CrossPoolEngine
