"""whisper-small — encoder-decoder audio backbone [arXiv:2212.04356; unverified].

Assigned config: 12L d_model=768 12H (kv=12, MHA) d_ff=3072 vocab=51865.
Encoder-decoder with a conv mel frontend, which is a STUB here:
``input_specs()`` provides precomputed frame embeddings (1500 frames after
the 2x conv downsampling of 30s audio).

Shape notes (DESIGN.md): decode_32k exceeds Whisper's 448 learned positions;
we lower it with sinusoidal positions and note the deviation.  long_500k is
SKIPPED (pure full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,                # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,
    attention="gqa",            # MHA == GQA with n_kv == n_heads
    is_encoder_decoder=True,
    n_encoder_layers=12,
    encoder_seq=1500,
    frontend="audio_frames",
    frontend_tokens=1500,
    mlp_kind="gelu",
    tie_embeddings=True,
    rope_theta=0.0,             # whisper uses learned/sinusoidal positions
    max_position=448,
    source="arXiv:2212.04356; unverified",
)

SMOKE = CONFIG.replace(
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=256, encoder_seq=32, frontend_tokens=32,
    max_position=448,
)
