"""Paged-pool decode vs dense-cache fused decode: the tentpole invariants.

* multi-step logits parity between the shared-pool paged path (fused AND
  host-driven lowering) and the dense contiguous-cache path, across GQA
  and MLA configs;
* property test: map/extend/release sequences never leak pages and
  ``utilization()`` stays consistent under mid-sequence OutOfPagesError;
* engine-level acceptance: the engine allocates a live device pool, split
  families carry NO dense per-model KV cache, and total device KV bytes
  are set by ``page_budget`` alone — constant as the colocated model
  count grows.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import PAPER_COLOC_SET, get_smoke_config
from repro.core.control import HostDrivenStep, PagedFusedStep
from repro.core.pools import build_pools
from repro.core.virtualizer import KVVirtualizer, OutOfPagesError
from repro.models import build_model


def _setup(name):
    cfg = get_smoke_config(name).replace(dtype="float32")
    models = {name: cfg}
    model = build_model(cfg)
    params = {name: model.init(jax.random.PRNGKey(0))}
    kv_pool, w_pool, pooled = build_pools(
        models, params, page_budget=256, page_bytes=4096,
        pool_dtype=jnp.float32)
    return cfg, model, params, kv_pool.virtualizer, pooled


@pytest.mark.parametrize("name", ["qwen3-moe-235b-a22b", "minicpm3-4b"])
@pytest.mark.parametrize("lowering", [True, False])
def test_paged_decode_matches_dense_multistep(name, lowering):
    """Greedy-decode N steps through the paged pool and the dense cache in
    lockstep; every step's logits must agree."""
    cfg, model, params, virt, pooled = _setup(name)
    B, seq, max_len, n_steps = 2, 8, 16, 4
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, seq)), jnp.int32)
    cache = model.init_cache(B, max_len)
    _, cache = model.prefill(params[name], tokens, cache)

    for b in range(B):
        virt.register_request(b, name, seq)
        virt.write_prompt_from_cache(name, b, cache, seq, batch_index=b)

    view = virt.views[name]
    max_pages = max(1, math.ceil(max_len / view.tokens_per_page))
    devs = jax.devices()
    step = (PagedFusedStep(pooled[name]) if lowering
            else HostDrivenStep(pooled[name], devs[0], devs[-1]))

    next_tok = jnp.zeros((B,), jnp.int32)
    for t in range(n_steps):
        length = seq + t
        want, cache = model.decode_step(params[name], next_tok, cache,
                                        jnp.int32(length))
        for b in range(B):
            virt.extend_request(b, 1)
        tables = virt.batch_tables(name, [0, 1], max_pages)
        got, virt.pool = step(next_tok, virt.pool, tables,
                              jnp.full((B,), length, jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        # feed the SAME (dense-path) greedy token to both paths
        next_tok = jnp.argmax(want, axis=-1).astype(jnp.int32)


@settings(max_examples=15, deadline=None)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["register", "extend", "release"]),
              st.sampled_from(list(PAPER_COLOC_SET)),
              st.integers(1, 2000)),
    min_size=1, max_size=30))
def test_property_no_leak_under_oom(ops):
    """Random map/extend/release interleavings, including ones that hit
    OutOfPagesError mid-sequence: no page leaks, no double mapping, layer
    tables stay equal-length, utilization() stays consistent."""
    budget = 64
    virt = KVVirtualizer({n: get_smoke_config(n) for n in PAPER_COLOC_SET},
                         page_budget=budget, page_bytes=4096,
                         allocate_device_pool=False)
    live = {}
    next_id = 0
    for op, model, toks in ops:
        try:
            if op == "register" or not live:
                virt.register_request(next_id, model, toks)
                live[next_id] = model
                next_id += 1
            elif op == "extend":
                rid = next(iter(live))
                virt.extend_request(rid, toks)
            else:
                rid = next(iter(live))
                virt.release_request(rid)
                del live[rid]
        except OutOfPagesError:
            pass
        # invariants after EVERY op, failed or not
        mapped = [p for r in virt.requests.values() for t in r.tables for p in t]
        mapped += [p for r in virt.requests.values() for p in r.state_pages]
        assert len(mapped) == len(set(mapped)), "double-mapped page"
        assert len(mapped) + virt.free_pages == budget, "page leak"
        for r in virt.requests.values():
            assert len({len(t) for t in r.tables} | {0}) <= 2, \
                "unequal layer tables"
        u = virt.utilization()
        assert u["mapped_pages"] == len(mapped)
        assert u["internal_frag_bytes"] >= 0
    for rid in list(live):
        virt.release_request(rid)
    assert virt.free_pages == budget


class TestEngineAcceptance:
    def _engine(self, names, budget=2048):
        from repro.runtime.engine import CrossPoolEngine, EngineMode
        models = {n: get_smoke_config(n).replace(dtype="float32")
                  for n in names}
        return CrossPoolEngine(models, page_budget=budget, page_bytes=4096,
                               max_batch=2, max_ctx=64,
                               mode=EngineMode(pipeline=True, lowering=True))

    def test_live_pool_and_no_dense_caches(self):
        engine = self._engine(PAPER_COLOC_SET)
        assert engine.virt.pool is not None
        for n, runner in engine.runners.items():
            assert runner.paged, f"{n} should run the paged path"
            assert not hasattr(runner, "cache"), \
                f"{n} still allocates a dense KV cache"

    def test_kv_bytes_set_by_page_budget_alone(self):
        """Device KV bytes stay constant as colocated models grow 1 -> 3."""
        one = self._engine(PAPER_COLOC_SET[:1])
        three = self._engine(PAPER_COLOC_SET)
        assert one.virt.pool.nbytes == three.virt.pool.nbytes

    def test_serves_and_releases(self):
        from repro.runtime import observe as trace_mod
        engine = self._engine(PAPER_COLOC_SET)
        reqs = trace_mod.make_requests(
            list(PAPER_COLOC_SET), rps_per_model=2.0, horizon_s=2,
            kind="sharegpt", seed=5, scale_tokens=0.05, max_new_cap=4)[:4]
        for r in reqs:
            r.prompt_tokens = max(min(r.prompt_tokens, 24), 4)
        stats = engine.run(reqs)
        assert stats.tokens_out > 0
        assert engine.virt.mapped_pages == sum(
            sum(len(t) for t in rp.tables) + len(rp.state_pages)
            for rp in engine.virt.requests.values())
