"""Prefill-through-arena acceptance: the PR-3 tentpole invariants.

* streaming prefill parity: prompt-phase FFN served from the shared slab
  arena with layer-by-layer weight uploads reproduces the seed full-tree
  ``model.prefill`` BIT-EXACTLY — logits AND the prompt KV scattered into
  the shared pool;
* streaming activation: a cold model's prefill starts with ZERO layers
  uploaded and finishes fully resident, one layer upload per layer;
* scheduler interleave: two models' prompt phases through the layer-wise
  pipeline scheduler reproduce the sequential streaming results exactly;
* pin/unpin mid-stream: a model evicted between prefill and its first
  decode is transparently re-activated (bit-identical logits), and a
  PINNED model can never be evicted in that window;
* the engine holds NO device-resident full param tree for paged models —
  device FFN bytes are slot_budget-bounded for prefill AND decode;
* arena-aware admission: a cold-model burst that cannot co-reside queues
  at the front door (no LRU thrash) and drains as pins drop.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PAPER_COLOC_SET, get_smoke_config
from repro.core.admission import AdmissionController, PendingRequest
from repro.core.control import PagedFusedStep, StreamingPrefill
from repro.core.pipeline import LayerPipelineScheduler
from repro.core.pools import build_pools
from repro.core.weight_pool import OutOfSlabsError, slabs_for_config
from repro.models import build_model
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime.request import Request

MOE, MLA = "qwen3-moe-235b-a22b", "minicpm3-4b"


def _build(names, dtype="float32", slot_budget=None, slab_bytes=4096,
           page_budget=256, activate=False):
    models = {n: get_smoke_config(n).replace(dtype=dtype) for n in names}
    params = {n: build_model(c).init(jax.random.PRNGKey(i))
              for i, (n, c) in enumerate(models.items())}
    kv_pool, w_pool, pooled = build_pools(
        models, params, page_budget=page_budget, page_bytes=4096,
        pool_dtype=jnp.float32 if dtype == "float32" else jnp.bfloat16,
        slot_budget=slot_budget, slab_bytes=slab_bytes,
        activate_resident=activate)
    return models, params, kv_pool, w_pool, pooled


def _prompt(cfg, seq, bucket, seed=0):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, cfg.vocab_size, bucket).astype(np.int32)
    return jnp.asarray(ids[None, :]), seq


def _writer(virt, name, rid, n_tokens):
    def write(layer, layer_kv, pool):
        return virt.write_prompt_layer(pool, name, rid, layer, layer_kv,
                                       n_tokens)
    return write


# ---------------------------------------------------------------------------
# bit-exact parity vs the seed full-tree prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", [MOE, MLA])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_streaming_prefill_matches_full_tree_bit_exact(name, dtype):
    """Arena prefill == full-tree prefill, bit for bit: the returned
    logits AND every prompt-KV byte landing in the shared pool."""
    models, params, kv_pool, w_pool, pooled = _build((name,), dtype=dtype)
    cfg = models[name]
    virt = kv_pool.virtualizer
    arena = w_pool.arena
    model = build_model(cfg)
    seq, bucket = 7, 16
    tokens, _ = _prompt(cfg, seq, bucket)

    # seed path: fused full-sequence prefill over the FULL param tree,
    # dense transient cache scattered into pool pages afterwards
    cache = model.init_cache(1, bucket)
    want, cache = model.prefill(params[name], tokens, cache,
                                logit_index=seq - 1)
    virt.register_request(0, name, seq)
    virt.write_prompt_from_cache(name, 0, cache, seq)

    # arena path: per-layer streaming with NO full tree anywhere
    virt.register_request(1, name, seq)
    assert not arena.is_resident(name)
    uploads0 = arena.layer_uploads
    sp = StreamingPrefill(pooled[name])
    got, virt.pool = sp(tokens, seq, virt.pool, _writer(virt, name, 1, seq))

    assert np.array_equal(np.asarray(want), np.asarray(got)), \
        f"{name}/{dtype}: streaming arena prefill logits != full-tree"
    # streaming activation: started cold, ended fully uploaded, one layer
    # upload per layer
    assert arena.residency[name].uploaded.all()
    assert arena.layer_uploads - uploads0 == cfg.n_layers
    # the prompt KV bytes in the pool must be identical page-for-page
    pool_np = np.asarray(virt.pool)
    r0, r1 = virt.requests[0], virt.requests[1]
    for t0, t1 in zip(r0.tables, r1.tables):
        for p0, p1 in zip(t0, t1):
            assert np.array_equal(pool_np[p0], pool_np[p1]), \
                f"{name}/{dtype}: prompt KV bytes differ in the pool"


def test_scheduler_prefill_interleaves_and_matches_sequential():
    """Two cold models' prompt phases through the pipeline scheduler:
    logits identical to sequential streaming prefill, stages interleaved,
    uploads streamed (never a monolithic upload)."""
    from repro.core.pipeline import InflightBatch
    models, params, kv_pool, w_pool, pooled = _build((MOE, MLA))
    virt = kv_pool.virtualizer
    arena = w_pool.arena
    devs = jax.devices()
    seq, bucket = 7, 16

    # sequential reference
    seq_logits = {}
    for rid, name in enumerate(models):
        tokens, _ = _prompt(models[name], seq, bucket, seed=rid)
        virt.register_request(rid, name, seq)
        sp = StreamingPrefill(pooled[name])
        seq_logits[name], virt.pool = sp(tokens, seq, virt.pool,
                                         _writer(virt, name, rid, seq))
    for name in models:
        arena.unpin(name)
        arena.evict(name)               # back to cold

    sched = LayerPipelineScheduler(pooled, devs[0], devs[-1])
    batches = []
    for i, name in enumerate(models):
        tokens, _ = _prompt(models[name], seq, bucket, seed=i)
        rid = 10 + i
        virt.register_request(rid, name, seq)
        batches.append(InflightBatch(
            batch_id=i, model=name, tokens=tokens, prefill=True,
            true_len=seq, kv_writer=_writer(virt, name, rid, seq)))
    done, virt.pool = sched.run(batches, virt.pool, max_inflight=2)
    assert len(done) == 2
    for b in done:
        assert np.array_equal(np.asarray(seq_logits[b.model]),
                              np.asarray(b.logits)), b.model
        assert arena.residency[b.model].uploaded.all()
    # the round-robin issue order must actually interleave the two pools
    assert sched.overlap_fraction() > 0.3
    models_in_log = {e[1] for e in sched.stage_log}
    assert models_in_log == set(models)


# ---------------------------------------------------------------------------
# pin/unpin correctness between prefill and the first decode
# ---------------------------------------------------------------------------

def test_eviction_between_prefill_and_first_decode():
    """A model evicted mid-stream (after prefill, before its first decode)
    is re-activated transparently by the decode step's ``acquire`` and
    produces bit-identical logits; while PINNED it cannot be evicted."""
    models, params, kv_pool, w_pool, pooled = _build((MOE, MLA))
    virt = kv_pool.virtualizer
    arena = w_pool.arena
    name, cfg = MOE, models[MOE]
    seq, bucket = 7, 16
    tokens, _ = _prompt(cfg, seq, bucket)
    virt.register_request(0, name, seq)
    sp = StreamingPrefill(pooled[name])
    arena.pin(name)                      # the engine's per-request pin
    _, virt.pool = sp(tokens, seq, virt.pool, _writer(virt, name, 0, seq))
    virt.extend_request(0, 1)

    view = virt.views[name]
    max_pages = max(1, math.ceil(32 / view.tokens_per_page))
    tables = virt.batch_tables(name, [0], max_pages)
    lengths = jnp.full((1,), seq, jnp.int32)
    next_tok = jnp.zeros((1,), jnp.int32)
    step = PagedFusedStep(pooled[name])

    # pinned: the prefill-to-first-decode window is eviction-proof
    with pytest.raises(ValueError):
        arena.evict(name)
    logits1, _ = step(next_tok, virt.pool, tables, lengths)

    # now simulate the mid-stream eviction: pins dropped (request aborted
    # elsewhere / accounting bug being defended against), model evicted
    arena.unpin(name)
    arena.evict(name)
    assert not arena.is_resident(name)
    logits2, _ = step(next_tok, virt.pool, tables, lengths)
    assert arena.is_resident(name) and arena.residency[name].uploaded.all()
    assert np.array_equal(np.asarray(logits1), np.asarray(logits2)), \
        "re-activation after mid-stream eviction changed decode logits"


# ---------------------------------------------------------------------------
# the engine holds no full tree; device FFN bytes phase-invariant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lowering", [True, False])
def test_engine_paged_models_hold_no_full_tree(lowering):
    models = {n: get_smoke_config(n).replace(dtype="float32")
              for n in PAPER_COLOC_SET}
    engine = CrossPoolEngine(
        models, page_budget=2048, page_bytes=4096, slab_bytes=4096,
        max_batch=2, max_ctx=64,
        mode=EngineMode(pipeline=True, lowering=lowering))
    for n, runner in engine.runners.items():
        assert runner.paged, n
        assert runner.params is None, \
            f"{n}: paged runner still holds a device-resident param tree"
    assert engine.arena.device_bytes() == \
        engine.arena.slot_budget * engine.arena.slab_bytes
    reqs = [Request(request_id=i, model=n, prompt_tokens=6,
                    max_new_tokens=2, arrival_time=0.0)
            for i, n in enumerate(models)]
    stats = engine.run(reqs)
    assert all(r.finish_time > 0 for r in reqs)
    assert stats.tokens_out == sum(r.max_new_tokens for r in reqs)
    assert "no full-tree phase remains" in engine.report()


def test_engine_pipelined_prefill_host_mode():
    """pipeline=ON / lowering=OFF: concurrent cold-model prompt phases go
    through the layer-wise scheduler and still serve to completion."""
    models = {n: get_smoke_config(n).replace(dtype="float32")
              for n in (MOE, MLA)}
    engine = CrossPoolEngine(
        models, page_budget=2048, page_bytes=4096, slab_bytes=4096,
        max_batch=2, max_ctx=64,
        mode=EngineMode(pipeline=True, lowering=False))
    reqs = [Request(request_id=i, model=n, prompt_tokens=6,
                    max_new_tokens=3, arrival_time=0.0)
            for i, n in enumerate(models)]
    stats = engine.run(reqs)
    assert all(r.finish_time > 0 for r in reqs)
    assert stats.tokens_out > 0
    # prefill stages went through the scheduler's log
    assert any(e[2] == "attn" for e in engine.scheduler.stage_log)


# ---------------------------------------------------------------------------
# arena-aware admission
# ---------------------------------------------------------------------------

def test_admission_queues_cold_burst_under_arena_pressure():
    """With a one-model arena, the second cold model's request QUEUES at
    admission (weights pressure) and drains once the first finishes."""
    models, params, kv_pool, w_pool, pooled = _build(
        (MOE, MLA), page_budget=4096,
        slot_budget=max(slabs_for_config(
            get_smoke_config(n).replace(dtype="float32"), 4096)
            for n in (MOE, MLA)))
    virt = kv_pool.virtualizer
    arena = w_pool.arena
    adm = AdmissionController(virt, arena=arena)

    r_moe = PendingRequest(0, MOE, 8, 4, 0.0)
    r_mla = PendingRequest(1, MLA, 8, 4, 0.0)
    assert adm.offer(r_moe, 0.0) == "admitted"
    # admission takes the pin immediately — BEFORE the model is resident
    assert arena.pins.get(MOE) == 1
    # MOE not activated yet, but its slabs are PROMISED: MLA must queue
    assert adm.offer(r_mla, 0.0) == "queued"
    assert adm.stats.weight_pressure_queued == 1
    arena.activate(MOE, upload=False)
    assert adm.drain(1.0) == []          # still pinned + in flight
    assert adm.drain(1.5) == []          # drain retries do NOT inflate
    assert adm.stats.weight_pressure_queued == 1
    adm.finish(MOE)                      # drops the pin + in-flight count
    assert MOE not in arena.pins
    drained = adm.drain(2.0)
    assert [p.request_id for p in drained] == [1]
    assert adm.stats.admitted == 2 and adm.stats.queued == 1


def test_admission_pin_protects_lru_victim_before_prefill():
    """A model with an admitted-but-not-yet-prefilled request cannot be
    picked as an LRU eviction victim by another activation: the pin is
    taken at ADMISSION, closing the admission-to-prefill window."""
    models, params, kv_pool, w_pool, pooled = _build(
        (MOE, MLA), page_budget=4096,
        slot_budget=max(slabs_for_config(
            get_smoke_config(n).replace(dtype="float32"), 4096)
            for n in (MOE, MLA)))
    arena = w_pool.arena
    adm = AdmissionController(kv_pool.virtualizer, arena=arena)
    arena.activate(MOE, upload=False)    # resident, idle, LRU-oldest
    assert adm.offer(PendingRequest(0, MOE, 8, 4, 0.0), 0.0) == "admitted"
    # cold MLA activation under pressure must NOT evict MOE (whose
    # admitted request has not prefilled yet) — it fails atomically
    with pytest.raises(OutOfSlabsError):
        arena.activate(MLA, upload=False)
    assert arena.is_resident(MOE)
    adm.finish(MOE)
    arena.activate(MLA, upload=False)    # now MOE is a legal victim
    assert arena.is_resident(MLA) and not arena.is_resident(MOE)


def test_engine_cold_burst_queues_not_thrash():
    """Engine-level: two cold models arriving together through a one-model
    arena both complete; the loser is queued by the admission controller
    (not busy-waited against the LRU) and each model activates exactly
    once — no ping-pong eviction."""
    models = {n: get_smoke_config(n).replace(dtype="float32")
              for n in (MOE, MLA)}
    need = {n: slabs_for_config(c, 4096) for n, c in models.items()}
    engine = CrossPoolEngine(
        models, page_budget=2048, page_bytes=4096,
        slot_budget=max(need.values()), slab_bytes=4096,
        max_batch=2, max_ctx=64,
        mode=EngineMode(pipeline=True, lowering=True))
    reqs = [Request(request_id=0, model=MOE, prompt_tokens=8,
                    max_new_tokens=3, arrival_time=0.0),
            Request(request_id=1, model=MLA, prompt_tokens=8,
                    max_new_tokens=3, arrival_time=0.0)]
    stats = engine.run(reqs)
    assert all(r.finish_time > 0 for r in reqs), "a request was dropped"
    assert stats.admission.weight_pressure_queued >= 1
    assert stats.weights_pool["activations"] == 2, \
        "cold burst must not thrash the arena LRU"
    assert stats.weights_pool["evictions"] == 1
    assert not engine.arena.pins
