"""Online vs batch serving through the session API: decode-tail parity.

Fig. 7 measures TBT tails in the discrete-event simulator at paper scale;
this benchmark runs the REAL engine at smoke scale on one Poisson
ShareGPT-like arrival trace, twice:

  * batch  — the offline ``run()`` compatibility wrapper (the seed API);
  * online — ``submit``/``step`` driven from the arrival clock, tokens
    streamed through per-request callbacks, same-model arrivals coalesced
    into [B, S] prefill passes.

Each engine is warmed up first (every prefill bucket/batch shape and the
decode programs compile before measurement, then ``reset_stats`` opens
the measured window), so the recorded TBTs are compute, not XLA traces.

``run()`` is a thin wrapper over the same step loop, so the two drivers
serve the same token VOLUME (asserted; per-token streams are compared
bit-exactly in ``tests/test_session.py`` on an arrival-free trace —
under live Poisson arrivals the step boundaries land wherever the host's
measured compute times put them, so stream identity across two
wall-clock runs is not a deterministic claim).  The guarded metrics are
the online/batch MEDIAN- and P99-TBT ratios — machine speed cancels in
a ratio, and both carry wide per-metric tolerances (the median is robust
to single-step OS jitter; the P99 is noisier still), so the regression
gate is stable across CI hosts while a real online-path slowdown (extra
dispatches, lost coalescing) still trips it.
"""
from __future__ import annotations

from benchmarks._stats import percentile
from repro.configs import EngineConfig, PAPER_COLOC_SET, get_smoke_config
from repro.runtime import observe as trace_mod
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime.observe import EngineObserver
from repro.runtime.request import Request


def _models():
    return {n: get_smoke_config(n).replace(dtype="float32")
            for n in PAPER_COLOC_SET}


def _engine():
    # both engines carry an observer, so the latency histograms are the
    # measurement source and any observer overhead cancels in the ratio
    return CrossPoolEngine(_models(), page_budget=4096, page_bytes=4096,
                           slab_bytes=4096, max_batch=2, max_ctx=64,
                           config=EngineConfig(
                               mode=EngineMode(pipeline=True, lowering=True)),
                           seed=0, observer=EngineObserver())


def _trace():
    reqs = trace_mod.make_requests(
        list(PAPER_COLOC_SET), rps_per_model=4.0, horizon_s=2.0,
        kind="sharegpt", seed=11, scale_tokens=0.05, max_new_cap=5)
    for r in reqs:
        # snap prompts to the warmed-up lengths: the pool's prompt-KV
        # scatter compiles per (model, n_tokens), so unseen lengths would
        # put XLA traces inside the measured TBT window
        r.prompt_tokens = 6 + (r.prompt_tokens % 2)
    # burst head (the paper's premise: bursty cold-model traffic): each
    # model's first two requests arrive together, so a coalesced [2, S]
    # prefill is part of the measured schedule deterministically — the
    # Poisson tail then exercises per-step late joins
    seen = {}
    for r in reqs:
        if seen.setdefault(r.model, 0) < 2:
            r.arrival_time = 0.0
            seen[r.model] += 1
    reqs.sort(key=lambda r: r.arrival_time)
    return reqs


def _warmup(engine):
    """Compile every shape the measured trace can hit: [1,16] and [2,16]
    prefill (coalesced and late-join), both decode programs."""
    reqs = [Request(10_000 + 10 * i + j, name, 5 + j, 2, 0.0)
            for i, name in enumerate(PAPER_COLOC_SET) for j in range(3)]
    engine.run(reqs)
    assert engine.stats.tokens_out > 0
    assert max(engine.stats.prefill_batch_sizes) > 1
    engine.reset_stats()


def _serve_online(engine, reqs):
    pending = sorted(reqs, key=lambda r: r.arrival_time)
    streamed = 0

    def on_token(event):
        nonlocal streamed
        streamed += 1

    steps = 0
    while pending or engine.busy:
        if steps >= 10_000:
            break
        steps += 1
        # advance-then-submit, exactly like the run() wrapper, so the
        # admission bookkeeping is stamped with the arrival clock
        if not engine.busy and pending:
            engine.advance(pending[0].arrival_time)
        due = [r for r in pending if r.arrival_time <= engine.now]
        pending = [r for r in pending if r.arrival_time > engine.now]
        for r in due:
            engine.submit(r, on_token=on_token)
        events = engine.step()
        if not events and not pending and not engine.busy:
            break
    stats = engine.finalize()
    return stats, streamed


def _measure(engine, online: bool):
    reqs = _trace()
    for r in reqs:
        # the warmup advanced the session clock; keep the Poisson gaps
        r.arrival_time += engine.now
    if online:
        stats, streamed = _serve_online(engine, reqs)
        assert streamed == stats.tokens_out, "callback stream lost tokens"
    else:
        stats = engine.run(reqs)
    # the P50/P99 sources are the SHARED observer histograms (ISSUE 7);
    # they must hold exactly the samples the per-request lists reconstruct
    tbt = engine.observer.tbt.all_samples()
    ttft = engine.observer.ttft.all_samples()
    assert sorted(tbt) == sorted(t for r in reqs for t in r.tbt_samples()), \
        "observer TBT histogram disagrees with per-request token times"
    assert sorted(ttft) == sorted(r.first_token_time - r.arrival_time
                                  for r in reqs if r.first_token_time), \
        "observer TTFT histogram disagrees with per-request arrival clocks"
    return stats, tbt, ttft, reqs


def run(csv=print) -> dict:
    # build + warm BOTH engines before measuring EITHER, so the process
    # (allocator pools, XLA runtime, dispatch paths) is equally warm for
    # the two measured phases
    eng_b, eng_o = _engine(), _engine()
    _warmup(eng_b)
    _warmup(eng_o)
    stats_b, tbt_b, _, reqs_b = _measure(eng_b, online=False)
    stats_o, tbt_o, ttft_o, reqs_o = _measure(eng_o, online=True)

    # run() is a thin wrapper over submit/step: same served volume
    assert len(reqs_b) == len(reqs_o)
    assert stats_o.tokens_out == stats_b.tokens_out, \
        "online submit/step served a different token volume than run()"
    sizes = stats_o.prefill_batch_sizes
    coalesced = sum(1 for b in sizes if b > 1)
    assert coalesced > 0, \
        "the Poisson burst never coalesced a same-model prefill"

    p99_b, p99_o = percentile(tbt_b, 99), percentile(tbt_o, 99)
    p50_b, p50_o = percentile(tbt_b, 50), percentile(tbt_o, 50)
    ratio_p50 = p50_o / p50_b if p50_b else float("nan")
    ratio_p99 = p99_o / p99_b if p99_b else float("nan")
    csv(f"online,batch_p99_tbt_ms={p99_b * 1e3:.2f},"
        f"online_p99_tbt_ms={p99_o * 1e3:.2f},p99_ratio={ratio_p99:.3f}")
    csv(f"online,batch_p50_tbt_ms={p50_b * 1e3:.2f},"
        f"online_p50_tbt_ms={p50_o * 1e3:.2f},p50_ratio={ratio_p50:.3f}")
    csv(f"online,requests={len(reqs_o)},tokens={stats_o.tokens_out},"
        f"prefill_passes={len(sizes)},coalesced={coalesced},"
        f"max_B={max(sizes, default=0)}")
    assert stats_o.tokens_out > 0
    return {
        "batch_p99_tbt_s": p99_b,
        "online_p99_tbt_s": p99_o,
        "batch_p50_tbt_s": p50_b,
        "online_p50_tbt_s": p50_o,
        "online_over_batch_p50": ratio_p50,
        "online_over_batch_p99": ratio_p99,
        "online_p95_ttft_s": percentile(ttft_o, 95),
        "tokens_out": stats_o.tokens_out,
        "prefill_passes": len(sizes),
        "coalesced_passes": int(coalesced),
        "coalesced_max_b": int(max(sizes, default=0)),
    }


if __name__ == "__main__":
    run()
