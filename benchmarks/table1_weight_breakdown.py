"""Table 1: FFN vs attention weight breakdown.

The paper's table shows MoE models put ~95% of params in FFN (the weights
pool wins big) while dense models sit at 66-77%.  We compute the same
breakdown analytically from our configs.
"""
from __future__ import annotations

from repro.configs import ARCH_NAMES, get_config


def run(csv=print) -> dict:
    out = {}
    for name in ARCH_NAMES:
        cfg = get_config(name)
        c = cfg.param_counts()
        ffn = c["ffn"]
        attn = c["attn"] + c["ssm"]
        total = c["total"]
        share = ffn / total if total else 0.0
        csv(f"table1,{name},total_B={total / 1e9:.1f},ffn_B={ffn / 1e9:.1f},"
            f"attn_B={attn / 1e9:.2f},ffn_share={share * 100:.1f}%")
        out[name] = share
    # paper's claim: MoE models are ~95% FFN, dense 60-85%
    assert out["qwen3-moe-235b-a22b"] > 0.90
    assert out["moonshot-v1-16b-a3b"] > 0.90
    assert 0.5 < out["qwen3-14b"] < 0.9
    return out


if __name__ == "__main__":
    run()
