"""Engine observability: metrics registry, span tracer, observer facade.

Three layers, composable bottom-up (DESIGN.md §10):

* :class:`MetricsRegistry` — counters / gauges / histograms with label
  sets, Prometheus text exposition and a JSON-able snapshot, plus a
  bounded structured-event log (``log_event`` / ``recent_events``) that
  backs ``engine.report()``'s last-N rebalance lines.  Histograms keep
  their raw samples, so ``percentile`` over a histogram is EXACTLY
  ``np.percentile`` over the same values — the benchmarks read their
  P50/P99 from here and must agree bit-for-bit with per-request lists.
* :class:`SpanTracer` — Chrome trace-event JSON (Perfetto-loadable)
  recorder.  Tracks (one per request, one per model, one for the engine
  step loop) map to tids; the clock is INJECTED so tests drive a fake
  monotonic clock and assert exact span sequences deterministically.
* :class:`EngineObserver` — the facade the engine wires in.  It extends
  :class:`~repro.core.hooks.CoreHooks`, so attaching the SAME object to
  the virtualizer / arena / admission / rebalancer gives the core layer
  a reporting channel without importing the runtime.

The disabled path is ``engine.observer is None``: every instrumentation
site in the step loop is a single ``is not None`` check, so a session
without an observer allocates nothing and calls nothing — token streams
are bit-exact with or without observation (the observer never touches
RNG, device state, or the virtual clock).
"""
from __future__ import annotations

import bisect
import collections
import json
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.configs.base import SLOConfig
from repro.core.hooks import CoreHooks
from repro.runtime.request import Request

__all__ = [
    "percentile", "summarize", "MetricsRegistry", "SpanTracer",
    "EngineObserver", "Counter", "Gauge", "Histogram",
    "SLOBreach", "SLOMonitor",
    "TraceStats", "sharegpt_like", "longalign_like", "poisson_arrivals",
    "make_requests",
]


def percentile(values: Sequence[float], q: float) -> float:
    """The repo's ONE quantile: ``np.percentile`` (linear interpolation),
    NaN on empty — every benchmark and report quotes this."""
    values = np.asarray(values, float).reshape(-1)
    if values.size == 0:
        return float("nan")
    return float(np.percentile(values, q))


def summarize(values: Sequence[float],
              qs: Sequence[float] = (50, 95, 99)) -> Dict[str, float]:
    """{'p50': ..., 'p95': ..., 'p99': ...} over one sample list."""
    return {f"p{q:g}": percentile(values, q) for q in qs}


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

#: Default histogram buckets (seconds) — engine dispatch / latency scale.
LATENCY_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5)


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class _HistogramChild:
    __slots__ = ("bounds", "bucket_counts", "sum", "samples")

    def __init__(self, bounds: Tuple[float, ...]):
        self.bounds = bounds
        self.bucket_counts = [0] * (len(bounds) + 1)   # last = +Inf
        self.sum = 0.0
        self.samples: List[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        self.bucket_counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.samples.append(v)

    @property
    def count(self) -> int:
        return len(self.samples)

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)

    def reset(self) -> None:
        self.bucket_counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.samples.clear()


class _Metric:
    """One named metric family; per-label-set children on demand."""

    kind = "untyped"
    child_cls = _CounterChild

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...]):
        self.name = name
        self.help = help
        self.labelnames = labelnames
        self._children: Dict[Tuple[str, ...], object] = {}

    def _make_child(self):
        return self.child_cls()

    def labels(self, *values: str):
        """Get-or-create the child for one label-value tuple.  Call sites
        on hot paths cache the returned child — it is a plain slotted
        object, so the per-event cost is one attribute bump."""
        assert len(values) == len(self.labelnames), \
            (self.name, self.labelnames, values)
        key = tuple(str(v) for v in values)
        child = self._children.get(key)
        if child is None:
            child = self._children[key] = self._make_child()
        return child

    @property
    def children(self) -> Dict[Tuple[str, ...], object]:
        return self._children

    def _label_str(self, key: Tuple[str, ...],
                   extra: Tuple[Tuple[str, str], ...] = ()) -> str:
        pairs = [f'{n}="{v}"' for n, v in zip(self.labelnames, key)]
        pairs += [f'{n}="{v}"' for n, v in extra]
        return "{" + ",".join(pairs) + "}" if pairs else ""


class Counter(_Metric):
    kind = "counter"
    child_cls = _CounterChild

    def inc(self, v: float = 1.0) -> None:
        assert not self.labelnames, f"{self.name}: use .labels(...)"
        self.labels().inc(v)

    @property
    def value(self) -> float:
        return sum(c.value for c in self._children.values())

    def expose(self, out: List[str]) -> None:
        for key, c in self._children.items():
            out.append(f"{self.name}{self._label_str(key)} {c.value:g}")

    def snap(self):
        return [{"labels": dict(zip(self.labelnames, k)), "value": c.value}
                for k, c in self._children.items()]


class Gauge(_Metric):
    kind = "gauge"
    child_cls = _GaugeChild

    def set(self, v: float) -> None:
        assert not self.labelnames, f"{self.name}: use .labels(...)"
        self.labels().set(v)

    @property
    def value(self) -> float:
        assert not self.labelnames, f"{self.name}: use .labels(...)"
        return self.labels().value

    expose = Counter.expose
    snap = Counter.snap


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...],
                 buckets: Tuple[float, ...] = LATENCY_BUCKETS):
        super().__init__(name, help, labelnames)
        self.buckets = tuple(sorted(float(b) for b in buckets))

    def _make_child(self):
        return _HistogramChild(self.buckets)

    def observe(self, v: float) -> None:
        assert not self.labelnames, f"{self.name}: use .labels(...)"
        self.labels().observe(v)

    def all_samples(self) -> List[float]:
        """Every observation across label sets, in observation order per
        child — the benchmarks' shared sample source."""
        out: List[float] = []
        for c in self._children.values():
            out.extend(c.samples)
        return out

    def percentile(self, q: float) -> float:
        return percentile(self.all_samples(), q)

    @property
    def count(self) -> int:
        return sum(c.count for c in self._children.values())

    def reset(self) -> None:
        for c in self._children.values():
            c.reset()

    def expose(self, out: List[str]) -> None:
        for key, c in self._children.items():
            cum = 0
            for b, n in zip(self.buckets, c.bucket_counts):
                cum += n
                ls = self._label_str(key, (("le", f"{b:g}"),))
                out.append(f"{self.name}_bucket{ls} {cum}")
            ls = self._label_str(key, (("le", "+Inf"),))
            out.append(f"{self.name}_bucket{ls} {c.count}")
            out.append(f"{self.name}_sum{self._label_str(key)} {c.sum:g}")
            out.append(f"{self.name}_count{self._label_str(key)} {c.count}")

    def snap(self):
        return [{"labels": dict(zip(self.labelnames, k)),
                 "count": c.count, "sum": c.sum,
                 "p50": c.percentile(50), "p99": c.percentile(99)}
                for k, c in self._children.items()]


class MetricsRegistry:
    """Named metric families + a bounded structured-event log.

    ``prometheus_text()`` is the scrape format; ``snapshot()`` the
    JSON-able form (histogram snapshots carry exact p50/p99).  Metric
    creation is get-or-create so multiple wiring sites can share one
    family; kind/label mismatches are programming errors and assert.
    """

    def __init__(self, *, event_log_size: int = 64):
        self._metrics: Dict[str, _Metric] = {}
        self._events: Dict[str, collections.deque] = \
            collections.defaultdict(
                lambda: collections.deque(maxlen=event_log_size))
        self._events_dropped: collections.Counter = collections.Counter()

    def _get_or_create(self, cls, name: str, help: str,
                       labelnames: Sequence[str], **kw):
        m = self._metrics.get(name)
        if m is not None:
            assert m.kind == cls.kind and m.labelnames == tuple(labelnames), \
                (name, m.kind, m.labelnames)
            return m
        m = self._metrics[name] = cls(name, help, tuple(labelnames), **kw)
        return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Tuple[float, ...] = LATENCY_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    # -- structured events (report()'s last-N source) -------------------
    def log_event(self, kind: str, **fields) -> None:
        dq = self._events[kind]
        if dq.maxlen is not None and len(dq) == dq.maxlen:
            # bounded log about to silently truncate: count the drop so
            # recent_events() consumers can detect it (surfaced by
            # engine.report() and crosspool_events_dropped_total)
            self._events_dropped[kind] += 1
            self.counter("crosspool_events_dropped_total",
                         "structured events lost to the bounded log",
                         ("kind",)).labels(kind).inc()
        dq.append(dict(fields))

    def recent_events(self, kind: str, n: Optional[int] = None
                      ) -> List[Dict]:
        ev = list(self._events.get(kind, ()))
        return ev if n is None else ev[-n:]

    def events_dropped(self, kind: Optional[str] = None):
        """Per-kind count of events lost to the bounded log — the whole
        dict, or one kind's count."""
        if kind is not None:
            return self._events_dropped.get(kind, 0)
        return dict(self._events_dropped)

    # -- exposition ------------------------------------------------------
    def prometheus_text(self) -> str:
        out: List[str] = []
        for m in self._metrics.values():
            if m.help:
                out.append(f"# HELP {m.name} {m.help}")
            out.append(f"# TYPE {m.name} {m.kind}")
            m.expose(out)
        return "\n".join(out) + "\n"

    def snapshot(self) -> Dict[str, Dict]:
        return {m.name: {"kind": m.kind, "help": m.help, "values": m.snap()}
                for m in self._metrics.values()}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.prometheus_text())


# ---------------------------------------------------------------------------
# span tracer (Chrome trace-event JSON, Perfetto-loadable)
# ---------------------------------------------------------------------------

class SpanTracer:
    """Records begin/end/instant/complete events onto named tracks.

    A track is a Perfetto "thread": first use allocates a tid and emits
    the ``thread_name`` metadata event.  Timestamps come from the
    injected ``clock`` (monotonic seconds; default ``time.perf_counter``)
    rebased to the tracer's construction, so tests inject a fake
    deterministic clock and real runs get wall time.  B/E events nest
    per track — callers keep per-track begin/end balanced (the engine's
    phase and request lifecycles are strictly bracketed).
    """

    PID = 1

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self.events: List[Dict] = []
        self._tids: Dict[str, int] = {}

    def now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = self._tids[track] = len(self._tids) + 1
            self.events.append({
                "name": "thread_name", "ph": "M", "pid": self.PID,
                "tid": tid, "args": {"name": track}})
        return tid

    def begin(self, track: str, name: str, cat: str = "engine",
              **args) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "B", "ts": self.now_us(),
            "pid": self.PID, "tid": self._tid(track), "args": args})

    def end(self, track: str, name: str, cat: str = "engine",
            **args) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "E", "ts": self.now_us(),
            "pid": self.PID, "tid": self._tid(track), "args": args})

    def complete(self, track: str, name: str, dur_s: float,
                 cat: str = "engine", **args) -> None:
        """An X event ENDING now whose duration was measured host-side.
        The start clamps at the trace origin: a duration can exceed the
        tracer-clock elapsed time (first-compile slices, fake clocks)
        and Perfetto rejects negative timestamps."""
        dur_us = max(float(dur_s), 0.0) * 1e6
        self.events.append({
            "name": name, "cat": cat, "ph": "X",
            "ts": max(self.now_us() - dur_us, 0.0), "dur": dur_us,
            "pid": self.PID, "tid": self._tid(track), "args": args})

    def instant(self, track: str, name: str, cat: str = "engine",
                **args) -> None:
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self.now_us(), "pid": self.PID,
            "tid": self._tid(track), "args": args})

    def counter(self, track: str, name: str, **values: float) -> None:
        """A Perfetto counter sample (ph "C"): one multi-series counter
        track per (track tid, name); Perfetto renders the series stacked,
        which is exactly the holder-class partition view the pool
        timelines want."""
        self.events.append({
            "name": name, "ph": "C", "ts": self.now_us(),
            "pid": self.PID, "tid": self._tid(track),
            "args": {k: float(v) for k, v in values.items()}})

    # -- export ----------------------------------------------------------
    def chrome_trace(self) -> Dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)

    # -- test helpers ----------------------------------------------------
    def track_events(self, track: str) -> List[Dict]:
        tid = self._tids.get(track)
        if tid is None:
            return []
        return [e for e in self.events
                if e.get("tid") == tid and e["ph"] != "M"]

    def span_names(self, track: str) -> List[Tuple[str, str]]:
        """[(ph, name), ...] on one track — the deterministic sequence
        the tracer tests assert against."""
        return [(e["ph"], e["name"]) for e in self.track_events(track)]


# ---------------------------------------------------------------------------
# the observer facade
# ---------------------------------------------------------------------------

class EngineObserver(CoreHooks):
    """Metrics + tracer, wired through the engine AND the core hooks.

    One instance per engine.  The engine calls the lifecycle methods
    below from its step loop (each site guarded by ``observer is not
    None``); the pools call the :class:`CoreHooks` overrides.  Latency
    observations (TTFT/TBT/dispatch seconds) use ENGINE virtual time so
    they match ``EngineStats`` exactly; trace timestamps use the
    tracer's own clock so Perfetto shows host wall time.
    """

    ENGINE_TRACK = "engine/step-loop"

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 registry: Optional[MetricsRegistry] = None):
        self.metrics = registry if registry is not None else MetricsRegistry()
        self.tracer = SpanTracer(clock=clock)
        m = self.metrics
        # admission front door
        self.admission_total = m.counter(
            "crosspool_admission_total",
            "front-door verdicts", ("model", "outcome"))
        self._adm_blocked = m.counter(
            "crosspool_admission_blocked_total",
            "queued verdicts by blocking resource", ("blocker",))
        self._adm_wait = m.histogram(
            "crosspool_admission_wait_seconds",
            "queue wait of drained requests", ("model",))
        # KV pool
        self._kv_mapped = m.gauge("crosspool_kv_pages_mapped",
                                  "device pages currently mapped")
        self._kv_budget = m.gauge("crosspool_kv_page_budget",
                                  "live KV pool size (pages)")
        self._kv_swapped = m.gauge("crosspool_kv_pages_swapped",
                                   "pages held in the host swap tier")
        self._kv_occ = m.gauge("crosspool_kv_occupancy",
                               "mapped / budget")
        swap = m.counter("crosspool_kv_swap_pages_total",
                         "pages moved across the swap tier", ("dir",))
        self._swap_out = swap.labels("out")
        self._swap_in = swap.labels("in")
        self._kv_reserved = m.counter(
            "crosspool_kv_reserved_pages_total",
            "pages pre-mapped for decode blocks")
        self._kv_trimmed = m.counter(
            "crosspool_kv_trimmed_pages_total",
            "unused reserved pages returned at commit")
        self._pool_resizes = m.counter(
            "crosspool_pool_resizes_total",
            "live pool resizes", ("pool",))
        # weights arena
        self._arena_resident = m.gauge("crosspool_arena_slabs_resident",
                                       "arena slabs mapped")
        self._arena_budget = m.gauge("crosspool_arena_slot_budget",
                                     "live arena size (slabs)")
        self._arena_occ = m.gauge("crosspool_arena_occupancy",
                                  "resident / budget")
        self._arena_act = m.counter("crosspool_arena_activations_total",
                                    "cold-model activations", ("model",))
        self._arena_evict = m.counter("crosspool_arena_evictions_total",
                                      "LRU evictions", ("model",))
        self._arena_upload = m.counter(
            "crosspool_arena_uploaded_slabs_total",
            "slabs uploaded host->device", ("model",))
        # prefix cache (DESIGN.md §11)
        self._cache_lookups = m.counter(
            "crosspool_prefix_cache_lookups_total",
            "cache-eligible admissions by outcome", ("model", "outcome"))
        self._cache_hit_tokens = m.counter(
            "crosspool_prefix_cache_hit_tokens_total",
            "prompt tokens served from the radix tree", ("model",))
        self._cache_evicted = m.counter(
            "crosspool_prefix_cache_evicted_pages_total",
            "device pages shed/evicted from the tree")
        self._cache_faulted = m.counter(
            "crosspool_prefix_cache_faulted_pages_total",
            "shed pages faulted back on a second-chance hit")
        # rebalancer
        self._rebalance = m.counter("crosspool_rebalance_total",
                                    "applied boundary moves", ("reason",))
        self._rebalance_swap = m.counter(
            "crosspool_rebalance_swapped_pages_total",
            "pages pushed to the swap tier by shrinks")
        self._rebalance_evict = m.counter(
            "crosspool_rebalance_evicted_models_total",
            "models evicted by arena shrinks")
        # request lifecycle + latency (windowed: reset_window clears)
        self._queue_depth = m.gauge("crosspool_queue_depth",
                                    "front-door queued requests")
        self._waiting = m.gauge("crosspool_waiting_requests",
                                "admitted requests without a batch slot")
        self.requests_total = m.counter("crosspool_requests_total",
                                        "terminal outcomes",
                                        ("model", "outcome"))
        self.tokens_total = m.counter("crosspool_tokens_total",
                                      "tokens emitted", ("model",))
        self.ttft = m.histogram("crosspool_ttft_seconds",
                                "time to first token", ("model",))
        self.tbt = m.histogram("crosspool_tbt_seconds",
                               "time between tokens", ("model",))
        self.prefill_seconds = m.histogram(
            "crosspool_prefill_dispatch_seconds",
            "wall time of one prefill pass", ("model",))
        self.decode_seconds = m.histogram(
            "crosspool_decode_dispatch_seconds",
            "wall time of one decode dispatch", ("model",))
        self.prefill_batch = m.histogram(
            "crosspool_prefill_batch_size",
            "rows per executed prefill pass",
            buckets=(1, 2, 4, 8, 16))
        self._batcher_deferrals = m.counter(
            "crosspool_batcher_deferrals_total",
            "requests kept waiting by the batcher", ("model", "reason"))
        # hot-path per-model child caches
        self._tok_children: Dict[str, _CounterChild] = {}
        self._tbt_children: Dict[str, _HistogramChild] = {}
        # request-track bookkeeping: rid -> (track, open span name | None)
        self._req_spans: Dict[int, Tuple[str, Optional[str]]] = {}
        self._steps = 0

    # ------------------------------------------------------------------
    # engine step loop
    # ------------------------------------------------------------------
    def step_begin(self, now: float) -> None:
        self._steps += 1
        self.tracer.begin(self.ENGINE_TRACK, "step",
                          step=self._steps, engine_time=now)

    def step_end(self) -> None:
        self.tracer.end(self.ENGINE_TRACK, "step")

    def phase_begin(self, name: str) -> None:
        self.tracer.begin(self.ENGINE_TRACK, name, cat="phase")

    def phase_end(self, name: str) -> None:
        self.tracer.end(self.ENGINE_TRACK, name, cat="phase")

    # ------------------------------------------------------------------
    # request lifecycle (engine virtual time in args; tracer clock in ts)
    # ------------------------------------------------------------------
    def _track(self, req) -> str:
        return f"req/{req.model}#{req.request_id}"

    def _open(self, req, span: str, **args) -> None:
        track = self._track(req)
        self._req_spans[req.request_id] = (track, span)
        self.tracer.begin(track, span, cat="request", **args)

    def _close(self, req) -> None:
        entry = self._req_spans.get(req.request_id)
        if entry is None or entry[1] is None:
            return
        track, span = entry
        self.tracer.end(track, span, cat="request")
        self._req_spans[req.request_id] = (track, None)

    def request_submitted(self, req, outcome: str) -> None:
        track = self._track(req)
        self.tracer.instant(track, "submit", cat="request",
                            outcome=outcome, prompt=req.prompt_tokens,
                            max_new=req.max_new_tokens)
        if outcome == "admitted":
            self._open(req, "admitted")
        elif outcome == "queued":
            self._open(req, "queued")
        else:
            self._req_spans[req.request_id] = (track, None)
            self.requests_total.labels(req.model, "rejected").inc()

    def request_admitted(self, req) -> None:
        """A queued request drained at a later step boundary."""
        self._close(req)
        self._open(req, "admitted")

    def prefill(self, model: str, batch_size: int, dt: float) -> None:
        self.prefill_seconds.labels(model).observe(dt)
        self.prefill_batch.labels().observe(batch_size)
        self.tracer.complete(f"model/{model}", "prefill", dt,
                             cat="dispatch", batch=batch_size)

    def first_token(self, req, ttft: float) -> None:
        """Prefill committed: the request's admitted span becomes its
        decode span, and the TTFT sample lands (engine virtual time —
        identical to the ``EngineStats.ttft`` entry)."""
        self.ttft.labels(req.model).observe(ttft)
        self._tok_child(req.model).inc()
        self._close(req)
        self._open(req, "decode", ttft=ttft)

    def _tok_child(self, model: str) -> _CounterChild:
        c = self._tok_children.get(model)
        if c is None:
            c = self._tok_children[model] = self.tokens_total.labels(model)
        return c

    def _tbt_child(self, model: str) -> _HistogramChild:
        c = self._tbt_children.get(model)
        if c is None:
            c = self._tbt_children[model] = self.tbt.labels(model)
        return c

    def token(self, req, gap: float) -> None:
        """One decode token: TBT gap in engine virtual time (matches the
        ``tbt_samples()`` pairwise diff exactly)."""
        self._tbt_child(req.model).observe(gap)
        self._tok_child(req.model).inc()

    def decode_block(self, req, n_tokens: int, dt: float) -> None:
        """One committed K-block for one request (an X slice inside the
        request's decode span)."""
        self.tracer.complete(self._track(req), "decode_block", dt,
                             cat="request", tokens=n_tokens)

    def decode_dispatch(self, model: str, dt: float) -> None:
        self.decode_seconds.labels(model).observe(dt)
        self.tracer.complete(f"model/{model}", "decode", dt, cat="dispatch")

    def request_finished(self, req) -> None:
        self._close(req)
        self.tracer.instant(self._track(req), "finished", cat="request",
                            tokens=req.generated)
        self.requests_total.labels(req.model, "finished").inc()

    def request_cancelled(self, req) -> None:
        self._close(req)
        self.tracer.instant(self._track(req), "cancelled", cat="request",
                            tokens=req.generated)
        self.requests_total.labels(req.model, "cancelled").inc()

    def batcher_deferral(self, model: str, reason: str) -> None:
        self._batcher_deferrals.labels(model, reason).inc()

    # ------------------------------------------------------------------
    # per-step pool sampling (gauges; runs BEFORE DemandTelemetry.observe
    # so gauge-fed EWMAs see this step's values)
    # ------------------------------------------------------------------
    def sample(self, virt, arena, admission, waiting: int) -> None:
        self._kv_mapped.set(virt.mapped_pages)
        self._kv_budget.set(virt.page_budget)
        self._kv_swapped.set(getattr(virt, "swapped_now", 0))
        self._kv_occ.set(virt.mapped_pages / max(virt.page_budget, 1))
        if arena is not None:
            self._arena_resident.set(arena.resident_slabs)
            self._arena_budget.set(arena.slot_budget)
            self._arena_occ.set(
                arena.resident_slabs / max(arena.slot_budget, 1))
        self._queue_depth.set(admission.queued_count())
        self._waiting.set(waiting)

    def pool_counters(self, snap: Dict) -> None:
        """Per-step Perfetto counter tracks from one pool snapshot
        (``runtime.flightrec.pool_snapshot``): KV pages by holder class,
        slabs by model, swap-tier depth, cache tree pages — the visual
        attribution layer for elastic decisions (DESIGN.md §13)."""
        kv = snap["kv"]
        self.tracer.counter("pool/kv", "kv_pages",
                            free=kv["free_pages"],
                            request=kv["request_pages"],
                            tree=kv["tree_pages"])
        self.tracer.counter("pool/kv", "swap_tier",
                            swapped=kv["swapped_now"])
        arena = snap.get("arena")
        if arena is not None:
            series = {"free": float(arena["free_slabs"])}
            series.update(arena["resident"])
            self.tracer.counter("pool/arena", "slabs", **series)
        cache = snap.get("cache")
        if cache is not None:
            self.tracer.counter("pool/cache", "tree_pages",
                                held=cache["device_pages_held"])

    # gauge accessors for DemandTelemetry's gauge-fed EWMAs
    def kv_occupancy(self) -> float:
        return self._kv_occ.value

    def slab_occupancy(self) -> float:
        return self._arena_occ.value

    def queue_depth(self) -> float:
        return self._queue_depth.value

    # ------------------------------------------------------------------
    # windowing
    # ------------------------------------------------------------------
    def reset_window(self) -> None:
        """Clear the WINDOWED histograms (latency/dispatch/batch-size) on
        ``engine.reset_stats()``; lifetime counters and gauges keep
        accumulating, mirroring the admission controller's counters."""
        for h in (self.ttft, self.tbt, self.prefill_seconds,
                  self.decode_seconds, self.prefill_batch):
            h.reset()

    # ------------------------------------------------------------------
    # CoreHooks overrides (called by the pools)
    # ------------------------------------------------------------------
    def kv_swap_out(self, pages: int) -> None:
        self._swap_out.inc(pages)
        self.tracer.instant("pool/kv", "swap_out", cat="pool", pages=pages)

    def kv_swap_in(self, pages: int) -> None:
        self._swap_in.inc(pages)
        self.tracer.instant("pool/kv", "swap_in", cat="pool", pages=pages)

    def kv_reserved(self, pages: int) -> None:
        self._kv_reserved.inc(pages)

    def kv_trimmed(self, pages: int) -> None:
        self._kv_trimmed.inc(pages)

    def kv_resize(self, old_pages: int, new_pages: int,
                  swapped_out: int, moved: int) -> None:
        self._pool_resizes.labels("kv").inc()
        self._kv_budget.set(new_pages)
        self.tracer.instant("pool/kv", "resize", cat="pool",
                            old=old_pages, new=new_pages,
                            swapped_out=swapped_out, moved=moved)

    def arena_activate(self, model: str, slabs: int) -> None:
        self._arena_act.labels(model).inc()
        self.tracer.instant("pool/arena", "activate", cat="pool",
                            model=model, slabs=slabs)

    def arena_evict(self, model: str, slabs: int) -> None:
        self._arena_evict.labels(model).inc()
        self.tracer.instant("pool/arena", "evict", cat="pool",
                            model=model, slabs=slabs)

    def arena_upload(self, model: str, slabs: int) -> None:
        self._arena_upload.labels(model).inc(slabs)

    def arena_resize(self, old_slots: int, new_slots: int,
                     evicted: int, moved: int) -> None:
        self._pool_resizes.labels("arena").inc()
        self._arena_budget.set(new_slots)
        self.tracer.instant("pool/arena", "resize", cat="pool",
                            old=old_slots, new=new_slots,
                            evicted=evicted, moved=moved)

    def admission(self, model: str, outcome: str, blocker: str) -> None:
        self.admission_total.labels(model, outcome).inc()
        if blocker:
            self._adm_blocked.labels(blocker).inc()

    def admission_wait(self, model: str, seconds: float) -> None:
        self._adm_wait.labels(model).observe(seconds)

    def cache_hit(self, model: str, tokens: int) -> None:
        self._cache_lookups.labels(model, "hit").inc()
        self._cache_hit_tokens.labels(model).inc(tokens)
        self.tracer.instant("pool/cache", "hit", cat="cache",
                            model=model, tokens=tokens)

    def cache_miss(self, model: str) -> None:
        self._cache_lookups.labels(model, "miss").inc()

    def cache_evict(self, pages: int) -> None:
        self._cache_evicted.inc(pages)
        self.tracer.instant("pool/cache", "evict", cat="cache", pages=pages)

    def cache_fault(self, pages: int) -> None:
        self._cache_faulted.inc(pages)
        self.tracer.instant("pool/cache", "fault", cat="cache", pages=pages)

    def rebalance(self, decision) -> None:
        self._rebalance.labels(decision.reason).inc()
        self._rebalance_swap.inc(decision.swapped_out)
        self._rebalance_evict.inc(decision.evicted_models)
        self.tracer.instant(self.ENGINE_TRACK, "rebalance", cat="elastic",
                            reason=decision.reason,
                            pages=(decision.old_page_budget,
                                   decision.new_page_budget),
                            slabs=(decision.old_slot_budget,
                                   decision.new_slot_budget))

    def slo_breach(self, breach) -> None:
        """Breach instant on the engine track (the counter and the
        structured event are bumped by :class:`SLOMonitor` itself, which
        shares this observer's registry — bumping here too would double
        count)."""
        self.tracer.instant(self.ENGINE_TRACK, "slo_breach", cat="slo",
                            model=breach.model, metric=breach.metric,
                            long_burn=breach.long_burn,
                            short_burn=breach.short_burn)


# ---------------------------------------------------------------------------
# SLO engine: multi-rate burn-rate evaluation (DESIGN.md §13)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SLOBreach:
    """One burn-rate breach edge for one (model, metric) objective."""

    model: str
    metric: str            # "ttft" | "tbt" | "queue_wait"
    threshold_s: float
    target: float
    long_burn: float       # budget-burn multiple over the long window
    short_burn: float      # ... over the short (fast) window
    window_value: float    # target-quantile of the long window (seconds)
    now: float             # engine virtual time of the evaluation


# (SLObjective field, metric key) pairs the monitor tracks
_SLO_METRICS = (("ttft_ms", "ttft"),
                ("tbt_p99_ms", "tbt"),
                ("queue_wait_ms", "queue_wait"))


def _bad_fraction(values: Sequence[float], threshold_s: float) -> float:
    """Fraction of samples STRICTLY over the threshold: a sample exactly
    at the objective is within SLO."""
    if not values:
        return 0.0
    return sum(1 for v in values if v > threshold_s) / len(values)


class SLOMonitor:
    """Windowed multi-rate burn-rate evaluation over latency samples.

    The engine feeds raw samples (``note``) in virtual time — the same
    values the registry histograms receive, so the monitor's windowed
    quantiles agree with ``np.percentile`` over the raw histogram
    samples exactly.  ``evaluate(now)`` prunes each (model, metric)
    window and fires an :class:`SLOBreach` on the breaching EDGE: both
    the long and the short window must burn the error budget faster
    than ``burn_rate_threshold`` (each with at least one sample), and
    the pair re-arms only after the condition clears.  Breaches land in
    the shared registry (``crosspool_slo_breaches_total`` + an
    ``slo_breach`` structured event) here, and are fanned to the hook
    sinks (observer trace, flight recorder) by the engine.

    Evaluation is pure arithmetic over deques of ``(time, value)`` —
    deterministic given the session's input stream, so a replayed
    session reproduces the exact breach sequence.
    """

    def __init__(self, cfg: SLOConfig,
                 registry: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._breaches = self.metrics.counter(
            "crosspool_slo_breaches_total",
            "multi-rate burn-rate breach edges", ("model", "metric"))
        # (model, metric) -> (threshold_s, target)
        self._objectives: Dict[Tuple[str, str], Tuple[float, float]] = {}
        for model, obj in cfg.objectives.items():
            for attr, metric in _SLO_METRICS:
                thr_ms = getattr(obj, attr)
                if thr_ms is not None:
                    self._objectives[(model, metric)] = (
                        float(thr_ms) / 1e3, float(obj.target))
        self._samples: Dict[Tuple[str, str], collections.deque] = {
            key: collections.deque() for key in self._objectives}
        self._active: Set[Tuple[str, str]] = set()
        self.evaluations = 0

    def note(self, metric: str, model: str, value_s: float,
             now: float) -> None:
        """One latency sample in engine virtual time; untracked
        (model, metric) pairs are dropped at the cost of one dict get."""
        q = self._samples.get((model, metric))
        if q is not None:
            q.append((float(now), float(value_s)))

    def _burns(self, key, now: float):
        """(long_burn, short_burn, long_values, short_n) after pruning
        the window; ``None`` when the long window is empty."""
        thr, target = self._objectives[key]
        q = self._samples[key]
        horizon = now - self.cfg.window_s
        while q and q[0][0] < horizon:
            q.popleft()
        if not q:
            return None
        budget = max(1.0 - target, 1e-9)
        long_vals = [v for _, v in q]
        fast_horizon = now - self.cfg.short_window_s
        short_vals = [v for t, v in q if t >= fast_horizon]
        long_burn = _bad_fraction(long_vals, thr) / budget
        short_burn = _bad_fraction(short_vals, thr) / budget
        return long_burn, short_burn, long_vals, len(short_vals)

    def evaluate(self, now: float) -> List[SLOBreach]:
        """Edge-triggered breach scan; called by the engine once per
        step (and callable directly in tests)."""
        self.evaluations += 1
        out: List[SLOBreach] = []
        for key, (thr, target) in self._objectives.items():
            burns = self._burns(key, now)
            if burns is None:
                self._active.discard(key)
                continue
            long_burn, short_burn, long_vals, short_n = burns
            breaching = (short_n > 0
                         and long_burn > self.cfg.burn_rate_threshold
                         and short_burn > self.cfg.burn_rate_threshold)
            if not breaching:
                self._active.discard(key)
                continue
            if key in self._active:
                continue
            self._active.add(key)
            model, metric = key
            breach = SLOBreach(
                model=model, metric=metric, threshold_s=thr, target=target,
                long_burn=long_burn, short_burn=short_burn,
                window_value=percentile(long_vals, target * 100.0), now=now)
            self._breaches.labels(model, metric).inc()
            self.metrics.log_event(
                "slo_breach", model=model, metric=metric,
                threshold_ms=thr * 1e3, long_burn=long_burn,
                short_burn=short_burn,
                window_value_ms=breach.window_value * 1e3, time=now)
            out.append(breach)
        return out

    def status(self, now: float) -> Dict[Tuple[str, str], Dict[str, float]]:
        """Read-only window view per objective (no pruning, no edges):
        sample count, bad fraction, burn rates, and the target-quantile
        of the long window — the reporting surface."""
        out = {}
        for key, (thr, target) in self._objectives.items():
            q = self._samples[key]
            long_vals = [v for t, v in q if t >= now - self.cfg.window_s]
            short_vals = [v for t, v in q
                          if t >= now - self.cfg.short_window_s]
            budget = max(1.0 - target, 1e-9)
            out[key] = {
                "n": len(long_vals),
                "threshold_s": thr,
                "target": target,
                "bad_fraction": _bad_fraction(long_vals, thr),
                "long_burn": _bad_fraction(long_vals, thr) / budget,
                "short_burn": _bad_fraction(short_vals, thr) / budget,
                "window_value": (percentile(long_vals, target * 100.0)
                                 if long_vals else float("nan")),
                "breaching": key in self._active,
            }
        return out

    def breach_count(self) -> int:
        return int(self._breaches.value)

    def reset(self) -> None:
        """Drop every window and re-arm every edge — wired to
        ``engine.reset_stats()`` so windowed SLO state matches the
        windowed histograms."""
        for q in self._samples.values():
            q.clear()
        self._active.clear()

    def report_line(self, now: float) -> str:
        n_breaching = sum(1 for key in self._objectives
                          if key in self._active)
        return (f"slo: {len(self._objectives)} objectives, "
                f"{self.breach_count()} breach edges, "
                f"{n_breaching} currently breaching")


# ---------------------------------------------------------------------------
# workload trace synthesis (formerly runtime/trace.py)
# ---------------------------------------------------------------------------
#
# Offline datasets are unavailable in this container, so we synthesize
# traces whose marginal token statistics match the published dataset
# summaries:
#
# * ShareGPT (Vicuna conversations): prompt/output token counts are
#   log-normal-ish with medians of a few hundred tokens and a heavy tail
#   (median prompt ~220, median output ~180, p99 ~2k) — the "balanced
#   input/output" workload of paper §5.1.
# * LongAlign-10k: context lengths spread 1k..64k with substantial mass
#   beyond 8k (the long-context scalability workload of Fig. 6), outputs
#   a few hundred tokens.
#
# Arrivals are Poisson at a configurable per-model RPS (paper: 0.2-1.0).


@dataclass(frozen=True)
class TraceStats:
    prompt_tokens: np.ndarray
    output_tokens: np.ndarray


def sharegpt_like(n: int, rng: np.random.Generator,
                  clip: int = 4096) -> TraceStats:
    prompt = np.clip(rng.lognormal(mean=5.4, sigma=0.9, size=n), 8,
                     clip).astype(int)
    output = np.clip(rng.lognormal(mean=5.2, sigma=0.8, size=n), 8,
                     clip).astype(int)
    return TraceStats(prompt, output)


def longalign_like(n: int, rng: np.random.Generator,
                   max_ctx: int = 65536) -> TraceStats:
    """Context lengths across 1k..64k bins with heavy long-tail mass."""
    bins = np.array([1024, 2048, 4096, 8192, 16384, 32768, 65536])
    weights = np.array([0.18, 0.2, 0.2, 0.16, 0.12, 0.09, 0.05])
    hi = rng.choice(bins, size=n, p=weights / weights.sum())
    prompt = (hi * rng.uniform(0.55, 1.0, size=n)).astype(int)
    prompt = np.minimum(prompt, max_ctx - 512)
    output = np.clip(rng.lognormal(5.0, 0.7, size=n), 16, 512).astype(int)
    return TraceStats(prompt, output)


def poisson_arrivals(rate: float, horizon_s: float,
                     rng: np.random.Generator) -> np.ndarray:
    n = rng.poisson(rate * horizon_s)
    return np.sort(rng.uniform(0.0, horizon_s, n))


def make_requests(models: List[str], *, rps_per_model: float,
                  horizon_s: float, kind: str = "sharegpt",
                  seed: int = 0, scale_tokens: float = 1.0,
                  max_new_cap: Optional[int] = None) -> List[Request]:
    """Interleaved multi-model request stream sorted by arrival time.

    ``scale_tokens`` shrinks token counts for CPU-scale engine runs while
    preserving the distribution shape.
    """
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    rid = 0
    for model in models:
        arrivals = poisson_arrivals(rps_per_model, horizon_s, rng)
        stats = (sharegpt_like(len(arrivals), rng) if kind == "sharegpt"
                 else longalign_like(len(arrivals), rng))
        for t, p, o in zip(arrivals, stats.prompt_tokens,
                           stats.output_tokens):
            p = max(int(p * scale_tokens), 1)
            o = max(int(o * scale_tokens), 1)
            if max_new_cap:
                o = min(o, max_new_cap)
            reqs.append(Request(rid, model, p, o, float(t)))
            rid += 1
    reqs.sort(key=lambda r: r.arrival_time)
    return reqs
