"""Cross-layer integration tests.

* model decode through the Pallas kernel path (impl="paged"/"flash")
  matches the pure-XLA path;
* engine serving with the paged kernel exercised end-to-end;
* planner -> virtualizer -> admission closed loop under a generated trace
  (hypothesis): budget never exceeded, no leaks, admitted work completes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import PAPER_COLOC_SET, get_smoke_config
from repro.core.admission import AdmissionController, PendingRequest
from repro.core.planner import WorkloadSpec, plan_pool
from repro.core.virtualizer import KVVirtualizer
from repro.models import build_model


class TestKernelModelPath:
    @pytest.mark.parametrize("arch", ["qwen3-14b", "moonshot-v1-16b-a3b"])
    def test_decode_paged_kernel_matches_xla(self, arch):
        """gqa_decode(impl='paged') routes through the Pallas contiguous
        decode kernel (interpret mode) and must match the XLA softmax."""
        from repro.kernels import ops as kops
        kops.set_default_impl("pallas")
        try:
            cfg = get_smoke_config(arch).replace(dtype="float32")
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            B, seq = 2, 8
            tokens = jnp.zeros((B, seq), jnp.int32)
            cache = model.init_cache(B, 16)
            _, cache = model.prefill(params, tokens, cache)
            tok = jnp.zeros((B,), jnp.int32)
            want, _ = model.decode_step(params, tok, cache, jnp.int32(seq),
                                        impl="xla")
            got, _ = model.decode_step(params, tok, cache, jnp.int32(seq),
                                       impl="paged")
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-4, atol=2e-4)
        finally:
            kops.set_default_impl("xla")

    def test_forward_flash_kernel_matches_xla(self):
        from repro.kernels import ops as kops
        kops.set_default_impl("pallas")
        try:
            cfg = get_smoke_config("qwen3-14b").replace(dtype="float32")
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(1))
            tokens = jnp.asarray(
                np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 16)),
                jnp.int32)
            want, _ = model.forward(params, tokens, impl="xla")
            got, _ = model.forward(params, tokens, impl="flash")
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-3, atol=2e-3)
        finally:
            kops.set_default_impl("xla")


class TestPlannerVirtualizerLoop:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), rate=st.floats(0.5, 4.0))
    def test_closed_loop_invariants(self, seed, rate):
        """Plan a pool from sampled workload, then replay a trace through
        admission: mapped pages never exceed the budget; releases restore
        the free list exactly."""
        models = {n: get_smoke_config(n) for n in PAPER_COLOC_SET}
        rng = np.random.default_rng(seed)
        specs = [WorkloadSpec(model=c, arrival_rate=rate,
                              prompt_tokens=rng.integers(8, 128, 100),
                              output_tokens=rng.integers(4, 64, 100),
                              decode_time=rng.uniform(0.1, 2.0, 100))
                 for c in models.values()]
        plan = plan_pool(specs, page_bytes=4096, quantile=0.95,
                         horizon_s=60.0, n_trials=1, seed=seed)
        budget = max(plan.pool_page_budget, 8)
        virt = KVVirtualizer(models, page_budget=budget, page_bytes=4096,
                             allocate_device_pool=False)
        ac = AdmissionController(virt, max_queue_per_model=4)

        names = list(models)
        live = []
        for i in range(40):
            name = names[int(rng.integers(0, len(names)))]
            outcome = ac.offer(PendingRequest(
                i, name, int(rng.integers(4, 256)), 0, float(i)), float(i))
            assert virt.mapped_pages <= budget
            if outcome == "admitted":
                live.append(i)
            # randomly finish someone
            if live and rng.random() < 0.5:
                rid = live.pop(int(rng.integers(0, len(live))))
                virt.release_request(rid)
                for p in ac.drain(float(i)):
                    live.append(p.request_id)
            assert virt.mapped_pages <= budget
        for rid in live:
            virt.release_request(rid)
        assert virt.free_pages == budget

    def test_planner_budget_covers_sampled_demand(self):
        """The P99 budget should admit the median concurrent load without
        queueing in a replay of the same distribution."""
        models = {n: get_smoke_config(n) for n in PAPER_COLOC_SET}
        rng = np.random.default_rng(3)
        specs = [WorkloadSpec(model=c, arrival_rate=1.0,
                              prompt_tokens=rng.integers(16, 64, 50),
                              output_tokens=rng.integers(4, 16, 50),
                              decode_time=rng.uniform(0.2, 1.0, 50))
                 for c in models.values()]
        plan = plan_pool(specs, page_bytes=4096, quantile=0.99,
                         horizon_s=120.0, n_trials=2)
        virt = KVVirtualizer(models, page_budget=plan.pool_page_budget,
                             page_bytes=4096, allocate_device_pool=False)
        # typical instantaneous concurrency ~ rate * residence = 1
        ok = 0
        for i, (name, cfg) in enumerate(models.items()):
            if virt.can_admit(name, 64, 16):
                virt.register_request(i, name, 64)
                ok += 1
        assert ok == len(models)
