"""Static-analysis subsystem: checkable invariants for the pool layers.

Three legs (DESIGN.md §12):

  * ``repro.analysis.lint`` — AST-based repo-specific lint rules
    (CP001..CP007) codifying the DESIGN.md contracts; CLI:
    ``python -m repro.analysis.lint``.
  * ``repro.analysis.jaxpr_audit`` — traces the fused step/prefill
    callables and structurally verifies closure/donation/transfer/
    dispatch invariants (CPA01..CPA04); CLI:
    ``python -m repro.analysis.jaxpr_audit``.
  * ``repro.analysis.sanitizer`` — a runtime shadow-sanitizer
    (``PoolSanitizer``) mirroring every page/slab/refcount/swap/reserve
    transition and raising on violations (SAN01..SAN07).
"""
__all__ = ["Finding", "lint_paths", "lint_source", "PoolSanitizer",
           "PoolSanitizerError"]

_HOMES = {"Finding": "lint", "lint_paths": "lint", "lint_source": "lint",
          "PoolSanitizer": "sanitizer", "PoolSanitizerError": "sanitizer"}


def __getattr__(name):
    # lazy re-exports: ``python -m repro.analysis.lint`` must not trigger
    # an eager sibling import (runpy warns), and importing the sanitizer
    # must not pull the AST linter into the engine's hot path
    if name in _HOMES:
        import importlib
        mod = importlib.import_module(f"repro.analysis.{_HOMES[name]}")
        return getattr(mod, name)
    raise AttributeError(name)
