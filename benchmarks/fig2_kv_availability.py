"""Fig. 2: KV-cache capacity visible to ONE request, 4 GPUs.

Monolithic placement: MHA(4 heads)=1, GQA(2)=1/2, MQA(1)=1/4 of the total.
Disaggregated (CrossPool) placement: 1 for all attention algorithms.
"""
from __future__ import annotations

from repro.core.placement import kv_availability_fraction


def run(csv=print) -> dict:
    cases = [("mha", 4), ("gqa", 2), ("mqa", 1)]
    out = {}
    for name, heads in cases:
        mono = kv_availability_fraction(heads, 4, disaggregated=False)
        xp = kv_availability_fraction(heads, 4, disaggregated=True)
        csv(f"fig2,{name}_monolithic_fraction,{mono:.3f}")
        csv(f"fig2,{name}_crosspool_fraction,{xp:.3f}")
        out[name] = (mono, xp)
    assert out["mha"][0] == 1.0 and out["gqa"][0] == 0.5 \
        and out["mqa"][0] == 0.25
    assert all(v[1] == 1.0 for v in out.values())
    return out


if __name__ == "__main__":
    run()
