"""Control lowering: host-driven vs device-resident decode step execution.

The paper's §3.3 persistent kernels keep the per-layer control loop on the
GPU.  The TPU/XLA analogue (DESIGN.md §2): a *fused* decode step — one XLA
program that scans over layers — is dispatched ONCE per token per batch;
layer transitions, the attention->FFN ping-pong and its collectives all
live inside the compiled program, exactly like a persistent kernel that
dispatches captured subgraphs.  The host keeps only admission and page
mapping, the paper's split.

``HostDrivenStep`` is the ablation baseline (Table 3 row 1): every layer
issues separate attention-stage and FFN-stage dispatches with host Python
in between — 2L+2 dispatches/token instead of 1, plus 2L inter-pool
device transfers driven from the host.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import split_exec
from repro.core.pools import PooledModel, transfer
from repro.models import build_model


class HostDrivenStep:
    """Per-layer host dispatch across the two pools (lowering OFF)."""

    def __init__(self, pooled: PooledModel, kv_device, w_device):
        self.pooled = pooled
        self.kv_device = kv_device
        self.w_device = w_device
        fns = pooled.stage_fns
        # execution placement follows the committed pool params: attention
        # stages run where kv_params live, FFN stages where w_params live.
        self._embed = jax.jit(fns.embed)
        self._attn = jax.jit(fns.attn_stage)
        self._ffn = jax.jit(fns.ffn_stage)
        self._combine = jax.jit(fns.combine)
        self._logits = jax.jit(fns.logits)

    def __call__(self, tokens, cache_k, cache_v, lengths
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        p_kv, p_w = self.pooled.kv_params, self.pooled.w_params
        x = self._embed(p_kv, tokens)
        for layer in range(self.pooled.stage_fns.n_layers):
            x, ffn_in, cache_k, cache_v = self._attn(
                p_kv, x, cache_k, cache_v, lengths, layer)
            ffn_in_w = transfer(ffn_in, self.w_device)      # A-to-F
            ffn_out = self._ffn(p_w, ffn_in_w, layer)
            ffn_out_kv = transfer(ffn_out, self.kv_device)  # F-to-A
            x = self._combine(x, ffn_out_kv)
        return self._logits(p_kv, x), cache_k, cache_v

    def stage_generator(self, tokens, cache_k, cache_v, lengths):
        """Yield one pipeline stage at a time (for the layer-wise scheduler).

        Yields ("attn"|"ffn", layer) after issuing that stage's dispatch;
        the final return carries (logits, cache_k, cache_v).
        """
        p_kv, p_w = self.pooled.kv_params, self.pooled.w_params
        x = self._embed(p_kv, tokens)
        for layer in range(self.pooled.stage_fns.n_layers):
            x, ffn_in, cache_k, cache_v = self._attn(
                p_kv, x, cache_k, cache_v, lengths, layer)
            yield ("attn", layer)
            ffn_in_w = transfer(ffn_in, self.w_device)
            ffn_out = self._ffn(p_w, ffn_in_w, layer)
            yield ("ffn", layer)
            ffn_out_kv = transfer(ffn_out, self.kv_device)
            x = self._combine(x, ffn_out_kv)
        yield ("logits", -1)
        self.result = (self._logits(p_kv, x), cache_k, cache_v)


class FusedStep:
    """Device-resident control (lowering ON): one dispatch per token.

    The whole stack — embed, every layer's attention + proxy boundary +
    FFN, final logits — is a single compiled program (scan over layers).
    """

    def __init__(self, pooled: PooledModel, device=None):
        self.pooled = pooled
        cfg = pooled.cfg
        model = build_model(cfg)
        params = split_exec.merge_params(pooled.kv_params, pooled.w_params)
        # the merged tree mixes pool devices; commit it to ONE device so the
        # fused program has a single placement
        device = device or jax.devices()[0]
        self.params = jax.device_put(params, device)

        def step(params, tokens, cache, lengths):
            return model.decode_step(params, tokens, cache, lengths)

        self._step = jax.jit(step)

    def __call__(self, tokens, cache: Dict, lengths
                 ) -> Tuple[jax.Array, Dict]:
        return self._step(self.params, tokens, cache, lengths)


def dispatch_count(n_layers: int, fused: bool) -> int:
    """Host dispatches per decode token (the ablation's control metric)."""
    if fused:
        return 1
    # embed + (attn + ffn + combine + 2 transfers) per layer + logits
    return 2 + n_layers * 5
