"""Split layer execution: attention sub-block vs FFN sub-block per layer.

This mirrors the paper's §4 runtime integration: in the KV-cache pool the
FFN of every transformer layer is replaced by a *proxy* — the attention
stage returns the post-attention hidden states, the FFN stage (running in
the weights pool, possibly another device) consumes them, and the combine
step resumes the residual stream.  ``attn_stage``/``ffn_stage``/``combine``
are the units the layer-wise pipeline scheduler interleaves.

The attention stage reads and writes KV through the virtualizer's SHARED
paged pool: it takes ``(x, pool, page_tables, lengths)`` instead of dense
per-model caches, writes the new token's K/V at its (page, slot)
coordinate and attends through ``repro.kernels.paged_attention``.  The
pool is the single source of KV truth for every split-execution model;
dense contiguous caches survive only in the fused fallback path
(``repro.models.decode``) used by the SSM/hybrid/enc-dec/SWA families.

The FFN stage is symmetric on the weights side: it does NOT close over a
per-model ``w_params`` tree.  It takes ``(arena, slot_table, ffn_in,
layer)`` and gathers the layer's expert / dense-MLP slabs out of the
SHARED weights arena (``repro.core.weight_pool.WeightArena``) through the
model's slot table, so FFN weights are read exactly like KV pages and
cold models can be activated/evicted without recompiling the stages.

Supported families: dense / moe / vlm with GQA or MLA attention — the
paper's serving targets.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import weight_pool
from repro.core.virtualizer import ModelView
from repro.models import attention as attn
from repro.models import layers, moe as moe_mod
from repro.models import transformer as tfm
from repro.models.hooks import IDENTITY_HOOKS


class StageFns(NamedTuple):
    embed: Callable          # (params, tokens [B])            -> x [B,1,D]
    attn_stage: Callable     # (params, x, pool, page_tables [L,B,P],
    #                           lengths [B], layer)
    #                           -> (x_resid, ffn_input, pool)
    ffn_stage: Callable      # (arena [S,slab], slot_table [L,spl],
    #                           ffn_input, layer)              -> ffn_out
    combine: Callable        # (x_resid, ffn_out)              -> x
    logits: Callable         # (params, x)                     -> [B,V]
    # prompt-phase (prefill) variants over the SAME arena: full-sequence
    # attention per layer, the identical ffn_stage consuming [B,S,D]
    prefill_embed: Callable  # (params, tokens [B,S])          -> x [B,S,D]
    prefill_attn: Callable   # (params, x [B,S,D], layer)
    #                           -> (x_resid, ffn_input, layer_kv)
    #                        layer_kv: (k, v) [B,S,KV,hd] for GQA or
    #                                  (latent, rope) [B,S,·] for MLA
    prefill_logits: Callable  # (params, x [B,S,D],
    #                           logit_index scalar | [B])      -> [B,V]
    n_layers: int
    # prefix-cache suffix prefill (DESIGN.md §11): attention over
    # (cached prefix KV ++ fresh suffix KV) at the producing pass's
    # reduction extent, and the FFN with the producing pass's expert
    # capacity + the prefix's routed-pair slot offsets
    suffix_attn: Optional[Callable] = None
    # (params, x [B,S_suf,D], prefix_rows [L, fork, *kv_shape],
    #  positions [B,S_suf], layer, kv_extent static)
    #                              -> (x_resid, ffn_input, layer_kv)
    suffix_ffn: Optional[Callable] = None
    # (arena, slot_table, ffn_input, layer, slot_offsets [L,E]|None,
    #  capacity static)            -> ffn_out
    prefill_route: Optional[Callable] = None
    # (arena, slot_table, ffn_input, layer) -> experts [B,S,k]
    #  (MoE only; recomputes the router's top-k choice so the prompt's
    #   routing can be captured without touching the FFN program)


def _layer_params(params: Dict, layer) -> Dict:
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, layer, 0, keepdims=False),
        params["layers"])


def supports_split(cfg: ModelConfig) -> bool:
    """Whether a model runs the split (paged-pool) decode path.

    Everything else — SSM, hybrid, enc-dec audio, sliding-window patterns —
    falls back to the fused dense-cache path.
    """
    return (cfg.family in ("dense", "moe", "vlm")
            and not cfg.attn_free
            and cfg.swa_pattern == 0
            and cfg.attention in ("gqa", "mla"))


def make_stage_fns(cfg: ModelConfig, view: ModelView,
                   w_view: "weight_pool.ModelArenaView") -> StageFns:
    """Stage functions over the shared paged pool + the weights arena.

    ``view`` is the virtualizer's :class:`ModelView` for this model — it
    fixes the static page geometry (``tokens_per_page``) the stage programs
    compile against.  ``w_view`` is the weights arena's
    :class:`~repro.core.weight_pool.ModelArenaView` — it fixes the static
    slab geometry the FFN stage's gather/bitcast unpacker compiles against.
    """
    if not supports_split(cfg):
        raise ValueError(
            f"split execution supports dense/moe/vlm with gqa/mla attention; "
            f"{cfg.name} ({cfg.family}) uses the fused path")
    tpp = view.tokens_per_page

    def embed(params, tokens):
        return layers.embed_tokens(params["embed"], tokens[:, None])

    def attn_stage(params, x, pool, page_tables, lengths, layer):
        p_l = _layer_params(params, layer)
        table = jax.lax.dynamic_index_in_dim(page_tables, layer, 0,
                                             keepdims=False)
        h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
        if cfg.attention == "mla":
            out, pool = attn.mla_paged_decode(p_l["attn"], cfg, h, pool,
                                              table, lengths,
                                              tokens_per_page=tpp)
        else:
            out, pool = attn.gqa_paged_decode(p_l["attn"], cfg, h, pool,
                                              table, lengths,
                                              tokens_per_page=tpp)
        x = x + out
        # the proxy boundary: pre-FFN norm runs in the KV pool, the
        # normalized hidden states are what crosses to the weights pool
        ffn_in = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
        return x, ffn_in, pool

    def ffn_stage(arena, slot_table, ffn_in, layer):
        row = jax.lax.dynamic_index_in_dim(slot_table, layer, 0,
                                           keepdims=False)
        p_l = w_view.unpack_layer(arena, row)
        if cfg.is_moe:
            B, S = ffn_in.shape[0], ffn_in.shape[1]
            if B > 1 and S > 1:
                # batched (coalesced) prefill: route each request's prompt
                # independently, so expert capacity is per request and a
                # [B,S] pass is bit-exact with B separate [1,S] passes —
                # one request's tokens can never evict another's from an
                # expert's capacity window (decode keeps the batch-global
                # formulation: its rows are single tokens)
                out, _ = jax.vmap(
                    lambda r: moe_mod.apply_moe(p_l["moe"], r[None], cfg)
                )(ffn_in)
                out = out[:, 0]
            else:
                out, _ = moe_mod.apply_moe(p_l["moe"], ffn_in, cfg)
        else:
            out = layers.apply_mlp(p_l["mlp"], ffn_in, cfg.mlp_kind)
        return out

    def combine(x, ffn_out):
        return x + ffn_out

    def logits(params, x):
        x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return layers.unembed(params["embed"], x)[:, 0]

    # ---- prompt phase (prefill) over the same arena ----------------------
    # The attention stage runs the FULL-sequence attention of
    # ``models.transformer`` (bit-identical math to the fused dense
    # prefill), but the FFN boundary is the same proxy as decode: the
    # normalized hidden states cross to the weights side and ``ffn_stage``
    # gathers the layer's slabs from the shared arena — no per-model
    # ``w_params`` tree exists at prompt time either.

    def prefill_embed(params, tokens):
        return layers.embed_tokens(params["embed"], tokens)

    def prefill_attn(params, x, layer):
        p_l = _layer_params(params, layer)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        x, layer_kv = tfm._attn_full(p_l, cfg, x, positions, 0,
                                     IDENTITY_HOOKS, "xla")
        ffn_in = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
        return x, ffn_in, layer_kv

    def prefill_logits(params, x, logit_index):
        # ``logit_index`` is scalar (one shared unpadded length) or [B]
        # (a coalesced batch where every row has its own true length)
        idx = jnp.asarray(logit_index, jnp.int32)
        if idx.ndim == 0:
            x_last = jax.lax.dynamic_slice_in_dim(x, idx, 1, axis=1)
        else:
            x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        x_last = layers.rms_norm(x_last, params["final_norm"], cfg.norm_eps)
        return layers.unembed(params["embed"], x_last)[:, 0]

    # ---- prefix-cache suffix prefill (DESIGN.md §11) ---------------------
    # Attention concatenates the cached prefix KV (gathered from the pool)
    # with the suffix's fresh KV and pins the reduction extent to the
    # PRODUCING pass's bucket; the FFN reuses the producing capacity with
    # the prefix's routed-pair counts as slot offsets — together the
    # suffix rows reproduce the full-prompt pass bit-for-bit at every
    # consumed position.

    def suffix_attn(params, x, prefix_rows, positions, layer, kv_extent):
        # ``prefix_rows`` is the [L, fork, *kv_shape] stack from
        # ``gather_prompt_rows``: the layer extraction and the K/V (or
        # MLA latent/rope) split happen here, inside the compiled stage,
        # so the host loop dispatches no eager slices per layer
        p_l = _layer_params(params, layer)
        rows = jax.lax.dynamic_index_in_dim(prefix_rows, layer, 0,
                                            keepdims=False)
        if cfg.attention == "mla":
            r = cfg.mla.kv_lora_rank
            prefix_a, prefix_b = rows[None, :, :r], rows[None, :, r:]
        else:
            prefix_a, prefix_b = rows[None, :, 0], rows[None, :, 1]
        h = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
        if cfg.attention == "mla":
            out, layer_kv = attn.mla_suffix(p_l["attn"], cfg, h, positions,
                                            prefix_a, prefix_b, kv_extent)
        else:
            out, layer_kv = attn.gqa_suffix(p_l["attn"], cfg, h, positions,
                                            prefix_a, prefix_b, kv_extent)
        x = x + out
        ffn_in = layers.rms_norm(x, p_l["ln2"], cfg.norm_eps)
        return x, ffn_in, layer_kv

    def suffix_ffn(arena, slot_table, ffn_in, layer, slot_offsets, capacity):
        row = jax.lax.dynamic_index_in_dim(slot_table, layer, 0,
                                           keepdims=False)
        p_l = w_view.unpack_layer(arena, row)
        if cfg.is_moe:
            # suffix groups are B=1 singletons, so the plain (non-vmapped)
            # dispatch is the bit-exact counterpart of the producing pass;
            # slot_offsets is the [L, E] stack, sliced in-program
            offset = None if slot_offsets is None else \
                jax.lax.dynamic_index_in_dim(slot_offsets, layer, 0,
                                             keepdims=False)
            out, _ = moe_mod.apply_moe(p_l["moe"], ffn_in, cfg,
                                       capacity=capacity,
                                       slot_offset=offset)
        else:
            out = layers.apply_mlp(p_l["mlp"], ffn_in, cfg.mlp_kind)
        return out

    if cfg.is_moe:
        def prefill_route(arena, slot_table, ffn_in, layer):
            row = jax.lax.dynamic_index_in_dim(slot_table, layer, 0,
                                               keepdims=False)
            p_l = w_view.unpack_layer(arena, row)
            B, S, d = ffn_in.shape
            _, experts, _ = moe_mod.route(p_l["moe"], ffn_in.reshape(-1, d),
                                          cfg)
            return experts.reshape(B, S, cfg.experts_per_token)
    else:
        prefill_route = None

    return StageFns(embed, attn_stage, ffn_stage, combine, logits,
                    prefill_embed, prefill_attn, prefill_logits,
                    cfg.n_layers, suffix_attn, suffix_ffn, prefill_route)


def split_params(params: Dict, cfg: ModelConfig) -> Tuple[Dict, Dict]:
    """Partition a param tree into (kv_pool_params, weights_pool_params).

    FFN/MoE weights go to the weights pool (the dominant MoE bytes, paper
    Table 1); embeddings, norms and attention stay with the KV pool.
    """
    ffn_keys = ("mlp", "moe")

    def is_ffn(path):
        return any(k in path for k in ffn_keys)

    kv_tree = {}
    w_tree = {}

    def walk(src, kv_dst, w_dst, path=()):
        for k, v in src.items():
            p = path + (k,)
            if isinstance(v, dict):
                kv_sub, w_sub = {}, {}
                walk(v, kv_sub, w_sub, p)
                if kv_sub:
                    kv_dst[k] = kv_sub
                if w_sub:
                    w_dst[k] = w_sub
            else:
                (w_dst if is_ffn(p) else kv_dst)[k] = v

    walk(params, kv_tree, w_tree)
    return kv_tree, w_tree


def merge_params(kv_tree: Dict, w_tree: Dict) -> Dict:
    out: Dict = {}

    def walk(src, dst):
        for k, v in src.items():
            if isinstance(v, dict):
                dst.setdefault(k, {})
                walk(v, dst[k])
            else:
                dst[k] = v

    walk(kv_tree, out)
    walk(w_tree, out)
    return out
