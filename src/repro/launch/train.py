"""Training launcher: real steps at host scale, dry-run lowering at fleet
scale.

  python -m repro.launch.train --arch qwen3-14b --smoke --steps 100
  python -m repro.launch.train --arch llama3-405b --dry-run --multi-pod

Fault tolerance: periodic (async) checkpoints, automatic resume from the
latest step, elastic restore onto whatever mesh the current run has
(checkpoint.py reshards), straggler counters per step.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import numpy as np


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config for host-scale real training")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="error-feedback int8 gradient compression")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the production train step instead")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    if args.dry_run:
        # delegate to the dry-run driver (sets XLA device flags itself)
        from repro.launch import dryrun
        rec = dryrun.run_cell(args.arch, "train_4k",
                              multi_pod=args.multi_pod,
                              strategy_name="train")
        raise SystemExit(0 if rec.get("ok") else 1)

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, get_smoke_config
    from repro.models import build_model
    from repro.training import checkpoint as ckpt
    from repro.training.data import DataConfig, SyntheticLM
    from repro.training.optimizer import AdamW
    from repro.training.train_step import init_train_state, make_train_step

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).replace(dtype="float32")
    model = build_model(cfg)
    optimizer = AdamW(lr=args.lr, warmup_steps=10)
    step_fn = jax.jit(make_train_step(
        model, optimizer, num_microbatches=args.microbatches,
        compress=args.compress, remat=False))

    state = init_train_state(model, optimizer, jax.random.PRNGKey(0),
                             compress=args.compress)
    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        spec = jax.eval_shape(lambda: state)
        state, start = ckpt.restore(args.ckpt_dir, target_tree=spec)
        print(f"resumed from step {start}")

    data = SyntheticLM(DataConfig(cfg.vocab_size, args.seq, args.batch))
    slow = 0
    times = []
    for i, batch in zip(range(start, args.steps), data.batches(start)):
        t0 = time.perf_counter()
        state, metrics = step_fn(state, {"tokens": jnp.asarray(batch["tokens"])})
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        if len(times) > 5 and dt > np.median(times) * 4:
            slow += 1                       # straggler counter
        times.append(dt)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {loss:.4f} ce {float(metrics['ce']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt * 1e3:.0f}ms")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            ckpt.save_async(state, i + 1, args.ckpt_dir)
    print(f"done: {args.steps - start} steps, median "
          f"{np.median(times) * 1e3:.0f} ms/step, {slow} straggler steps")


if __name__ == "__main__":
    main()
