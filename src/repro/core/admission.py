"""Admission control: per-model queues enforcing the planner's page budget.

Paper §3.1: "if the pool page budget is exhausted, admission control queues
or rejects new requests instead of interrupting active decode requests."
Active pages are never revoked; shedding happens only at admission.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from repro.core.virtualizer import KVVirtualizer


@dataclass
class PendingRequest:
    request_id: int
    model: str
    prompt_tokens: int
    expected_output: int
    arrival_time: float
    enqueue_time: float = 0.0


@dataclass
class ModelAdmissionStats:
    """Per-model admitted/queued/rejected counters."""

    admitted: int = 0
    queued: int = 0
    rejected: int = 0


@dataclass
class AdmissionStats:
    admitted: int = 0
    queued: int = 0
    rejected: int = 0
    queue_wait_total: float = 0.0
    per_model: Dict[str, ModelAdmissionStats] = field(default_factory=dict)

    def bump(self, model: str, outcome: str) -> None:
        """Count one admission outcome globally AND for ``model``."""
        setattr(self, outcome, getattr(self, outcome) + 1)
        m = self.per_model.setdefault(model, ModelAdmissionStats())
        setattr(m, outcome, getattr(m, outcome) + 1)


class AdmissionController:
    """Queue-or-reject front door for the shared KV pool."""

    def __init__(self, virtualizer: KVVirtualizer, *,
                 max_queue_per_model: int = 64,
                 reserve_output_tokens: bool = True):
        self.virt = virtualizer
        self.max_queue = max_queue_per_model
        self.reserve_output = reserve_output_tokens
        self.queues: Dict[str, Deque[PendingRequest]] = collections.defaultdict(
            collections.deque)
        self.stats = AdmissionStats()

    def offer(self, req: PendingRequest, now: float) -> str:
        """Returns 'admitted' | 'queued' | 'rejected'."""
        if self._try_admit(req):
            self.stats.bump(req.model, "admitted")
            return "admitted"
        if len(self.queues[req.model]) < self.max_queue:
            req.enqueue_time = now
            self.queues[req.model].append(req)
            self.stats.bump(req.model, "queued")
            return "queued"
        self.stats.bump(req.model, "rejected")
        return "rejected"

    def _try_admit(self, req: PendingRequest) -> bool:
        expect = req.expected_output if self.reserve_output else 0
        if not self.virt.can_admit(req.model, req.prompt_tokens, expect):
            return False
        self.virt.register_request(req.request_id, req.model,
                                   req.prompt_tokens)
        return True

    def drain(self, now: float) -> List[PendingRequest]:
        """Admit queued requests that now fit (FIFO per model, round-robin
        across models so one model cannot starve the others)."""
        admitted: List[PendingRequest] = []
        progress = True
        while progress:
            progress = False
            for model in list(self.queues):
                q = self.queues[model]
                if not q:
                    continue
                head = q[0]
                if self._try_admit(head):
                    q.popleft()
                    self.stats.queue_wait_total += now - head.enqueue_time
                    self.stats.bump(model, "admitted")
                    admitted.append(head)
                    progress = True
        return admitted

    def queued_count(self) -> int:
        return sum(len(q) for q in self.queues.values())
