"""Online serving session acceptance: the PR-4 tentpole invariants.

* pre-refactor parity: ``run()`` — now a thin wrapper over
  ``submit``/``step`` — reproduces the token streams captured from the
  seed offline driver BIT-EXACTLY on a fixed seed/trace, in both
  lowering modes, while same-model arrivals coalesce into [B>1, S]
  prefill passes;
* session parity: driving ``submit``/``step`` by hand produces the same
  streams as the ``run()`` wrapper;
* batched prefill parity: one coalesced [B, S] StreamingPrefill pass is
  bit-exact with B separate [1, S] passes — logits AND every prompt-KV
  byte landing in the shared pool (per-request expert routing);
* cancellation: ``cancel()`` frees KV pages and drops the arena pin
  atomically, mid-prefill (admitted, pages mapped, no slot yet) and
  mid-decode (in a batch slot), returning pool/arena accounting to
  baseline while the rest of the session keeps serving;
* backpressure on the handle: admit/queue/reject is visible at submit
  time, queued handles drain to ADMITTED, and per-token callbacks
  stream TokenEvents with first/done marks.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PAPER_COLOC_SET, get_smoke_config
from repro.core.control import StreamingPrefill
from repro.core.pools import build_pools
from repro.models import build_model
from repro.runtime.engine import CrossPoolEngine, EngineMode, ServingSession
from repro.runtime.request import Phase, Request
from repro.runtime.session import HandleState

MOE, MLA, MOON = "qwen3-moe-235b-a22b", "minicpm3-4b", "moonshot-v1-16b-a3b"
FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "pre_refactor_token_streams.json")


def _models(names=PAPER_COLOC_SET):
    return {n: get_smoke_config(n).replace(dtype="float32") for n in names}


def _engine(names=PAPER_COLOC_SET, lowering=True, **kw):
    kw.setdefault("page_budget", 2048)
    kw.setdefault("page_bytes", 4096)
    kw.setdefault("slab_bytes", 4096)
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_ctx", 64)
    kw.setdefault("seed", 0)
    return CrossPoolEngine(_models(names),
                           mode=EngineMode(pipeline=True, lowering=lowering),
                           **kw)


def _trace_fused():
    return [Request(0, MOE, 6, 3, 0.0), Request(1, MOE, 7, 3, 0.0),
            Request(2, MOE, 9, 4, 0.0), Request(3, MLA, 5, 3, 0.0),
            Request(4, MLA, 6, 2, 0.0), Request(5, MOON, 20, 3, 0.0)]


def _trace_host():
    return [Request(0, MOE, 6, 3, 0.0), Request(1, MLA, 5, 2, 0.0),
            Request(2, MOON, 20, 3, 0.0)]


def _streams(reqs):
    return {str(r.request_id): list(map(int, r.output_ids)) for r in reqs}


# ---------------------------------------------------------------------------
# bit-exact parity with the pre-refactor offline driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("key,lowering,mk", [
    ("fused_pipeline", True, _trace_fused),
    ("host_pipeline", False, _trace_host),
])
def test_run_matches_pre_refactor_driver(key, lowering, mk):
    """The compat wrapper (submit/step underneath) reproduces the token
    streams captured from the seed monolithic ``run()`` loop, bit for
    bit — and the fused trace witnesses B>1 coalesced prefill."""
    with open(FIXTURE) as f:
        want = json.load(f)[key]
    engine = _engine(lowering=lowering)
    reqs = mk()
    stats = engine.run(reqs)
    assert _streams(reqs) == want["streams"]
    assert stats.tokens_out == want["tokens_out"]
    if key == "fused_pipeline":
        # same-model same-bucket arrivals in one step window ran as ONE
        # [B, S] pass with B > 1 (the two t=0 MOE and the two MLA
        # requests), and the late joiner ran B=1 — continuous batching
        assert max(stats.prefill_batch_sizes) > 1
        assert stats.prefill_batch_sizes.count(2) == 2


def test_session_api_matches_run_wrapper():
    """Driving submit/step by hand == the run() wrapper, bit for bit."""
    ref_engine = _engine()
    ref_reqs = _trace_fused()
    ref_engine.run(ref_reqs)

    engine = _engine()
    reqs = _trace_fused()
    handles = [engine.submit(r) for r in reqs]
    assert all(h.admission == "admitted" for h in handles)
    steps = 0
    while any(not h.done for h in handles):
        engine.step()
        steps += 1
        assert steps < 100
    assert _streams(reqs) == _streams(ref_reqs)
    assert all(h.state is HandleState.FINISHED for h in handles)
    # ServingSession is the same front-end
    assert ServingSession is CrossPoolEngine


def test_streaming_callbacks_and_events():
    """Per-token callbacks fire in stream order with first/done marks and
    agree with the events returned by step()."""
    engine = _engine(names=(MOE, MLA))
    seen = []
    h = engine.submit(Request(0, MOE, 6, 3, 0.0),
                      on_token=lambda e: seen.append(e))
    all_events = []
    while not h.done:
        all_events.extend(engine.step())
    assert [e.token for e in seen] == h.tokens
    assert [e.index for e in seen] == [0, 1, 2]
    assert seen[0].first and not seen[0].done
    assert seen[-1].done and not seen[-1].first
    assert all(e.model == MOE for e in seen)
    assert [e.token for e in all_events if e.request_id == 0] == h.tokens
    # event times are the request's token times (TBT bookkeeping source)
    assert [e.time for e in seen] == h.request.token_times


# ---------------------------------------------------------------------------
# batched same-model prefill parity vs B=1
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", [MOE, MLA])
def test_batched_prefill_bit_exact_vs_solo(name):
    """One [B=2, S] coalesced pass == two [1, S] passes: the returned
    logits AND every prompt-KV byte landing in the shared pool."""
    models = _models((name,))
    cfg = models[name]
    params = {name: build_model(cfg).init(jax.random.PRNGKey(0))}
    kv_pool, w_pool, pooled = build_pools(
        models, params, page_budget=256, page_bytes=4096,
        pool_dtype=jnp.float32, slab_bytes=4096, activate_resident=False)
    virt = kv_pool.virtualizer
    seq, bucket = 7, 16
    rng = np.random.default_rng(0)
    ids = [rng.integers(0, cfg.vocab_size, bucket).astype(np.int32)
           for _ in range(2)]
    sp = StreamingPrefill(pooled[name])

    def writer(rid, n, batch_index=0):
        def write(layer, layer_kv, pool):
            return virt.write_prompt_layer(pool, name, rid, layer, layer_kv,
                                           n, batch_index=batch_index)
        return write

    # solo reference passes
    solo = []
    for i in range(2):
        virt.register_request(i, name, seq)
        logits, virt.pool = sp(jnp.asarray(ids[i][None]), seq, virt.pool,
                               writer(i, seq))
        solo.append(np.asarray(logits[0]))

    # one coalesced pass into fresh requests
    virt.register_request(10, name, seq)
    virt.register_request(11, name, seq)

    def batched_writer(layer, layer_kv, pool):
        pool = writer(10, seq, 0)(layer, layer_kv, pool)
        return writer(11, seq, 1)(layer, layer_kv, pool)

    logits, virt.pool = sp(jnp.asarray(np.stack(ids)), [seq, seq],
                           virt.pool, batched_writer)
    got = np.asarray(logits)
    for i in range(2):
        assert np.array_equal(solo[i], got[i]), \
            f"{name}: batched prefill row {i} logits != solo pass"
    # prompt KV bytes identical page-for-page
    pool_np = np.asarray(virt.pool)
    for solo_rid, batch_rid in ((0, 10), (1, 11)):
        r_s, r_b = virt.requests[solo_rid], virt.requests[batch_rid]
        for t_s, t_b in zip(r_s.tables, r_b.tables):
            for p_s, p_b in zip(t_s, t_b):
                assert np.array_equal(pool_np[p_s], pool_np[p_b]), \
                    f"{name}: prompt KV bytes differ in the pool"


def test_mixed_length_group_uses_per_row_logit_index():
    """Rows of one coalesced pass keep their own unpadded lengths: a
    [2, S] group with different true lengths matches the two solo passes
    at those lengths."""
    models = _models((MLA,))
    cfg = models[MLA]
    params = {MLA: build_model(cfg).init(jax.random.PRNGKey(0))}
    _, _, pooled = build_pools(
        models, params, page_budget=256, page_bytes=4096,
        pool_dtype=jnp.float32, slab_bytes=4096, activate_resident=False)
    rng = np.random.default_rng(0)
    ids = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
           for _ in range(2)]
    sp = StreamingPrefill(pooled[MLA])
    want0, _ = sp(jnp.asarray(ids[0][None]), 5, None, None)
    want1, _ = sp(jnp.asarray(ids[1][None]), 9, None, None)
    got, _ = sp(jnp.asarray(np.stack(ids)), [5, 9], None, None)
    assert np.array_equal(np.asarray(want0[0]), np.asarray(got[0]))
    assert np.array_equal(np.asarray(want1[0]), np.asarray(got[1]))


# ---------------------------------------------------------------------------
# cancellation correctness
# ---------------------------------------------------------------------------

def _accounting(engine):
    return {
        "mapped_pages": engine.virt.mapped_pages,
        "live_requests": sorted(engine.virt.requests),
        "pins": dict(engine.arena.pins) if engine.arena is not None else {},
        "inflight": dict(engine.admission.inflight),
        "queued": engine.admission.queued_count(),
    }


def test_cancel_mid_prefill_and_mid_decode_restores_accounting():
    """cancel() unpins weight slabs and frees KV pages atomically: after
    a mid-prefill cancel (admitted: pages mapped + pin held, no slot yet)
    and a mid-decode cancel (in a batch slot), pool and arena accounting
    return to baseline and the session still serves new work."""
    engine = _engine(names=(MOE, MLA))
    baseline = _accounting(engine)

    # --- mid-prefill: admission mapped pages and took the pin ----------
    h0 = engine.submit(Request(0, MOE, 6, 4, 0.0))
    assert h0.state is HandleState.ADMITTED
    assert engine.virt.mapped_pages > baseline["mapped_pages"]
    assert engine.arena.pins.get(MOE) == 1
    assert engine.cancel(h0)
    assert h0.state is HandleState.CANCELLED
    assert h0.request.phase is Phase.CANCELLED
    assert _accounting(engine) == baseline
    assert not engine.cancel(h0)            # idempotent on terminal states

    # --- mid-decode: prefilled into a slot, tokens already streaming ---
    h1 = engine.submit(Request(1, MOE, 6, 50, 0.0))
    h2 = engine.submit(Request(2, MLA, 5, 3, 0.0))
    engine.step()
    engine.step()
    assert h1.state is HandleState.DECODING
    assert len(h1.tokens) >= 2
    assert engine.runners[MOE].active
    assert engine.cancel(h1)
    assert not engine.runners[MOE].active
    # the co-resident request is untouched and drains to completion
    stats = engine.drain()
    assert h2.state is HandleState.FINISHED
    assert len(h2.tokens) == 3
    assert _accounting(engine) == baseline
    assert stats.cancelled == 2


def test_cancel_queued_request_leaves_queue():
    """A request queued by arena backpressure cancels out of the queue
    (it holds no resources) and the session drains without it."""
    from repro.core.weight_pool import slabs_for_config
    models = _models((MOE, MLA))
    need = {n: slabs_for_config(c, 4096) for n, c in models.items()}
    engine = CrossPoolEngine(
        models, page_budget=2048, page_bytes=4096,
        slot_budget=max(need.values()), slab_bytes=4096,
        max_batch=2, max_ctx=64,
        mode=EngineMode(pipeline=True, lowering=True))
    h_moe = engine.submit(Request(0, MOE, 8, 3, 0.0))
    h_mla = engine.submit(Request(1, MLA, 8, 3, 0.0))
    assert h_moe.admission == "admitted"
    assert h_mla.admission == "queued"      # weights-arena backpressure
    assert h_mla.state is HandleState.QUEUED
    assert engine.cancel(h_mla)
    assert engine.admission.queued_count() == 0
    engine.drain()
    assert h_moe.state is HandleState.FINISHED
    assert h_mla.state is HandleState.CANCELLED
    assert len(h_mla.tokens) == 0
    assert not engine.arena.pins and not engine.admission.inflight


def test_queued_handle_drains_to_admitted_and_finishes():
    """Backpressure lifecycle on the handle: queued at submit, ADMITTED
    once the blocking request finishes, FINISHED at end of stream."""
    from repro.core.weight_pool import slabs_for_config
    models = _models((MOE, MLA))
    need = {n: slabs_for_config(c, 4096) for n, c in models.items()}
    engine = CrossPoolEngine(
        models, page_budget=2048, page_bytes=4096,
        slot_budget=max(need.values()), slab_bytes=4096,
        max_batch=2, max_ctx=64,
        mode=EngineMode(pipeline=True, lowering=True))
    h_moe = engine.submit(Request(0, MOE, 8, 2, 0.0))
    h_mla = engine.submit(Request(1, MLA, 8, 2, 0.0))
    assert h_mla.state is HandleState.QUEUED
    engine.drain()
    assert h_moe.state is HandleState.FINISHED
    assert h_mla.state is HandleState.FINISHED
    assert len(h_mla.tokens) == 2
    assert engine.stats.admission.weight_pressure_queued >= 1


def test_cancel_from_on_token_callback_defers_to_step_boundary():
    """The "stop at token X" pattern: a cancel issued from inside a
    streaming callback must not corrupt the in-flight commit loops — it
    defers to the step boundary, then tears down atomically."""
    engine = _engine(names=(MOE, MLA))
    baseline = _accounting(engine)
    h_victim = engine.submit(Request(0, MOE, 6, 50, 0.0))
    h_trigger = engine.submit(
        Request(1, MOE, 7, 50, 0.0),
        on_token=lambda e: e.index >= 2 and h_victim.cancel())
    engine.step()                        # prefill both (coalesced) + decode:
    assert h_victim.state is HandleState.DECODING      # indices 0 and 1
    engine.step()                        # trigger's token 2 cancels victim
    assert h_victim.state is HandleState.CANCELLED
    assert engine.cancel(h_trigger)      # direct cancel outside a step
    assert _accounting(engine) == baseline
    assert engine.stats.cancelled == 2


def test_real_prompt_ids_round_trip_and_length_contract():
    """``prompt_ids`` drives the prefill when provided; a length that
    disagrees with ``prompt_tokens`` (the page-mapping contract) fails
    loudly instead of scattering KV past the mapped pages."""
    engine = _engine(names=(MOE, MLA))
    rng = np.random.default_rng(3)
    ids = rng.integers(0, _models()[MOE].vocab_size, 6).astype(np.int32)
    h = engine.submit(Request(0, MOE, 6, 2, 0.0, prompt_ids=ids))
    engine.drain()
    assert h.state is HandleState.FINISHED and len(h.tokens) == 2

    engine.submit(Request(1, MOE, 9, 2, 0.0, prompt_ids=ids))  # 6 != 9
    with pytest.raises(AssertionError, match="prompt_ids length"):
        engine.step()


def test_reset_stats_opens_window_and_prunes_terminal_handles():
    """reset_stats() starts a fresh latency window and prunes terminal
    handles (the memory bound for long-lived sessions)."""
    engine = _engine(names=(MOE, MLA))
    engine.submit(Request(0, MOE, 6, 3, 0.0))
    stats = engine.drain()
    assert stats.tokens_out == 3 and len(stats.tbt) == 2
    engine.reset_stats()
    assert not engine.handles and not engine._submitted
    h = engine.submit(Request(1, MOE, 6, 2, 0.0))
    stats = engine.drain()
    assert h.state is HandleState.FINISHED
    assert stats.tokens_out == 2 and len(stats.tbt) == 1   # window-scoped


def test_rejection_visible_on_handle():
    """The front door's reject verdict lands on the handle at submit."""
    engine = _engine(names=(MLA,), page_budget=8)
    handles = [engine.submit(Request(i, MLA, 4096, 4, 0.0))
               for i in range(engine.admission.max_queue + 1)]
    assert all(h.state is HandleState.QUEUED for h in handles[:-1])
    assert handles[-1].state is HandleState.REJECTED
    assert handles[-1].admission == "rejected"
    assert handles[-1].request.phase is Phase.REJECTED
    # nothing can ever drain these; the session exits instead of spinning
    stats = engine.drain()
    assert stats.tokens_out == 0


def test_synthetic_prompts_are_silently_cache_cold():
    """A request without ``prompt_ids`` has no token content to key the
    radix tree — with the cache ON it must run cache-cold (no hit, no
    insert) and serve the exact same stream as a cache-off engine."""
    from repro.configs.base import CacheConfig, EngineConfig

    streams = []
    for cache_on in (True, False):
        cfg = EngineConfig(mode=EngineMode(pipeline=True, lowering=True),
                           cache=CacheConfig(enabled=cache_on))
        engine = CrossPoolEngine(_models((MLA,)), page_budget=2048,
                                 page_bytes=4096, max_batch=2, max_ctx=64,
                                 config=cfg, seed=0)
        handles = [engine.submit(Request(i, MLA, 6, 3, 0.0))
                   for i in range(2)]
        engine.drain()
        assert all(h.state is HandleState.FINISHED for h in handles)
        assert all(not h.cache_hit and h.cached_tokens == 0
                   for h in handles)
        if cache_on:
            snap = engine.cache.snapshot()
            assert snap["hits"] == 0 and snap["inserted_chunks"] == 0
        streams.append([list(h.tokens) for h in handles])
    assert streams[0] == streams[1]
