"""KV-cache planner: Eq. (1)-(2) trace-driven Monte Carlo pool sizing.

The planner answers C1 (paper §3.1): given per-model workload samples and
arrival rates, size ONE shared KV-cache pool for the P95/P99 of *aggregate
active* KV demand at a random observation time — not the per-model worst
case — and emit a parallelism plan per model.

Eq. (1): at request age u, active KV tokens grow linearly through decode:
    Q_i(u) = (O_p,i + O_d,i * u / T_i) * 1{0 <= u < T_i}
    K_M(t) = sum_i kappa(M) * Q_i(t - A_i)
Eq. (2): K_pool(t) = sum_M K_M(t).

Sampling draws whole trace ROWS (prompt, output, service-time) jointly, so
the empirical correlations between the three are preserved — sizing each
dimension independently at a worst-case percentile over-provisions (the
paper's stated reason for Monte Carlo over closed forms).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.virtualizer import DEFAULT_PAGE_BYTES
from repro.core.weight_pool import (DEFAULT_SLAB_BYTES, slabs_for_config,
                                    static_ffn_bytes)


@dataclass(frozen=True)
class WorkloadSpec:
    """Per-model offered workload: joint samples + Poisson arrival rate."""

    model: ModelConfig
    arrival_rate: float                      # requests/s (lambda_M)
    prompt_tokens: np.ndarray                # [n] joint trace rows
    output_tokens: np.ndarray                # [n]
    decode_time: np.ndarray                  # [n] seconds resident in KV pool

    def sample_rows(self, rng: np.random.Generator, k: int) -> np.ndarray:
        idx = rng.integers(0, len(self.prompt_tokens), k)
        return idx


@dataclass(frozen=True)
class ModelPlan:
    """Parallelism + paging plan for one colocated model."""

    name: str
    kv_bytes_per_token: int                  # kappa(M), all layers
    tokens_per_page: int                     # per-layer page granularity
    pages_per_token: float                   # amortized, all layers
    attention_type: str                      # "type1" | "type2" | "attn_free"
    attention_strategy: str                  # "head_tp" | "seq_sharded" | "state"
    state_pages_per_request: int             # SSM constant-size state
    expected_active_kv_bytes: float          # mean aggregate for this model


@dataclass(frozen=True)
class PoolPlan:
    """Planner output: enforceable online budget + per-model plans."""

    page_bytes: int
    pool_page_budget: int
    pool_bytes: float
    quantile: float
    mean_active_bytes: float
    per_model: Dict[str, ModelPlan]
    horizon_s: float

    def summary(self) -> str:
        lines = [f"pool budget: {self.pool_page_budget} pages "
                 f"({self.pool_bytes / 2 ** 30:.2f} GiB) at P{self.quantile * 100:.0f} "
                 f"(mean {self.mean_active_bytes / 2 ** 30:.2f} GiB)"]
        for name, p in self.per_model.items():
            lines.append(
                f"  {name}: kappa={p.kv_bytes_per_token}B/token "
                f"{p.attention_type}/{p.attention_strategy} "
                f"tokens/page={p.tokens_per_page}")
        return "\n".join(lines)


def active_kv_timeline(spec: WorkloadSpec, rng: np.random.Generator,
                       horizon_s: float, dt: float = 1.0,
                       kappa: Optional[int] = None) -> np.ndarray:
    """Simulate K_M(t) over ``horizon_s`` seconds on a dt grid (Eq. 1)."""
    kappa = spec.model.kv_bytes_per_token() if kappa is None else kappa
    n_arrivals = rng.poisson(spec.arrival_rate * horizon_s)
    t_grid = np.arange(0.0, horizon_s, dt)
    usage = np.zeros_like(t_grid)
    if n_arrivals == 0:
        return usage
    arrivals = rng.uniform(0.0, horizon_s, n_arrivals)
    rows = spec.sample_rows(rng, n_arrivals)
    o_p = spec.prompt_tokens[rows].astype(np.float64)
    o_d = spec.output_tokens[rows].astype(np.float64)
    t_res = np.maximum(spec.decode_time[rows].astype(np.float64), dt)
    state_const = spec.model.state_bytes_per_request()
    for a, p, d, tr in zip(arrivals, o_p, o_d, t_res):
        u = t_grid - a
        live = (u >= 0) & (u < tr)
        q = (p + d * np.minimum(u / tr, 1.0)) * live            # Eq. (1)
        usage += kappa * q + state_const * live
    return usage


def plan_pool(specs: Sequence[WorkloadSpec], *,
              page_bytes: int = DEFAULT_PAGE_BYTES,
              quantile: float = 0.99, horizon_s: float = 3600.0,
              n_trials: int = 8, seed: int = 0, model_axis: int = 16,
              headroom: float = 1.05, dt: float = 2.0) -> PoolPlan:
    """Monte Carlo P-quantile sizing of the shared pool (Eq. 2).

    ``n_trials`` independent hour-long traces are simulated and the
    (quantile) of the pooled aggregate over all sampled observation times is
    the provisioning target, rounded up to pages with ``headroom``.
    """
    rng = np.random.default_rng(seed)
    samples: List[np.ndarray] = []
    for _ in range(n_trials):
        total = None
        for spec in specs:
            u = active_kv_timeline(spec, rng, horizon_s, dt=dt)
            total = u if total is None else total + u           # Eq. (2)
        samples.append(total)
    pooled = np.concatenate(samples)
    # cp: allow(CP005) — the provisioning quantile of Eq. (2), a planner
    target = float(np.quantile(pooled, quantile)) * headroom  # input, not a latency statistic
    budget_pages = int(math.ceil(target / page_bytes)) or 1

    per_model: Dict[str, ModelPlan] = {}
    for spec in specs:
        cfg = spec.model
        kappa = cfg.kv_bytes_per_token()
        per_layer = (kappa // max(cfg.n_decoder_attn_layers, 1)
                     if kappa else 0)
        tpp = max(page_bytes // per_layer, 1) if per_layer else 0
        if cfg.attn_free:
            atype, astrat = "attn_free", "state"
        elif cfg.attention == "mla" or cfg.n_kv_heads < model_axis:
            atype, astrat = "type2", "seq_sharded"
        else:
            atype, astrat = "type1", "head_tp"
        mean_active = float(np.mean(
            active_kv_timeline(spec, np.random.default_rng(seed + 1),
                               min(horizon_s, 600.0), dt=dt)))
        per_model[cfg.name] = ModelPlan(
            name=cfg.name,
            kv_bytes_per_token=kappa,
            tokens_per_page=tpp,
            pages_per_token=(cfg.n_decoder_attn_layers / tpp) if tpp else 0.0,
            attention_type=atype,
            attention_strategy=astrat,
            state_pages_per_request=int(
                math.ceil(cfg.state_bytes_per_request() / page_bytes)),
            expected_active_kv_bytes=mean_active,
        )

    return PoolPlan(
        page_bytes=page_bytes,
        pool_page_budget=budget_pages,
        pool_bytes=budget_pages * page_bytes,
        quantile=quantile,
        mean_active_bytes=float(np.mean(pooled)),
        per_model=per_model,
        horizon_s=horizon_s,
    )


@dataclass(frozen=True)
class DeviceBytesPlan:
    """How one device-byte budget splits between the two pools.

    ``page_budget`` bounds the shared KV pool and ``slot_budget`` bounds
    the weights arena — together they are the ONLY knobs that set device
    bytes for the paged families, so this split IS the device memory plan.
    """

    total_bytes: int
    page_bytes: int
    slab_bytes: int
    page_budget: int                       # KV pool pages
    slot_budget: int                       # weights arena slabs
    kv_target_bytes: float                 # planner's P-quantile KV demand
    weight_target_bytes: float             # expected-resident arena demand
    resident_probability: Dict[str, float]  # P(model active at random t)

    def summary(self) -> str:
        kv_b = self.page_budget * self.page_bytes
        w_b = self.slot_budget * self.slab_bytes
        lines = [f"device split: {kv_b / 2 ** 30:.2f} GiB KV "
                 f"({self.page_budget} pages) + {w_b / 2 ** 30:.2f} GiB "
                 f"weights arena ({self.slot_budget} slabs) "
                 f"of {self.total_bytes / 2 ** 30:.2f} GiB"]
        for name, p in self.resident_probability.items():
            lines.append(f"  {name}: P(resident)={p:.3f}")
        return "\n".join(lines)


def split_device_budget(specs: Sequence[WorkloadSpec], total_bytes: int, *,
                        page_bytes: int = DEFAULT_PAGE_BYTES,
                        slab_bytes: int = DEFAULT_SLAB_BYTES,
                        quantile: float = 0.99, horizon_s: float = 3600.0,
                        residency_s: float = 300.0, n_trials: int = 4,
                        coresident: int = 1, seed: int = 0) -> DeviceBytesPlan:
    """Split one device-byte budget into ``page_budget`` vs ``slot_budget``.

    KV demand is the Eq. (2) Monte Carlo P-quantile (:func:`plan_pool`).
    Weights demand uses the arrival rates: a cold model is resident
    whenever it served a request within the last ``residency_s`` seconds
    (the engine keeps weights mapped while requests are in flight and
    evicts LRU), so under Poisson arrivals
    ``P(resident) = 1 - exp(-lambda_M * residency_s)`` and the expected
    arena working set is ``sum_M P(resident) * slabs(M)``.

    The weights floor is the ``coresident`` largest models together.  With
    prefill ALSO running through the arena, an activated model stays
    pinned from prompt phase to completion, so a deployment that should
    never queue a cold model's prefill behind a decoding one wants
    ``coresident=2`` (the arena-aware admission controller queues the
    burst at the front door when the floor is 1).  Both targets are scaled
    proportionally when they exceed ``total_bytes``; the floor never
    shrinks below the single largest model.
    """
    kv_plan = plan_pool(specs, page_bytes=page_bytes, quantile=quantile,
                        horizon_s=horizon_s, n_trials=n_trials, seed=seed)
    kv_target = float(kv_plan.pool_bytes)

    p_res: Dict[str, float] = {}
    w_target = 0.0
    sizes: List[int] = []
    for spec in specs:
        cfg = spec.model
        p = 1.0 - math.exp(-spec.arrival_rate * residency_s)
        p_res[cfg.name] = p
        slabs = slabs_for_config(cfg, slab_bytes)
        w_target += p * slabs * slab_bytes
        sizes.append(slabs * slab_bytes)
    sizes.sort(reverse=True)
    w_floor = sum(sizes[:max(coresident, 1)])
    w_target = max(w_target, float(w_floor))
    if total_bytes < w_floor + page_bytes:
        raise ValueError(
            f"total_bytes={total_bytes} cannot hold the largest model's "
            f"weights ({w_floor} B) plus one KV page — no plan from this "
            f"budget can serve; raise total_bytes or shrink the model set")

    want = kv_target + w_target
    if want > total_bytes:
        scale = total_bytes / want
        kv_target *= scale
        w_target = max(w_target * scale, float(w_floor))
        kv_target = min(kv_target, total_bytes - w_target)
    else:
        kv_target += total_bytes - want     # spare bytes buy KV headroom

    return DeviceBytesPlan(
        total_bytes=total_bytes,
        page_bytes=page_bytes,
        slab_bytes=slab_bytes,
        page_budget=max(int(kv_target // page_bytes), 1),
        slot_budget=max(int(math.ceil(w_target / slab_bytes)), 1),
        kv_target_bytes=kv_target,
        weight_target_bytes=w_target,
        resident_probability=p_res,
    )


def replan_split(specs: Sequence[WorkloadSpec], total_bytes: int, *,
                 page_bytes: int = DEFAULT_PAGE_BYTES,
                 slab_bytes: int = DEFAULT_SLAB_BYTES,
                 quantile: float = 0.95, window_s: float = 30.0,
                 residency_s: Optional[float] = None,
                 coresident: int = 1, seed: int = 0,
                 cached_token_fraction: float = 0.0) -> DeviceBytesPlan:
    """Windowed ONLINE re-run of the Eq. (1)-(2) split (DESIGN.md §8).

    Same machinery as :func:`split_device_budget`, parameterized for the
    elastic rebalancer's step-boundary cadence instead of offline
    provisioning: the ``specs`` come from the telemetry window (observed
    arrival rates + joint rows of recently completed requests), the
    Monte Carlo horizon is a few windows rather than an hour, and the
    trial count is small — the hysteresis/cooldown dampers absorb the
    extra estimator variance.  Deterministic for a fixed ``seed`` and
    fixed specs, which is what makes rebalance decisions replayable on a
    recorded trace.

    ``cached_token_fraction`` makes the re-plan prefix-cache aware
    (DESIGN.md §11): that fraction of observed prompt tokens was served
    from SHARED radix-tree pages at zero marginal page cost, so each
    spec's prompt demand is scaled down by it before the split — a
    cache-heavy window frees device bytes for the weights side instead
    of re-reserving KV the tree already holds once.
    """
    horizon = max(4.0 * window_s, 20.0)
    f = min(max(cached_token_fraction, 0.0), 0.95)
    if f > 0.0:
        specs = [dataclasses.replace(
            s, prompt_tokens=np.maximum(s.prompt_tokens * (1.0 - f), 1.0))
            for s in specs]
    return split_device_budget(
        specs, total_bytes, page_bytes=page_bytes, slab_bytes=slab_bytes,
        quantile=quantile, horizon_s=horizon,
        residency_s=residency_s if residency_s is not None
        else max(window_s, 1.0),
        n_trials=2, coresident=coresident, seed=seed)


def worst_case_weight_bytes(specs: Sequence[WorkloadSpec]) -> int:
    """Static baseline: every colocated model's FFN device-resident."""
    return sum(static_ffn_bytes(s.model) for s in specs)


def worst_case_pages(specs: Sequence[WorkloadSpec], page_bytes: int,
                     horizon_s: float = 3600.0) -> int:
    """Static-partition comparison point: per-model worst-case reservation.

    Each model reserves its own P100 concurrent demand — the 'reserve peak
    KV per model' baseline the paper argues wastes memory (§1).
    """
    total = 0
    for spec in specs:
        rng = np.random.default_rng(1234)
        u = active_kv_timeline(spec, rng, horizon_s)
        total += int(math.ceil(u.max() / page_bytes))
    return max(total, 1)
