"""Pallas TPU grouped expert GEMM (megablox-style) for the weights pool.

``out[i] = x[i] @ w[expert_of(i)]`` over token-sorted ``x`` with ragged
per-expert group sizes.  Grid ``(row_blocks, col_blocks, experts)`` with the
expert dimension innermost/sequential: each (i, j) output block accumulates
contributions from every expert whose row range overlaps row block i —
non-overlapping experts are skipped with ``pl.when``, so on hardware the
effective grid is ~(row_blocks + E) x col_blocks matmuls.

Group offsets arrive via scalar prefetch so both the skip predicate and the
row masking are resolved before the DMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_gemm_kernel(offsets_ref, x_ref, w_ref, o_ref, acc_ref, *,
                     block_n: int):
    i = pl.program_id(0)          # row block
    g = pl.program_id(2)          # expert (innermost, sequential)
    ne = pl.num_programs(2)

    @pl.when(g == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = offsets_ref[g]
    end = offsets_ref[g + 1]
    row0 = i * block_n

    @pl.when((start < row0 + block_n) & (end > row0))
    def _compute():
        x = x_ref[...].astype(jnp.float32)                   # [bn, K]
        w = w_ref[0].astype(jnp.float32)                     # [K, bm]
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_n, 1), 0)
        mask = (rows >= start) & (rows < end)                # [bn,1]
        acc_ref[...] += jnp.where(mask, x, 0.0) @ w

    @pl.when(g == ne - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def moe_gemm(x: jax.Array, w: jax.Array, group_sizes: jax.Array, *,
             block_n: int = 128, block_m: int = 128,
             interpret: bool = True) -> jax.Array:
    """x: [N,K] token-sorted; w: [E,K,M]; group_sizes: [E] -> [N,M]."""
    N, K = x.shape
    E, _, M = w.shape
    block_n = min(block_n, N)
    block_m = min(block_m, M)
    nn = pl.cdiv(N, block_n)
    nm = pl.cdiv(M, block_m)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(group_sizes).astype(jnp.int32)])

    kernel = functools.partial(_moe_gemm_kernel, block_n=block_n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nn, nm, E),
        in_specs=[
            pl.BlockSpec((block_n, K), lambda i, j, g, off: (i, 0)),
            pl.BlockSpec((1, K, block_m), lambda i, j, g, off: (g, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m),
                               lambda i, j, g, off: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_n, block_m), jnp.float32)],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, M), x.dtype),
        interpret=interpret,
    )(offsets, x, w)
