"""CrossPool core: the paper's contribution.

* planner      — Eq. (1)-(2) Monte Carlo P95/P99 pool sizing + plans,
                 plus the page_budget vs slot_budget device-bytes splitter
* virtualizer  — paged KV virtualization of one shared physical pool
* weight_pool  — expert-slab weights arena: cold-model activation/eviction
* admission    — queue-or-reject enforcement of the planned budget
* elastic      — online KV<->weights boundary rebalancer (host KV swap tier)
* pools        — KVCachePool / WeightsPool engine-level disaggregation
* split_exec   — proxy-layer split of attention vs FFN execution
* pipeline     — layer-wise two-batch pipeline scheduler (+ slab prefetch)
* control      — host-driven vs fused ("persistent kernel") decode steps
* placement    — StaticPartition / kvcached / CrossPool capacity models
"""
from repro.core.admission import AdmissionController, PendingRequest  # noqa: F401
from repro.core.elastic import ElasticRebalancer, RebalanceDecision  # noqa: F401
from repro.core.planner import (DeviceBytesPlan, PoolPlan,  # noqa: F401
                                WorkloadSpec, plan_pool, replan_split,
                                split_device_budget, worst_case_pages)
from repro.core.virtualizer import KVVirtualizer, OutOfPagesError  # noqa: F401
from repro.core.weight_pool import (OutOfSlabsError, WeightArena,  # noqa: F401
                                    slabs_for_config)
