"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp oracle.

CPU wall times of interpret-mode kernels are NOT TPU performance — the
meaningful numbers here are (a) correctness deltas and (b) the jnp-oracle
XLA:CPU timings that anchor the engine cost model.  TPU-side performance is
reasoned structurally in §Roofline from the lowered HLO.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.moe_gemm import moe_gemm
from repro.kernels.paged_attention import (contiguous_decode_attention,
                                           paged_decode_attention)
from repro.kernels.ssd_scan import ssd_scan


def _time(fn, *args, iters=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(csv=print) -> dict:
    out = {}
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # flash attention prefill
    B, S, H, KV, D = 1, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    t_ref = _time(jax.jit(lambda a, b, c: ref.flash_attention(a, b, c,
                                                              D ** -0.5)),
                  q, k, v)
    err = float(jnp.max(jnp.abs(
        flash_attention(q, k, v, scale=D ** -0.5)
        - ref.flash_attention(q, k, v, D ** -0.5))))
    csv(f"kernels,flash_attention,ref_us={t_ref:.0f},max_err={err:.2e}")
    out["flash"] = (t_ref, err)

    # contiguous decode
    B, T = 8, 2048
    q = jax.random.normal(ks[3], (B, 1, H, D), jnp.float32)
    ck = jax.random.normal(ks[4], (B, T, KV, D), jnp.float32)
    cv = jax.random.normal(ks[5], (B, T, KV, D), jnp.float32)
    lengths = jnp.full((B,), T, jnp.int32)
    t_ref = _time(jax.jit(lambda a, b, c, l: ref.decode_attention(
        a, b, c, l, D ** -0.5)), q, ck, cv, lengths)
    err = float(jnp.max(jnp.abs(
        contiguous_decode_attention(q, ck, cv, lengths, scale=D ** -0.5)
        - ref.decode_attention(q, ck, cv, lengths, D ** -0.5))))
    csv(f"kernels,decode_attention,ref_us={t_ref:.0f},max_err={err:.2e}")
    out["decode"] = (t_ref, err)

    # paged decode through a shuffled table
    ps, npages = 64, T // 64
    pages = jnp.stack(
        [ck.reshape(B, npages, ps, KV, D), cv.reshape(B, npages, ps, KV, D)],
        axis=3).reshape(B * npages, ps, 2, KV, D)
    table = jnp.arange(B * npages, dtype=jnp.int32).reshape(B, npages)
    t_ref = _time(jax.jit(lambda a, p, t, l: ref.paged_decode_attention(
        a, p, t, l, D ** -0.5)), q, pages, table, lengths)
    err = float(jnp.max(jnp.abs(
        paged_decode_attention(q, pages, table, lengths, scale=D ** -0.5)
        - ref.paged_decode_attention(q, pages, table, lengths, D ** -0.5))))
    csv(f"kernels,paged_decode,ref_us={t_ref:.0f},max_err={err:.2e}")
    out["paged"] = (t_ref, err)

    # grouped expert GEMM
    N, K, M, E = 512, 128, 256, 8
    x = jax.random.normal(ks[6], (N, K), jnp.float32)
    w = jax.random.normal(ks[7], (E, K, M), jnp.float32) / np.sqrt(K)
    sizes = jnp.full((E,), N // E, jnp.int32)
    t_ref = _time(jax.jit(lambda a, b, s: ref.moe_gemm(a, b, s)), x, w, sizes)
    err = float(jnp.max(jnp.abs(moe_gemm(x, w, sizes)
                                - ref.moe_gemm(x, w, sizes))))
    csv(f"kernels,moe_gemm,ref_us={t_ref:.0f},max_err={err:.2e}")
    out["moe_gemm"] = (t_ref, err)

    # SSD scan
    B2, S2, H2, P2, N2 = 2, 256, 4, 32, 32
    xs = jax.random.normal(ks[0], (B2, S2, H2, P2)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B2, S2, H2)))
    A = -jnp.exp(jax.random.normal(ks[2], (H2,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B2, S2, 1, N2)) * 0.5
    Cm = jax.random.normal(ks[4], (B2, S2, 1, N2)) * 0.5
    t_ref = _time(jax.jit(lambda *a: ref.ssd_scan(*a)), xs, dt, A, Bm, Cm)
    y_k, _ = ssd_scan(xs, dt, A, Bm, Cm, chunk=64)
    y_r, _ = ref.ssd_scan(xs, dt, A, Bm, Cm)
    err = float(jnp.max(jnp.abs(y_k - y_r)))
    csv(f"kernels,ssd_scan,ref_us={t_ref:.0f},max_err={err:.2e}")
    out["ssd"] = (t_ref, err)
    return out


if __name__ == "__main__":
    run()
