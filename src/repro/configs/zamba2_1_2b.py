"""zamba2-1.2b — hybrid Mamba2 + shared attention [arXiv:2411.15242; hf].

Assigned config: 38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  Zamba2 interleaves Mamba2 blocks with a *shared*
attention+MLP block applied periodically (the shared block is the
architecture's hallmark: one set of attention weights reused at several
depths).  We lay out 38 layers as 6 groups of (5 Mamba2 + 1 shared-attn
block) + 2 tail Mamba2 layers.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32_000,
    attention="gqa",
    ssm=SSMConfig(d_state=64, head_dim=64, n_groups=1, expand=2, conv_width=4),
    hybrid_groups=6,
    ssm_per_group=5,
    tail_ssm_layers=2,
    rope_theta=10_000.0,
    max_position=1_048_576,     # SSM layers are O(1)-state; attn is 6 blocks
    source="arXiv:2411.15242; hf",
)

SMOKE = CONFIG.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=256,
    ssm=SSMConfig(d_state=16, head_dim=16, n_groups=1, expand=2, conv_width=4),
    hybrid_groups=2, ssm_per_group=3, tail_ssm_layers=0, max_position=512,
)
