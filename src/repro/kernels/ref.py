"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth for the kernel allclose sweeps AND the
implementation used by the distributed dry-run (XLA-visible FLOPs for the
roofline; Pallas calls are opaque to ``cost_analysis``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Flash attention (prefill): causal GQA
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    scale: float) -> jax.Array:
    """Causal grouped attention. q:[B,S,H,D], k/v:[B,T,KV,D] -> [B,S,H,D]."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = (q_pos + (T - S)) >= k_pos           # causal with prefix offset
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, H, D)


# ---------------------------------------------------------------------------
# Decode attention over a contiguous cache with per-row lengths
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                     lengths: jax.Array, scale: float) -> jax.Array:
    """q:[B,1,H,D]; cache:[B,T,KV,D]; lengths:[B] valid prefix -> [B,1,H,D]."""
    B, _, H, D = q.shape
    T, KV = cache_k.shape[1], cache_k.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, D)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, cache_k,
                        preferred_element_type=jnp.float32) * scale
    mask = (jnp.arange(T)[None, :] < lengths[:, None])[:, None, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(cache_v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, cache_v)
    return out.reshape(B, 1, H, D)


# ---------------------------------------------------------------------------
# Paged decode attention (page-table indirection, the virtualizer's view)
# ---------------------------------------------------------------------------

def paged_decode_attention(q: jax.Array, kv_pages: jax.Array,
                           page_table: jax.Array, lengths: jax.Array,
                           scale: float) -> jax.Array:
    """Decode attention reading K/V through a page table.

    q:          [B,1,H,D]
    kv_pages:   [N_pages, page_size, 2, KV, D]  (the physical pool)
    page_table: [B, max_pages] int32 physical page ids (-1 = unmapped)
    lengths:    [B] tokens valid per sequence
    """
    B, _, H, D = q.shape
    page_size = kv_pages.shape[1]
    KV = kv_pages.shape[3]
    max_pages = page_table.shape[1]
    T = max_pages * page_size
    safe = jnp.maximum(page_table, 0)
    gathered = kv_pages[safe]                       # [B,max_pages,ps,2,KV,D]
    k = gathered[:, :, :, 0].reshape(B, T, KV, D)
    v = gathered[:, :, :, 1].reshape(B, T, KV, D)
    return decode_attention(q, k, v, lengths, scale)


def paged_mla_decode_attention(q: jax.Array, kv_pages: jax.Array,
                               page_table: jax.Array, lengths: jax.Array,
                               latent_dim: int, scale: float) -> jax.Array:
    """Absorbed-MLA decode attention through a page table.

    q:          [B,1,H, r+rp]  absorbed query [q_latent | q_rope]
    kv_pages:   [N_pages, page_size, r+rp]  (the pool's MLA-typed view)
    page_table: [B, max_pages] int32 physical page ids (-1 = unmapped)
    lengths:    [B] tokens valid per sequence
    Returns the latent context [B,1,H,latent_dim].
    """
    B, _, H, e = q.shape
    page_size = kv_pages.shape[1]
    max_pages = page_table.shape[1]
    T = max_pages * page_size
    safe = jnp.maximum(page_table, 0)
    rows = kv_pages[safe].reshape(B, T, e)          # [B,T, r+rp]
    scores = jnp.einsum("bshe,bte->bhst", q, rows,
                        preferred_element_type=jnp.float32) * scale
    mask = (jnp.arange(T)[None, :] < lengths[:, None])[:, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(rows.dtype)
    ctx = jnp.einsum("bhst,btr->bshr", w, rows[..., :latent_dim])
    return ctx


# ---------------------------------------------------------------------------
# Grouped expert GEMM (token-sorted MoE)
# ---------------------------------------------------------------------------

def moe_gemm(x: jax.Array, w: jax.Array, group_sizes: jax.Array) -> jax.Array:
    """Token-sorted grouped matmul.

    x: [N, K] tokens sorted by expert; w: [E, K, M]; group_sizes: [E] with
    sum == N.  Token i uses expert e(i) = bucket of i under group_sizes.
    """
    N = x.shape[0]
    E = w.shape[0]
    bounds = jnp.cumsum(group_sizes)
    expert_of = jnp.searchsorted(bounds, jnp.arange(N), side="right")
    w_tok = w[expert_of]                            # [N, K, M]
    return jnp.einsum("nk,nkm->nm", x, w_tok)


# ---------------------------------------------------------------------------
# Mamba2 SSD chunked scan
# ---------------------------------------------------------------------------

def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
             C_: jax.Array, chunk: int = 64,
             h0: Optional[jax.Array] = None,
             ) -> Tuple[jax.Array, jax.Array]:
    """Reference SSD via the *sequential* per-token recurrence.

    x:  [B,S,H,P]   inputs per head
    dt: [B,S,H]     discretization steps (post-softplus)
    A:  [H]         negative decay rates (A = -exp(A_log))
    B_: [B,S,G,N]   input projections (G groups broadcast onto H)
    C_: [B,S,G,N]   output projections
    h0: [B,H,P,N]   optional initial state
    Returns (y [B,S,H,P], h_final [B,H,P,N]).
    """
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=2)                # [B,S,H,N]
    Ch = jnp.repeat(C_, rep, axis=2)
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # [B,H,P],[B,H],[B,H,N],[B,H,N]
        dA = jnp.exp(dtt * A[None, :])              # [B,H]
        h = h * dA[..., None, None] + (dtt[..., None, None]
                                       * xt[..., :, None] * bt[..., None, :])
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Bh.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Ch.astype(jnp.float32), 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)      # [B,S,H,P]
    return y, h_final
