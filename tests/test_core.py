"""Core CrossPool tests: planner, virtualizer, admission, placement,
split execution, pipeline scheduler, control lowering."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import PAPER_COLOC_SET, get_smoke_config
from repro.core import planner as planner_mod
from repro.core.admission import AdmissionController, PendingRequest
from repro.core import placement
from repro.core.control import (HostDrivenStep, PagedFusedStep,
                                dispatch_count)
from repro.core.pipeline import InflightBatch, LayerPipelineScheduler
from repro.core.pools import build_pools
from repro.core import split_exec
from repro.core.virtualizer import KVVirtualizer, OutOfPagesError
from repro.models import build_model


def _coloc_models():
    return {n: get_smoke_config(n) for n in PAPER_COLOC_SET}


def _workload(cfg, rate=0.2, n=200, seed=0):
    rng = np.random.default_rng(seed)
    return planner_mod.WorkloadSpec(
        model=cfg,
        arrival_rate=rate,
        prompt_tokens=rng.integers(32, 512, n),
        output_tokens=rng.integers(16, 256, n),
        decode_time=rng.uniform(1.0, 20.0, n),
    )


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

class TestPlanner:
    def test_pool_quantile_below_worst_case(self):
        specs = [_workload(c, seed=i) for i, c in
                 enumerate(_coloc_models().values())]
        plan = planner_mod.plan_pool(specs, quantile=0.99, horizon_s=600,
                                     n_trials=3)
        worst = planner_mod.worst_case_pages(specs, plan.page_bytes,
                                             horizon_s=600)
        assert 0 < plan.pool_page_budget
        # pooled P99 of the aggregate must beat per-model worst-case sums
        assert plan.pool_page_budget <= worst

    def test_quantile_monotone(self):
        specs = [_workload(c, seed=i) for i, c in
                 enumerate(_coloc_models().values())]
        p95 = planner_mod.plan_pool(specs, quantile=0.95, horizon_s=300,
                                    n_trials=2)
        p99 = planner_mod.plan_pool(specs, quantile=0.99, horizon_s=300,
                                    n_trials=2)
        assert p95.pool_page_budget <= p99.pool_page_budget

    def test_type_classification(self):
        models = _coloc_models()
        specs = [_workload(c, seed=i) for i, c in enumerate(models.values())]
        plan = planner_mod.plan_pool(specs, horizon_s=120, n_trials=1,
                                     model_axis=16)
        mla = plan.per_model["minicpm3-4b"]
        assert mla.attention_type == "type2"
        assert mla.attention_strategy == "seq_sharded"

    def test_split_device_budget(self):
        """The device-bytes splitter: budgets track arrival rates, the
        largest model always fits, and both budgets respect the total."""
        models = list(_coloc_models().values())
        slab = 1 << 16
        specs_hot = [_workload(c, rate=1.0, seed=i)
                     for i, c in enumerate(models)]
        specs_cold = [_workload(c, rate=1e-6, seed=i)
                      for i, c in enumerate(models)]
        kw = dict(slab_bytes=slab, horizon_s=120.0, n_trials=2)
        total = 1 << 26
        hot = planner_mod.split_device_budget(specs_hot, total, **kw)
        cold = planner_mod.split_device_budget(specs_cold, total, **kw)
        from repro.core.weight_pool import slabs_for_config
        floor = max(slabs_for_config(c, slab) for c in models)
        for plan in (hot, cold):
            assert plan.slot_budget >= floor      # hot model must fit
            assert (plan.page_budget * plan.page_bytes
                    + plan.slot_budget * slab) <= total * 1.01
        # hot arrivals expect every model resident; cold ones only the floor
        assert hot.slot_budget > cold.slot_budget == floor
        assert all(p > 0.99 for p in hot.resident_probability.values())
        assert all(p < 0.01 for p in cold.resident_probability.values())
        assert planner_mod.worst_case_weight_bytes(specs_cold) > 0
        # a budget that cannot hold the largest model is a planning error,
        # not a silently unserveable plan
        with pytest.raises(ValueError):
            planner_mod.split_device_budget(specs_cold, floor * slab // 2,
                                            **kw)

    def test_eq1_linear_growth(self):
        """A single request's active KV grows linearly to O_p + O_d."""
        cfg = get_smoke_config("qwen3-14b")
        spec = planner_mod.WorkloadSpec(
            model=cfg, arrival_rate=1e-9,
            prompt_tokens=np.array([100]), output_tokens=np.array([50]),
            decode_time=np.array([10.0]))
        rng = np.random.default_rng(0)
        # force one arrival by direct construction
        kappa = cfg.kv_bytes_per_token()
        u = np.linspace(0, 9.99, 100)
        q = (100 + 50 * u / 10.0) * kappa
        assert q[0] == 100 * kappa
        assert math.isclose(q[-1], (100 + 50 * 0.999) * kappa, rel_tol=1e-6)


# ---------------------------------------------------------------------------
# virtualizer
# ---------------------------------------------------------------------------

class TestVirtualizer:
    def _virt(self, budget=256):
        return KVVirtualizer(_coloc_models(), page_budget=budget,
                             page_bytes=4096, allocate_device_pool=False)

    def test_heterogeneous_tokens_per_page(self):
        v = self._virt()
        tpps = {n: view.tokens_per_page for n, view in v.views.items()}
        # MLA caches far more tokens per page than GQA (the Type II win)
        assert tpps["minicpm3-4b"] > tpps["qwen3-moe-235b-a22b"]

    def test_map_unmap_roundtrip(self):
        v = self._virt()
        free0 = v.free_pages
        v.register_request(1, "qwen3-moe-235b-a22b", prompt_tokens=100)
        assert v.free_pages < free0
        v.extend_request(1, 50)
        v.release_request(1)
        assert v.free_pages == free0

    def test_budget_enforced(self):
        v = self._virt(budget=4)
        with pytest.raises(OutOfPagesError):
            v.register_request(1, "qwen3-moe-235b-a22b", prompt_tokens=10_000)

    @settings(max_examples=20, deadline=None)
    @given(ops=st.lists(st.tuples(st.sampled_from(list(PAPER_COLOC_SET)),
                                  st.integers(1, 300)), min_size=1,
                        max_size=20))
    def test_property_no_page_leak_or_double_free(self, ops):
        """Invariant: after releasing every request, all pages are free and
        no physical page is ever mapped twice."""
        v = self._virt(budget=4096)
        live = []
        for i, (model, toks) in enumerate(ops):
            try:
                v.register_request(i, model, toks)
                live.append(i)
            except OutOfPagesError:
                pass
        # no double-mapping
        mapped = [p for r in v.requests.values()
                  for t in r.tables for p in t]
        mapped += [p for r in v.requests.values() for p in r.state_pages]
        assert len(mapped) == len(set(mapped))
        for rid in live:
            v.release_request(rid)
        assert v.free_pages == 4096

    def test_register_atomic_on_oom(self):
        """A register that cannot be fully satisfied takes NOTHING."""
        v = self._virt(budget=8)
        name = "qwen3-moe-235b-a22b"
        tpp = v.views[name].tokens_per_page
        free0 = v.free_pages
        # 5 chunks/layer x 2 layers = 10 pages needed: the FIRST layer alone
        # (5) would fit, so a non-atomic mapper would leak it
        with pytest.raises(OutOfPagesError):
            v.register_request(1, name, prompt_tokens=5 * tpp)
        assert v.free_pages == free0
        assert 1 not in v.requests

    def test_extend_atomic_on_oom(self):
        """A failed extend leaves every layer table at its old equal length
        and the token count unchanged."""
        v = self._virt(budget=16)
        v.register_request(1, "qwen3-moe-235b-a22b", prompt_tokens=8)
        req = v.requests[1]
        lens0 = [len(t) for t in req.tables]
        toks0 = req.tokens
        mapped0 = v.mapped_pages
        with pytest.raises(OutOfPagesError):
            v.extend_request(1, 100_000)
        assert [len(t) for t in req.tables] == lens0
        assert len({len(t) for t in req.tables}) == 1   # equal lengths
        assert req.tokens == toks0
        assert v.mapped_pages == mapped0
        # the virtualizer stays fully usable: small extends still succeed
        v.extend_request(1, 1)
        v.release_request(1)
        assert v.free_pages == 16

    def test_batch_tables_incremental(self):
        """The device batch table is re-uploaded only when a row's mapping
        changes; in-page extends reuse the cached array."""
        v = self._virt(budget=256)
        name = "qwen3-moe-235b-a22b"
        v.register_request(0, name, prompt_tokens=4)
        t0 = v.batch_tables(name, [0, None], max_pages=4)
        tpp = v.views[name].tokens_per_page
        v.extend_request(0, 1)              # still inside the first page
        t1 = v.batch_tables(name, [0, None], max_pages=4)
        assert t1 is t0                      # cached device array reused
        v.extend_request(0, tpp)             # crosses into a new page
        t2 = v.batch_tables(name, [0, None], max_pages=4)
        assert t2 is not t0
        tab = np.asarray(t2)
        assert tab.shape == (v.views[name].n_kv_layers, 2, 4)
        assert (tab[:, 1, :] == -1).all()    # empty slot stays unmapped
        assert (tab[:, 0, :2] >= 0).all()

    def test_batch_tables_not_stale_after_rid_reuse(self):
        """Releasing and re-registering the SAME request id must not serve
        the stale cached table (the new mapping owns different pages)."""
        v = self._virt(budget=64)
        name = "qwen3-moe-235b-a22b"
        v.register_request(1, name, prompt_tokens=4)
        t0 = np.asarray(v.batch_tables(name, [1], max_pages=2))
        v.release_request(1)
        v.register_request(99, name, prompt_tokens=4)   # takes the freed pages
        v.register_request(1, name, prompt_tokens=4)    # same id, new pages
        t1 = np.asarray(v.batch_tables(name, [1], max_pages=2))
        expect = np.full_like(t1, -1)
        for layer, tab in enumerate(v.requests[1].tables):
            expect[layer, 0, : len(tab)] = tab
        np.testing.assert_array_equal(t1, expect)
        assert not np.array_equal(t1, t0)

    def test_device_pool_write_read(self):
        models = {"minicpm3-4b": get_smoke_config("minicpm3-4b")}
        v = KVVirtualizer(models, page_budget=32, page_bytes=1024)
        v.register_request(0, "minicpm3-4b", prompt_tokens=3)
        view = v.views["minicpm3-4b"]
        kv = jnp.arange(3 * view.per_token_elems, dtype=jnp.bfloat16
                        ).reshape(3, *view.kv_shape)
        v.write_tokens("minicpm3-4b", layer=0, request_id=0, start_token=0,
                       kv=kv)
        typed = v.typed_pages("minicpm3-4b")
        table = v.page_table_array([0], layer=0, max_pages=4)
        page0 = int(table[0, 0])
        got = typed[page0, :3]
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(kv, np.float32))


# ---------------------------------------------------------------------------
# admission
# ---------------------------------------------------------------------------

class TestAdmission:
    def test_queue_then_drain(self):
        v = KVVirtualizer(_coloc_models(), page_budget=64, page_bytes=4096,
                          allocate_device_pool=False)
        ac = AdmissionController(v, max_queue_per_model=4)
        r0 = PendingRequest(0, "qwen3-moe-235b-a22b", 400, 0, 0.0)
        assert ac.offer(r0, 0.0) == "admitted"
        # flood until queueing starts
        outcomes = [ac.offer(PendingRequest(i, "qwen3-moe-235b-a22b", 400, 0,
                                            0.0), 0.0)
                    for i in range(1, 12)]
        assert "queued" in outcomes
        assert ac.stats.rejected + ac.stats.queued + ac.stats.admitted == 12
        # finishing the first request lets queued ones in
        v.release_request(0)
        admitted = ac.drain(now=1.0)
        assert len(admitted) >= 1

    def test_never_interrupts_active(self):
        """Active requests keep pages even when the queue is full."""
        v = KVVirtualizer(_coloc_models(), page_budget=32, page_bytes=4096,
                          allocate_device_pool=False)
        ac = AdmissionController(v, max_queue_per_model=1)
        assert ac.offer(PendingRequest(0, "minicpm3-4b", 200, 0, 0.0),
                        0.0) == "admitted"
        pages_held = v.mapped_pages
        for i in range(1, 8):
            ac.offer(PendingRequest(i, "minicpm3-4b", 5000, 0, 0.0), 0.0)
        assert v.requests[0] is not None
        assert v.mapped_pages == pages_held  # nothing revoked


# ---------------------------------------------------------------------------
# placement capacity models
# ---------------------------------------------------------------------------

class TestPlacement:
    def test_fig2_fractions(self):
        # MHA (4 heads, 4 gpus) -> 1; GQA(2) -> 1/2; MQA(1) -> 1/4
        assert placement.kv_availability_fraction(4, 4, False) == 1.0
        assert placement.kv_availability_fraction(2, 4, False) == 0.5
        assert placement.kv_availability_fraction(1, 4, False) == 0.25
        assert placement.kv_availability_fraction(1, 4, True) == 1.0

    def test_crosspool_beats_baselines_on_visible_kv(self):
        """Paper Fig. 6 story, with full-config param counts and hardware
        sized like the testbed (weights ~= 77% of HBM, as in §5.1)."""
        from repro.configs import get_config
        models = [get_config(n) for n in PAPER_COLOC_SET]
        hw0 = placement.Hardware(n_gpus=5, hbm_bytes=1.0)
        w_total = sum(c.param_counts()["total"] * 2 for c in models)
        hw = placement.Hardware(n_gpus=5, hbm_bytes=w_total / 5 / 0.77)
        static = placement.static_partition(models, hw, [2, 2, 1])
        kvc = placement.kvcached(models, hw)
        xp = placement.crosspool(models, hw, kv_gpus=1)
        # The paper's claims (§2.2, Fig. 2, Fig. 6):
        # (1) Type II (MLA) requests see a small fraction of the elastic
        #     pool under DP attention; crosspool exposes the whole pool.
        mla = models[2]           # minicpm3 (MLA) = the Type II headline
        assert xp.per_model[mla.name][0] > 3 * kvc.per_model[mla.name][0]
        assert xp.max_context(mla) > kvc.max_context(mla)
        # (2) static partition cannot fit the largest model's weights in its
        #     slice, while every crosspool model still serves long context.
        assert min(static.max_context(c) for c in models) \
            < min(xp.max_context(c) for c in models)


# ---------------------------------------------------------------------------
# split execution + pools + pipeline + control lowering
# ---------------------------------------------------------------------------

def _pooled_setup(names=("qwen3-moe-235b-a22b", "minicpm3-4b"),
                  page_budget=256):
    models = {n: get_smoke_config(n).replace(dtype="float32") for n in names}
    params = {n: build_model(c).init(jax.random.PRNGKey(i))
              for i, (n, c) in enumerate(models.items())}
    kv_pool, w_pool, pooled = build_pools(
        models, params, page_budget=page_budget, page_bytes=4096,
        pool_dtype=jnp.float32)
    return models, params, kv_pool, w_pool, pooled


def _map_and_seed(virt, name, model, params, rids, seq, max_len, B=None):
    """Register ``rids`` in the pool and seed their pages from a dense
    prefill; returns (dense cache, per-request lengths vector)."""
    B = B or len(rids)
    tokens = jnp.zeros((B, seq), jnp.int32)
    cache = model.init_cache(B, max_len)
    _, cache = model.prefill(params[name], tokens, cache)
    for row, rid in enumerate(rids):
        virt.register_request(rid, name, seq)
        virt.write_prompt_from_cache(name, rid, cache, seq, batch_index=row)
    return cache, jnp.full((len(rids),), seq, jnp.int32)


def _tables_for(virt, name, rids, max_len):
    """Extend each request by one token (the decode write) and return the
    [L,B,P] batch page table."""
    view = virt.views[name]
    max_pages = max(1, math.ceil(max_len / view.tokens_per_page))
    for rid in rids:
        virt.extend_request(rid, 1)
    return virt.batch_tables(name, list(rids), max_pages)


class TestSplitExec:
    def test_split_merge_roundtrip(self):
        cfg = get_smoke_config("qwen3-moe-235b-a22b")
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        kv_t, w_t = split_exec.split_params(params, cfg)
        merged = merge = split_exec.merge_params(kv_t, w_t)
        assert jax.tree.structure(merged) == jax.tree.structure(params)
        # FFN bytes dominate for the MoE model (paper Table 1)
        w_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(w_t))
        kv_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(kv_t))
        assert w_bytes > kv_bytes

    def test_host_driven_paged_matches_fused_dense(self):
        """The disaggregated per-layer path, serving KV from the SHARED
        paged pool, must equal the fused dense-cache model."""
        models, params, kv_pool, w_pool, pooled = _pooled_setup(
            ("qwen3-moe-235b-a22b",))
        name = "qwen3-moe-235b-a22b"
        cfg = models[name]
        model = build_model(cfg)
        virt = kv_pool.virtualizer
        B, seq, max_len = 2, 8, 16
        cache, lengths = _map_and_seed(virt, name, model, params,
                                       rids=(0, 1), seq=seq, max_len=max_len)
        next_tok = jnp.zeros((B,), jnp.int32)
        want, _ = model.decode_step(params[name], next_tok, cache,
                                    jnp.int32(seq))

        tables = _tables_for(virt, name, (0, 1), max_len)
        devs = jax.devices()
        step = HostDrivenStep(pooled[name], devs[0], devs[-1])
        got, virt.pool = step(next_tok, virt.pool, tables, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_fused_step_consumes_pooled_params(self):
        """PagedFusedStep (lowering=ON) runs the same pooled param split
        over the pool and matches the per-layer host-driven path."""
        models, params, kv_pool, w_pool, pooled = _pooled_setup(
            ("minicpm3-4b",))
        name = "minicpm3-4b"
        model = build_model(models[name])
        virt = kv_pool.virtualizer
        B, seq, max_len = 2, 8, 16
        cache, lengths = _map_and_seed(virt, name, model, params,
                                       rids=(0, 1), seq=seq, max_len=max_len)
        want, _ = model.decode_step(params[name], jnp.zeros((B,), jnp.int32),
                                    cache, jnp.int32(seq))
        tables = _tables_for(virt, name, (0, 1), max_len)
        fused = PagedFusedStep(pooled[name])
        got, virt.pool = fused(jnp.zeros((B,), jnp.int32), virt.pool,
                               tables, lengths)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_dispatch_count_accounting(self):
        assert dispatch_count(48, fused=True) == 1
        assert dispatch_count(48, fused=False) == 2 + 48 * 5


class TestPipeline:
    def test_two_batch_interleave_and_early_exit(self):
        models, params, kv_pool, w_pool, pooled = _pooled_setup()
        virt = kv_pool.virtualizer
        devs = jax.devices()
        sched = LayerPipelineScheduler(pooled, devs[0], devs[-1])
        batches = []
        B, seq, max_len = 2, 8, 16
        for i, (name, cfg) in enumerate(models.items()):
            model = build_model(cfg)
            rids = (10 * i, 10 * i + 1)
            _, lengths = _map_and_seed(virt, name, model, params,
                                       rids=rids, seq=seq, max_len=max_len)
            tables = _tables_for(virt, name, rids, max_len)
            batches.append(InflightBatch(
                batch_id=i, model=name, tokens=jnp.zeros((B,), jnp.int32),
                page_tables=tables, lengths=lengths))
        done, virt.pool = sched.run(batches, virt.pool, max_inflight=2)
        assert len(done) == 2
        assert all(b.logits is not None and b.logits.shape[0] == 2
                   for b in done)
        # models have different layer counts (2 vs 2 here) but the schedule
        # must still alternate pools heavily
        assert sched.overlap_fraction() > 0.4

    def test_pipeline_matches_serial(self):
        models, params, kv_pool, w_pool, pooled = _pooled_setup(
            ("minicpm3-4b",))
        name = "minicpm3-4b"
        model = build_model(models[name])
        virt = kv_pool.virtualizer
        B, seq, max_len = 2, 8, 16
        devs = jax.devices()

        def make_batch(bid, base_rid):
            rids = (base_rid, base_rid + 1)
            _, lengths = _map_and_seed(virt, name, model, params,
                                       rids=rids, seq=seq, max_len=max_len)
            tables = _tables_for(virt, name, rids, max_len)
            return InflightBatch(
                batch_id=bid, model=name, tokens=jnp.zeros((B,), jnp.int32),
                page_tables=tables, lengths=lengths)

        s1 = LayerPipelineScheduler(pooled, devs[0], devs[-1])
        out_pipe, virt.pool = s1.run(
            [make_batch(0, 0), make_batch(1, 10)], virt.pool, max_inflight=2)
        s2 = LayerPipelineScheduler(pooled, devs[0], devs[-1])
        out_serial, virt.pool = s2.run_serial(
            [make_batch(0, 20), make_batch(1, 30)], virt.pool)
        a = sorted(out_pipe, key=lambda b: b.batch_id)
        b = sorted(out_serial, key=lambda b: b.batch_id)
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x.logits),
                                       np.asarray(y.logits),
                                       rtol=1e-5, atol=1e-5)

    def test_refill_on_early_exit(self):
        models, params, kv_pool, w_pool, pooled = _pooled_setup(
            ("minicpm3-4b",))
        name = "minicpm3-4b"
        model = build_model(models[name])
        virt = kv_pool.virtualizer
        B, seq, max_len = 1, 4, 8
        pending = []
        for i in range(4):
            _, lengths = _map_and_seed(virt, name, model, params,
                                       rids=(i,), seq=seq, max_len=max_len)
            tables = _tables_for(virt, name, (i,), max_len)
            pending.append(InflightBatch(
                batch_id=i, model=name, tokens=jnp.zeros((B,), jnp.int32),
                page_tables=tables, lengths=lengths))
        devs = jax.devices()
        sched = LayerPipelineScheduler(pooled, devs[0], devs[-1])
        first_two, rest = pending[:2], pending[2:]

        def refill():
            return rest.pop(0) if rest else None

        done, virt.pool = sched.run(first_two, virt.pool, refill=refill,
                                    max_inflight=2)
        assert len(done) == 4
