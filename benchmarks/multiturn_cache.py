"""Multi-turn context caching: radix-tree prefix reuse vs cache-off.

The prefix-cache claim (DESIGN.md §11): in multi-turn chat each turn's
prompt is the previous turn verbatim plus a small delta, so with the
radix tree ON the engine maps the cached prefix pages and prefills ONLY
the uncached suffix — warm-turn TTFT drops to the suffix pass while the
cache-off engine re-prefills the whole conversation every turn.  Token
streams are bit-exact either way (test-gated in
``tests/test_prefix_cache.py``); this benchmark measures the latency
and compute win at EQUAL DEVICE BYTES (identical page/slab budgets —
the cached pages come out of the same shared pool).

Per model (served alone, sequential turns — turn N+1's prompt extends
turn N's, so each turn must finish before the next submits):

  * warm-turn TTFT — wall-clock submit -> first streamed token, turns
    >= 1 of each measured conversation (turn 0 is cold in BOTH
    engines).  Guarded metric: the worst MoE-model warm-TTFT ratio
    cache-on/cache-off; the acceptance bound is <= 0.5x (the MLA model
    rides along unguarded);
  * prefill tokens computed — the cache-on engine's suffix lengths vs
    the cache-off engine's full prompts.  Prefill FLOPs are linear in
    computed rows for the FFN/MoE stages (the dominant cost), so the
    saved-token fraction is the prefill-FLOPs-saved figure.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import PAPER_COLOC_SET, get_smoke_config
from repro.configs.base import CacheConfig, EngineConfig, MLAConfig
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime.request import Request

PROMPT0 = 400                 # turns stay in ONE prefill bucket (512)
MAX_NEW = 4
DELTA = 8                     # user-turn delta appended after each reply
TURNS = 3
CONVS = 3                     # measured conversations
WARM_CONVS = 1                # compile-covering warmup conversations
PAGE_BUDGET = 4096
PAGE_BYTES = 4096
SLAB_BYTES = 65536
MAX_CTX = 512
MOE_TARGETS = tuple(n for n in PAPER_COLOC_SET
                    if get_smoke_config(n).is_moe)


def _bench_config(name: str):
    """Compute-realistic variant of a smoke config.

    At the tier-1 smoke width (d_model=64, 2 layers) a prefill turn is
    host-dispatch-bound — both engines pay the same per-layer dispatch
    overhead and the saved prefill FLOPs are invisible.  The cache's
    claim is about the compute-dominated regime, so this benchmark
    widens the same architectures (more layers, wider d_model/FFN)
    until the full-prompt pass costs real device time; the toy width
    stays the default everywhere else.
    """
    cfg = get_smoke_config(name).replace(dtype="float32")
    if cfg.attention == "mla":
        return cfg.replace(d_model=512, n_heads=8, head_dim=64, d_ff=1024,
                           n_layers=4,
                           mla=MLAConfig(q_lora_rank=256, kv_lora_rank=128,
                                         qk_nope_head_dim=64,
                                         qk_rope_head_dim=32,
                                         v_head_dim=64))
    return cfg.replace(d_model=512, n_heads=16, head_dim=32, n_kv_heads=4,
                       d_ff=512, n_layers=4)


def _engine(name: str, cache_on: bool) -> CrossPoolEngine:
    models = {name: _bench_config(name)}
    return CrossPoolEngine(
        models, page_budget=PAGE_BUDGET, page_bytes=PAGE_BYTES,
        slab_bytes=SLAB_BYTES, max_batch=2, max_ctx=MAX_CTX,
        config=EngineConfig(mode=EngineMode(pipeline=True, lowering=True),
                            cache=CacheConfig(enabled=cache_on)),
        seed=0)


def _conversation(engine, name, base_id: int, seed: int):
    """One sequential multi-turn conversation; returns per-turn
    (wall TTFT, prompt tokens, cached tokens) and the streams."""
    cfg = _bench_config(name)
    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, PROMPT0).astype(np.int32)
    turns, streams = [], []
    for t in range(TURNS):
        req = Request(base_id + t, name, len(prompt), MAX_NEW,
                      engine.now, prompt_ids=prompt.copy())
        t0 = time.perf_counter()
        h = engine.submit(req)
        assert h.admission == "admitted", h.admission
        ttft = None
        while not h.done:
            engine.step()
            if ttft is None and h.tokens:
                ttft = time.perf_counter() - t0
        out = list(req.output_ids)
        streams.append(out)
        turns.append((ttft, len(prompt), h.cached_tokens))
        delta = rng.integers(0, cfg.vocab_size, DELTA).astype(np.int32)
        prompt = np.concatenate([prompt, np.asarray(out, np.int32), delta])
    return turns, streams


def _serve(name: str, cache_on: bool):
    engine = _engine(name, cache_on)
    # warmup conversations compile every shape this benchmark touches
    # (full-bucket prefill, the suffix pass, decode) and stream the
    # arena slabs resident — same lengths, different token content
    for w in range(WARM_CONVS):
        _conversation(engine, name, 900_000 + 100 * w, seed=1_000 + w)
    convs = [_conversation(engine, name, 1_000 * c, seed=c)
             for c in range(CONVS)]
    warm_ttfts = [ttft for turns, _ in convs
                  for ttft, _, _ in turns[1:]]
    prefilled = sum(p - c for turns, _ in convs for _, p, c in turns)
    cached = sum(c for turns, _ in convs for _, p, c in turns)
    streams = [s for _, ss in convs for s in ss]
    if cache_on:
        snap = engine.cache.snapshot()
        assert snap["hits"] > 0, "warm turns never hit the prefix cache"
    # median over the 2 x CONVS warm turns: robust to a single
    # scheduler-noise outlier on a shared machine
    return {"warm_ttft": float(np.median(warm_ttfts)),
            "prefill_tokens_computed": prefilled,
            "cached_tokens": cached, "streams": streams}


def run(csv=print) -> dict:
    out = {}
    moe_ratios = []
    for name in PAPER_COLOC_SET:
        off = _serve(name, cache_on=False)
        on = _serve(name, cache_on=True)
        assert on["streams"] == off["streams"], \
            f"{name}: cache-on streams diverged from cache-off"
        ratio = on["warm_ttft"] / off["warm_ttft"]
        total = on["prefill_tokens_computed"] + on["cached_tokens"]
        flops_saved = on["cached_tokens"] / total
        guarded = name in MOE_TARGETS
        csv(f"multiturn,{name},off_warm_ttft_ms="
            f"{off['warm_ttft'] * 1e3:.3f},on_warm_ttft_ms="
            f"{on['warm_ttft'] * 1e3:.3f},on_over_off={ratio:.3f},"
            f"prefill_flops_saved={flops_saved:.3f},guarded={guarded}")
        out[f"{name}_off_warm_ttft_s"] = off["warm_ttft"]
        out[f"{name}_on_warm_ttft_s"] = on["warm_ttft"]
        out[f"{name}_prefill_flops_saved"] = flops_saved
        if guarded:
            moe_ratios.append(ratio)
            # the acceptance bound: warm-turn TTFT <= 0.5x cache-off
            assert ratio <= 0.5, \
                (f"{name}: cache-on warm TTFT {on['warm_ttft']:.6f}s "
                 f"is not 2x better than {off['warm_ttft']:.6f}s")
    out["ttft_warm_ratio"] = max(moe_ratios)
    return out


if __name__ == "__main__":
    run()
