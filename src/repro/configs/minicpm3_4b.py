"""minicpm3-4b — dense decoder with MLA [hf:openbmb/MiniCPM3-4B; hf].

Assigned config: 62L d_model=2560 40H d_ff=6400 vocab=73448, MLA attention.
MLA dims per the HF config: q_lora_rank=768, kv_lora_rank=256,
qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64.

This is the paper's Type II flagship: the KV cache stores only
(kv_lora_rank + qk_rope_head_dim) = 288 scalars per token per layer,
independent of the 40 query heads — exactly the KV-head-limited case where
monolithic DP-attention placement wastes capacity (paper §2.2, Fig. 2).
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,      # nominal (assignment lists kv=40); MLA overrides KV layout
    head_dim=64,
    d_ff=6400,
    vocab_size=73_448,
    attention="mla",
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    rope_theta=10_000.0,
    max_position=32_768 * 32,   # long-context serving target via rope scaling
    source="hf:openbmb/MiniCPM3-4B; hf",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
    vocab_size=256,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    max_position=512,
)
