"""Turn dry-run JSONL records into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import argparse
import json
from typing import Dict, List


def load(path: str) -> List[Dict]:
    out = []
    with open(path) as f:
        for line in f:
            out.append(json.loads(line))
    return out


def fmt_bytes(n) -> str:
    if n is None:
        return "-"
    return f"{n / 2 ** 30:.2f}"


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    return f"{x:.2e}"


def dryrun_table(records: List[Dict]) -> str:
    lines = [
        "| arch | shape | strategy | lower/compile s | args GiB/dev | "
        "temp GiB/dev | collectives (count) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | SKIP | - | - | "
                         f"{r['reason'][:48]} |")
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r.get('strategy')} "
                         f"| **FAIL** | - | - | {r.get('error', '')[:48]} |")
            continue
        m, rf = r["memory"], r["roofline"]
        coll = rf["collective_breakdown"]
        abbrev = {"all-gather": "ag", "all-reduce": "ar",
                  "reduce-scatter": "rs", "all-to-all": "a2a",
                  "collective-permute": "cp"}
        parts = [f"{abbrev.get(k, k)}:{v // 2 ** 20}M"
                 for k, v in coll.items()
                 if k != "count" and v > 0]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['strategy']} "
            f"| {r['lower_s']:.0f}/{r['compile_s']:.0f} "
            f"| {fmt_bytes(m['argument_bytes'])} "
            f"| {fmt_bytes(m['temp_bytes'])} "
            f"| {' '.join(parts) or '0'} ({coll['count']}) |")
    return "\n".join(lines)


def roofline_table(records: List[Dict]) -> str:
    lines = [
        "| arch | shape | strat | t_compute | t_memory | t_collective | "
        "dominant | useful-FLOPs | roofline-frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if not r.get("ok"):
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['strategy'][:5]} "
            f"| {fmt_s(rf['t_compute'])} | {fmt_s(rf['t_memory'])} "
            f"| {fmt_s(rf['t_collective'])} | **{rf['dominant']}** "
            f"| {rf['useful_flops_ratio'] * 100:.0f}% "
            f"| {rf['roofline_fraction'] * 100:.1f}% |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("jsonl")
    ap.add_argument("--kind", choices=["dryrun", "roofline"],
                    default="dryrun")
    args = ap.parse_args()
    records = load(args.jsonl)
    if args.kind == "dryrun":
        print(dryrun_table(records))
    else:
        print(roofline_table(records))


if __name__ == "__main__":
    main()
