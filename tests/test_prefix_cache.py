"""Prefix-cache tests (DESIGN.md §11).

Two layers:

* accounting properties — random insert / share / shed / fault / release
  interleavings over the radix tree + virtualizer NEVER leak a page,
  alias a freed page, or leave a refcount out of sync with the actual
  holder set (hypothesis-driven; the fallback sweep runs hermetically);
* end-to-end parity — a multi-turn conversation served with the cache ON
  produces bit-identical token streams to the same conversation with the
  cache OFF, in BOTH lowering modes, including after the cached prefix
  was forcibly shed to the host swap tier and faulted back.
"""
import collections

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.configs.base import CacheConfig, EngineConfig
from repro.core.prefix_cache import PrefixCache
from repro.core.virtualizer import KVVirtualizer, OutOfPagesError
from repro.runtime.engine import CrossPoolEngine, EngineMode
from repro.runtime.request import Request

MOE = "qwen3-moe-235b-a22b"
MLA = "minicpm3-4b"


# ---------------------------------------------------------------------------
# accounting properties (host-side only: no device pool)
# ---------------------------------------------------------------------------

def _models():
    return {n: get_smoke_config(n) for n in (MOE, MLA)}


def _check_invariants(virt: KVVirtualizer, cache: PrefixCache) -> None:
    """No leak, no alias, refcounts == holder counts, page conservation."""
    held = collections.Counter()
    tree_dev = set()
    for node in cache._walk():
        for p in node.pages:
            if p >= 0:
                held[p] += 1
                tree_dev.add(p)
    # the tree's device-page set is exactly its nodes' device entries
    assert tree_dev == cache._device_pages
    for rp in virt.requests.values():
        for tab in rp.tables:
            for p in tab:
                if p >= 0:
                    held[p] += 1
        for p in rp.state_pages:
            held[p] += 1
    free = virt.free_list
    assert len(set(free)) == len(free), "duplicate free-list entry"
    assert not (set(free) & set(held)), "freed page still held"
    # every held page's refcount equals the number of live holders
    for p, n in held.items():
        assert virt.page_refs(p) == n, (p, n, virt.page_refs(p))
    # conservation: every budgeted page is free xor held (exactly once)
    assert len(free) + len(held) == virt.page_budget


def _ids(rng, base, n):
    """Prompts share a common base (forced prefix overlap) + random tail."""
    n = max(n, 1)
    shared = min(n, len(base))
    tail = rng.integers(0, 4, n - shared).astype(np.int32)
    return np.concatenate([base[:shared], tail])


_OPS = st.lists(
    st.tuples(st.sampled_from(["produce", "share", "shed", "evict",
                               "release"]),
              st.integers(0, 2 ** 30)),
    min_size=5, max_size=40)


class TestAccountingProperties:
    @settings(max_examples=20, deadline=None)
    @given(_OPS, st.integers(0, 2 ** 30))
    def test_never_leaks_or_aliases(self, ops, seed):
        rng = np.random.default_rng(seed)
        virt = KVVirtualizer(_models(), page_budget=64, page_bytes=4096,
                             allocate_device_pool=False)
        cache = PrefixCache(virt, CacheConfig(enabled=True,
                                              max_pages_fraction=0.4))
        base = rng.integers(0, 4, 48).astype(np.int32)
        live = []          # request ids registered and not yet released
        next_rid = [0]

        def produce(arg):
            model = (MOE, MLA)[arg % 2]
            ids = _ids(rng, base, 4 + arg % 29)
            rid = next_rid[0]
            next_rid[0] += 1
            try:
                virt.register_request(rid, model, len(ids))
            except OutOfPagesError:
                return
            live.append(rid)
            tpp = virt.views[model].tokens_per_page
            rp = virt.requests[rid]
            L = virt.views[model].n_kv_layers
            n_chunks = -(-len(ids) // tpp)
            cache.insert(model, 64, ids,
                         [[rp.tables[l][c] for l in range(L)]
                          for c in range(n_chunks)])

        def share(arg):
            model = (MOE, MLA)[arg % 2]
            ids = _ids(rng, base, 4 + arg % 29)
            matched, nodes = cache.match_prefix(model, 64, ids)
            fork = min(matched, len(ids) - 1)
            tpp = virt.views[model].tokens_per_page
            n_full, rem = fork // tpp, fork % tpp
            rid = next_rid[0]
            next_rid[0] += 1
            try:
                if fork > 0:
                    cache.fault_chunks(nodes[:n_full + (1 if rem else 0)])
                    virt.register_request_with_prefix(
                        rid, model, len(ids),
                        [n.pages for n in nodes[:n_full]],
                        nodes[n_full].pages if rem else None)
                else:
                    virt.register_request(rid, model, len(ids))
            except OutOfPagesError:
                return
            live.append(rid)

        def release(arg):
            if live:
                virt.release_request(live.pop(arg % len(live)))

        for op, arg in ops:
            if op == "produce":
                produce(arg)
            elif op == "share":
                share(arg)
            elif op == "shed":
                cache.shed(1 + arg % 8)
            elif op == "evict":
                cache.evict(1 + arg % 8)
            else:
                release(arg)
            _check_invariants(virt, cache)
        # teardown: releasing every request and dropping the whole tree
        # returns EVERY page to the free list (zero leak at quiescence)
        for rid in live:
            virt.release_request(rid)
        cache.evict(virt.page_budget)
        _check_invariants(virt, cache)
        assert cache.device_pages_held == 0
        assert virt.free_pages + virt.swapped_now == virt.page_budget

    def test_shed_keeps_nodes_matchable(self):
        """Second-chance shed moves pages to the swap tier but the chunk
        stays in the tree and faults back on the next match."""
        virt = KVVirtualizer(_models(), page_budget=64, page_bytes=4096,
                             allocate_device_pool=False)
        cache = PrefixCache(virt, CacheConfig(enabled=True))
        ids = np.arange(16, dtype=np.int32)
        virt.register_request(1, MOE, len(ids))
        rp = virt.requests[1]
        L = virt.views[MOE].n_kv_layers
        tpp = virt.views[MOE].tokens_per_page
        n_chunks = -(-len(ids) // tpp)
        cache.insert(MOE, 64, ids,
                     [[rp.tables[l][c] for l in range(L)]
                      for c in range(n_chunks)])
        virt.release_request(1)
        held = cache.device_pages_held
        assert held > 0
        assert cache.shed(held) == held
        assert cache.device_pages_held == 0
        matched, nodes = cache.match_prefix(MOE, 64, ids)
        assert matched == len(ids)           # swapped chunks still match
        assert all(n.swapped for n in nodes)
        cache.fault_chunks(nodes)
        assert cache.device_pages_held == held   # bit-exact fault-in
        assert not any(n.swapped for n in nodes)
        _check_invariants(virt, cache)


# ---------------------------------------------------------------------------
# end-to-end multi-turn parity (real compute, smoke models)
# ---------------------------------------------------------------------------

_STREAMS = {}     # (model, lowering, cache_on, shed) -> list of streams


def _multiturn(model, lowering, cache_on, shed=False):
    """Serve a 3-turn conversation (each turn = previous prompt + output +
    delta, all in one prefill bucket); returns the per-turn token streams."""
    key = (model, lowering, cache_on, shed)
    if key in _STREAMS:
        return _STREAMS[key]
    models = {model: get_smoke_config(model).replace(dtype="float32")}
    cfg = EngineConfig(mode=EngineMode(pipeline=False, lowering=lowering),
                       cache=CacheConfig(enabled=cache_on))
    eng = CrossPoolEngine(models, page_budget=4096, page_bytes=4096,
                          max_batch=2, max_ctx=32, config=cfg, seed=0)
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, models[model].vocab_size, 17).astype(np.int32)
    streams, hits = [], []
    for turn in range(3):
        req = Request(request_id=turn, model=model,
                      prompt_tokens=len(prompt), max_new_tokens=3,
                      arrival_time=0.0, prompt_ids=prompt.copy())
        h = eng.submit(req)
        assert h.admission == "admitted"
        for _ in range(30):
            eng.step()
            if h.done:
                break
        assert h.done
        streams.append(list(req.output_ids))
        hits.append((h.cache_hit, h.cached_tokens))
        if shed and eng.cache is not None:
            # force the whole tree to the host swap tier between turns:
            # the next hit must fault back bit-exactly (second chance)
            eng.cache.shed(eng.cache.device_pages_held)
        delta = rng.integers(0, models[model].vocab_size, 2).astype(np.int32)
        prompt = np.concatenate(
            [prompt, np.asarray(streams[-1], np.int32), delta])
    if cache_on:
        # warm turns actually hit, and the hit covers the full prior turn
        assert hits[0] == (False, 0)
        assert hits[1][0] and hits[1][1] == 17
        assert hits[2][0] and hits[2][1] == 22
        if shed:
            assert eng.cache.faulted_pages > 0, \
                "forced shed should exercise the fault-back path"
    _STREAMS[key] = streams
    return streams


class TestMultiTurnParity:
    def test_fused_moe_bit_exact(self):
        assert _multiturn(MOE, True, True) == _multiturn(MOE, True, False)

    def test_fused_mla_bit_exact(self):
        assert _multiturn(MLA, True, True) == _multiturn(MLA, True, False)

    def test_host_moe_bit_exact(self):
        assert _multiturn(MOE, False, True) == _multiturn(MOE, False, False)

    def test_shed_then_refault_bit_exact(self):
        """Evict-to-swap-tier between turns, then fault back on the next
        hit: the stream stays identical to the cache-off run."""
        assert _multiturn(MOE, True, True, shed=True) \
            == _multiturn(MOE, True, False)


class TestUnifiedConfig:
    def test_config_equivalent_to_legacy_kwargs(self):
        """CrossPoolEngine(config=EngineConfig(mode=...)) serves the same
        streams as the deprecated loose kwargs."""
        import warnings as _w
        models = {MLA: get_smoke_config(MLA).replace(dtype="float32")}
        outs = []
        for use_config in (True, False):
            mode = EngineMode(pipeline=False, lowering=True)
            if use_config:
                eng = CrossPoolEngine(models, page_budget=2048,
                                      page_bytes=4096, max_batch=2,
                                      max_ctx=32,
                                      config=EngineConfig(mode=mode), seed=3)
            else:
                with _w.catch_warnings(record=True) as caught:
                    _w.simplefilter("always")
                    eng = CrossPoolEngine(models, page_budget=2048,
                                          page_bytes=4096, max_batch=2,
                                          max_ctx=32, mode=mode, seed=3)
                assert any(issubclass(c.category, DeprecationWarning)
                           for c in caught)
            rng = np.random.default_rng(11)
            ids = rng.integers(0, models[MLA].vocab_size, 9).astype(np.int32)
            req = Request(request_id=0, model=MLA, prompt_tokens=9,
                          max_new_tokens=3, arrival_time=0.0, prompt_ids=ids)
            h = eng.submit(req)
            for _ in range(20):
                eng.step()
                if h.done:
                    break
            assert h.done
            outs.append(list(req.output_ids))
        assert outs[0] == outs[1]

    def test_config_and_legacy_kwargs_conflict(self):
        import pytest
        models = {MLA: get_smoke_config(MLA)}
        with pytest.raises(TypeError):
            CrossPoolEngine(models, page_budget=64,
                            config=EngineConfig(),
                            mode=EngineMode())
