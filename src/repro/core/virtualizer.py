"""KV-cache virtualizer: paged virtualization of one shared physical pool.

TPU adaptation of the paper's CUDA-VMM design (DESIGN.md §2): XLA has no
virtual-memory API, so the pool is ONE pre-allocated device array of
fixed-size pages, and "mapping" is page-table bookkeeping on the host —
identical bytes, identical slow-path/fast-path split:

  * fast path (per token, on device): attention kernels read K/V through a
    page table (``repro.kernels.paged_attention``), writes go to
    (page, slot) coordinates — no allocation on the critical path;
  * slow path (per ~page, on host): ``register_request`` /
    ``extend_request`` / ``release_request`` update the free list and
    per-request page tables against the planner's budget.

Heterogeneity (C1): the pool is untyped (flat elements of one pool dtype).
Each model views a page as ``tokens_per_page(M)`` tokens of ONE layer's K+V
(or MLA latent+rope, or SSM state), so models with different KV layouts
share the same physical pages.  ``tokens_per_page`` = page_elems //
per-token-elems, with the remainder as internal fragmentation — as in any
real pager.

Device-side state is maintained incrementally:

  * writes are ONE jitted scatter per call (``write_tokens`` /
    ``write_prompt_from_cache``) with the pool buffer donated — no
    per-token Python loop, no whole-pool rebind per token;
  * ``batch_tables`` returns a cached ``[n_layers, B, max_pages]`` device
    array and re-uploads only the rows whose page mapping actually changed
    (a request that decodes within its last page does not dirty its row).

Elasticity (DESIGN.md §8): the pool is no longer frozen at session start.
``resize`` grows or shrinks ``page_budget`` at step boundaries, and a
**host swap tier** makes shrinking safe for in-flight requests: instead of
killing a request whose pages no longer fit, its coldest pages move to a
host-resident buffer (``swap_out``), survivors are compacted into the
retained prefix of the pool with ONE jitted gather, page tables are
remapped atomically under the existing revision counter, and swapped
pages fault back in on next touch (``ensure_resident``).  Swapped table
entries are encoded in-place as ``-2 - host_slot`` so the page tables
stay the single source of mapping truth; ``-1`` remains the batch-table
padding sentinel and never appears in a request's own tables.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.errors import PoolAccountingError, check
from repro.kernels.ops import donate_argnums, paged_kv_write

#: The single page-size constant shared by the virtualizer, the pools and
#: the engine.  16 KiB balances internal fragmentation (half a page per
#: request per layer on average) against page-table length for long
#: contexts; it matches the paper's CUDA-VMM granularity choice.
DEFAULT_PAGE_BYTES = 16 * 1024


class OutOfPagesError(RuntimeError):
    pass


# re-exported for callers that treat the virtualizer as the accounting
# surface; defined in ``repro.core.errors`` so the weights arena shares it
__all__ = ["KVVirtualizer", "OutOfPagesError", "PoolAccountingError"]


@dataclass
class ModelView:
    """How one model interprets physical pages."""

    name: str
    per_token_elems: int          # one layer's K+V (or latent) elems per token
    tokens_per_page: int
    n_kv_layers: int
    kv_shape: Tuple[int, ...]     # per-token per-layer logical shape

    def pages_for(self, tokens: int) -> int:
        """Physical pages to hold ``tokens`` across all KV layers."""
        if self.tokens_per_page == 0:
            return 0
        per_layer = math.ceil(tokens / self.tokens_per_page)
        return per_layer * self.n_kv_layers


def make_view(cfg: ModelConfig, page_elems: int) -> ModelView:
    if cfg.attn_free:
        return ModelView(cfg.name, 0, 0, 0, ())
    if cfg.attention == "mla":
        m = cfg.mla
        per_tok = m.kv_lora_rank + m.qk_rope_head_dim
        shape = (per_tok,)
    else:
        per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
        shape = (2, cfg.n_kv_heads, cfg.head_dim)
    tpp = page_elems // per_tok
    if tpp == 0:
        raise ValueError(
            f"{cfg.name}: per-token KV ({per_tok} elems) exceeds page size "
            f"({page_elems} elems); increase page_bytes")
    return ModelView(cfg.name, per_tok, tpp, cfg.n_decoder_attn_layers, shape)


#: Swapped page-table encoding: entry ``-2 - host_slot``.  ``-1`` stays
#: the batch-table padding sentinel, so any entry <= _SWAP_BASE is a
#: swapped page and ``_SWAP_BASE - entry`` recovers the host slot.
_SWAP_BASE = -2


def _swap_encode(host_slot: int) -> int:
    return _SWAP_BASE - host_slot


def _swap_decode(entry: int) -> int:
    return _SWAP_BASE - entry


@dataclass
class RequestPages:
    """Per-request mapping: page_table[layer][chunk] -> physical page id.

    Entries >= 0 are device pages; entries <= -2 encode pages swapped to
    the host tier (see ``_swap_encode``) — ``n_swapped`` counts them so
    residency checks stay O(1).
    """

    request_id: int
    model: str
    tokens: int = 0
    tables: List[List[int]] = field(default_factory=list)   # [layer][chunk]
    state_pages: List[int] = field(default_factory=list)    # SSM constant state
    # globally monotonic mapping revision (assigned by the virtualizer):
    # unique per registration AND per page-mapping change, so a reused
    # request id can never alias a stale cached batch table
    rev: int = -1
    last_touch: int = 0            # virtualizer idle clock (swap victim order)
    n_swapped: int = 0             # table entries currently in the host tier

    def device_entries(self):
        """Yield (table, index, page) for every device-resident entry."""
        for tab in self.tables:
            for i, p in enumerate(tab):
                if p >= 0:
                    yield tab, i, p
        for i, p in enumerate(self.state_pages):
            if p >= 0:
                yield self.state_pages, i, p

    def swapped_entries(self):
        """Yield (table, index, host_slot) for every swapped entry."""
        for tab in self.tables:
            for i, p in enumerate(tab):
                if p <= _SWAP_BASE:
                    yield tab, i, _swap_decode(p)
        for i, p in enumerate(self.state_pages):
            if p <= _SWAP_BASE:
                yield self.state_pages, i, _swap_decode(p)



_POOL_SCATTER = None
_ROW_SCATTER = None
_ROW_GATHER = None


def _pool_scatter(pool, kv_flat, pages, slots):
    """One donated-buffer scatter of ``n`` token rows into the flat pool.

    Jitted lazily so importing this module does not initialize the jax
    backend (``donate_argnums`` needs to know it)."""
    global _POOL_SCATTER
    if _POOL_SCATTER is None:
        _POOL_SCATTER = jax.jit(paged_kv_write,
                                donate_argnums=donate_argnums(0))
    return _POOL_SCATTER(pool, kv_flat, pages, slots)


_PAIR_SCATTER = None


def _pool_pair_scatter(pool, a, b, pages, slots, *, n_tokens, batch_index,
                       mla):
    """Slice one batch row out of a layer's ``(a, b)`` KV pair, pack it to
    token rows, and scatter — ONE jitted dispatch with the pool donated.

    The packing (slice to ``n_tokens``, MLA concat / GQA stack, flatten)
    used to run as eager ops before the scatter; on the prefill path that
    is several host dispatches PER LAYER, which is exactly the fixed
    per-turn cost a cache-hit suffix prefill is trying to shed."""
    global _PAIR_SCATTER
    if _PAIR_SCATTER is None:
        def _pack_write(pool, a, b, pages, slots, n_tokens, batch_index,
                        mla):
            a = a[batch_index, :n_tokens]
            b = b[batch_index, :n_tokens]
            kv = (jnp.concatenate([a, b], axis=-1) if mla
                  else jnp.stack([a, b], axis=1))
            return paged_kv_write(pool, kv.reshape(n_tokens, -1),
                                  pages, slots)
        _PAIR_SCATTER = jax.jit(_pack_write, static_argnums=(5, 6, 7),
                                donate_argnums=donate_argnums(0))
    return _PAIR_SCATTER(pool, a, b, pages, slots, n_tokens, batch_index,
                         mla)


def _pool_row_scatter(pool, ids, rows):
    """Scatter whole page rows (fault-in from the host swap tier)."""
    global _ROW_SCATTER
    if _ROW_SCATTER is None:
        _ROW_SCATTER = jax.jit(lambda p, i, r: p.at[i].set(r),
                               donate_argnums=donate_argnums(0))
    return _ROW_SCATTER(pool, ids, rows)


def _pool_row_gather(pool, ids):
    """Gather whole page rows: ONE compiled gather builds the compacted /
    resized pool buffer (also used to read rows out for swap-out)."""
    global _ROW_GATHER
    if _ROW_GATHER is None:
        _ROW_GATHER = jax.jit(lambda p, i: p[i])
    return _ROW_GATHER(pool, ids)


class KVVirtualizer:
    """Host-side pager over one device-resident physical pool."""

    def __init__(self, models: Dict[str, ModelConfig], *,
                 page_budget: int, page_bytes: int = DEFAULT_PAGE_BYTES,
                 dtype=jnp.bfloat16, allocate_device_pool: bool = True,
                 device=None):
        self.page_bytes = page_bytes
        self.dtype = jnp.dtype(dtype)
        self.page_elems = page_bytes // self.dtype.itemsize
        self.page_budget = page_budget
        self.views = {n: make_view(c, self.page_elems)
                      for n, c in models.items()}
        self.configs = dict(models)
        self.free_list: List[int] = list(range(page_budget - 1, -1, -1))
        self.requests: Dict[int, RequestPages] = {}
        self.pool: Optional[jax.Array] = None
        if allocate_device_pool:
            pool = jnp.zeros((page_budget, self.page_elems), dtype)
            # co-locate with the KV pool's attention params (``device`` is
            # KVCachePool's device; None = jax default)
            self.pool = jax.device_put(pool, device) if device is not None \
                else pool
        # incremental device page-table cache: key -> {buf, revs, dev}
        self._batch_cache: Dict[tuple, dict] = {}
        self._rev_counter = 0
        # host swap tier: page rows evicted by a shrink live here until the
        # next touch faults them back in (lazily allocated, grows 2x)
        self.swap_buffer: Optional[np.ndarray] = None
        self.swap_free: List[int] = []
        self._touch_clock = 0
        # stats
        self.peak_mapped = 0
        self.map_events = 0
        self.unmap_events = 0
        self.swap_out_pages = 0
        self.swap_in_pages = 0
        self.resizes = 0
        self.swapped_now = 0           # entries currently in the host tier
        # per-page share counts for prefix-cached pages (DESIGN.md §11).
        # Absent = refcount 1 (sole owner, the common case): the dict only
        # holds pages currently shared between holders (requests and/or
        # the prefix tree), so the cache-off path never touches it.
        self._refs: Dict[int, int] = {}
        # prefix-cache provider (core.prefix_cache.PrefixCache): owns
        # device pages outside any request table, so shrink-compaction and
        # idle swap must consult it (``device_pages``/``remap``/``shed``)
        self.cache_provider = None
        # optional observability sink (core.hooks.CoreHooks); every hook
        # fires AFTER the matching stat counter above has been updated
        self.hooks = None

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    @property
    def mapped_pages(self) -> int:
        return self.page_budget - len(self.free_list)

    @property
    def free_pages(self) -> int:
        return len(self.free_list)

    def can_admit(self, model: str, prompt_tokens: int,
                  expected_output: int = 0, reserve: int = 0,
                  discount_pages: int = 0) -> bool:
        """``reserve`` pages are held back from admission — the elastic
        rebalancer's pressure signal (pages promised to a pending shrink
        or kept as fault-in headroom for the swap tier).

        ``discount_pages`` is the prefix cache's net credit: pages the
        request will map read-only from DEVICE-RESIDENT cached chunks
        instead of taking from the free list.  Cached-but-swapped chunks
        get no credit — their fault-in takes a fresh page each, exactly
        like a cold chunk — and a swapped copy-on-write SOURCE makes the
        value negative (its fault pages are on top of the cold-path
        need), so the verdict still honors ``reserve``."""
        return self.admission_deficit(model, prompt_tokens, expected_output,
                                      reserve, discount_pages) == 0

    def admission_deficit(self, model: str, prompt_tokens: int,
                          expected_output: int = 0, reserve: int = 0,
                          discount_pages: int = 0) -> int:
        """Pages MISSING for this admission (0 = admissible).  The
        admission controller uses the deficit as the prefix cache's shed
        target: reclaiming that many refcount-0 tree pages makes the
        request fit without touching any live request."""
        view = self.views[model]
        cfg = self.configs[model]
        need = view.pages_for(prompt_tokens + expected_output) if view.n_kv_layers \
            else 0
        need = max(need - discount_pages, 0)
        need += math.ceil(cfg.state_bytes_per_request() / self.page_bytes)
        return max(need - (self.free_pages - max(reserve, 0)), 0)

    # ------------------------------------------------------------------
    # per-page refcounts (prefix sharing, DESIGN.md §11)
    # ------------------------------------------------------------------
    def page_refs(self, page: int) -> int:
        return self._refs.get(page, 1)

    def retain_page(self, page: int) -> None:
        """Add one holder to a device page (prefix tree or a sharing
        request); freshly ``_take``n pages carry an implicit refcount 1."""
        check(page >= 0, f"cannot retain non-device entry {page}")
        self._refs[page] = self._refs.get(page, 1) + 1

    def _unref(self, page: int) -> bool:
        """Drop one holder; True when the page is fully released (the
        caller returns it to the free list) — a page is only ever freed
        at refcount 0, never while another holder maps it."""
        c = self._refs.get(page, 1) - 1
        if c == 0:
            self._refs.pop(page, None)
            return True
        if c == 1:
            self._refs.pop(page, None)   # back to implicit sole owner
        else:
            self._refs[page] = c
        return False

    # ------------------------------------------------------------------
    # slow path: map / unmap
    # ------------------------------------------------------------------
    def _next_rev(self) -> int:
        self._rev_counter += 1
        return self._rev_counter

    def _take(self, n: int) -> List[int]:
        """Atomically pop ``n`` pages: raises BEFORE mutating any state."""
        if n > len(self.free_list):
            raise OutOfPagesError(
                f"need {n} pages, {len(self.free_list)} free "
                f"(budget {self.page_budget})")
        pages = [self.free_list.pop() for _ in range(n)]
        self.map_events += n
        self.peak_mapped = max(self.peak_mapped, self.mapped_pages)
        return pages

    def register_request(self, request_id: int, model: str,
                         prompt_tokens: int) -> RequestPages:
        """Map pages for a request's prompt KV (+ SSM state).

        Atomic: the total page count is taken in ONE ``_take``, so an
        ``OutOfPagesError`` leaves the free list untouched (no partially
        mapped request to roll back).
        """
        view = self.views[model]
        cfg = self.configs[model]
        chunks = math.ceil(max(prompt_tokens, 1) / view.tokens_per_page) \
            if view.n_kv_layers else 0
        state_pages = math.ceil(cfg.state_bytes_per_request() / self.page_bytes)
        pages = self._take(chunks * view.n_kv_layers + state_pages)
        req = RequestPages(request_id, model)
        for layer in range(view.n_kv_layers):
            req.tables.append(pages[layer * chunks:(layer + 1) * chunks])
        if state_pages:
            req.state_pages = pages[view.n_kv_layers * chunks:]
        req.tokens = prompt_tokens
        req.rev = self._next_rev()
        self.requests[request_id] = req
        self.touch(request_id)
        return req

    def register_request_with_prefix(
            self, request_id: int, model: str, prompt_tokens: int,
            shared_chunks: Sequence[Sequence[int]],
            cow_chunk: Optional[Sequence[int]] = None) -> RequestPages:
        """Map a request whose leading prompt chunks are already cached.

        ``shared_chunks[c][layer]`` are device page ids of cached FULL
        chunks mapped read-only (refcount +1 each); ``cow_chunk[layer]``
        is the source of the partially-reused boundary chunk, copied
        page-for-page into fresh pages (copy-on-write at the fork point:
        the request appends suffix KV into its private copy while the
        cached original stays immutable).  All ids must be
        device-resident — the caller faults swapped chunks first.

        Atomic like ``register_request``: all fresh pages (suffix chunks,
        the CoW destination, SSM state) come from ONE ``_take``, and the
        retains/copies happen only after it succeeds.
        """
        view = self.views[model]
        cfg = self.configs[model]
        L = view.n_kv_layers
        chunks = math.ceil(max(prompt_tokens, 1) / view.tokens_per_page) \
            if L else 0
        n_shared = len(shared_chunks)
        check(n_shared + (1 if cow_chunk is not None else 0) <= chunks,
              f"prefix covers {n_shared} shared chunks"
              f"{' + a CoW chunk' if cow_chunk is not None else ''} but the "
              f"prompt only spans {chunks}")
        state_pages = math.ceil(cfg.state_bytes_per_request() / self.page_bytes)
        fresh_per_layer = chunks - n_shared
        pages = self._take(fresh_per_layer * L + state_pages)
        req = RequestPages(request_id, model)
        for layer in range(L):
            fresh = pages[layer * fresh_per_layer:
                          (layer + 1) * fresh_per_layer]
            req.tables.append(
                [shared_chunks[c][layer] for c in range(n_shared)] + fresh)
        if state_pages:
            req.state_pages = pages[L * fresh_per_layer:]
        for c in range(n_shared):
            for layer in range(L):
                self.retain_page(shared_chunks[c][layer])
        if cow_chunk is not None:
            # chunk ``n_shared`` of every layer is the private boundary
            # copy: one vectorized device row copy, byte-exact
            srcs = [int(cow_chunk[layer]) for layer in range(L)]
            dsts = [req.tables[layer][n_shared] for layer in range(L)]
            check(all(s >= 0 for s in srcs),
                  f"copy-on-write source chunk is not device-resident: {srcs}")
            if self.pool is not None:
                rows = _pool_row_gather(self.pool,
                                        jnp.asarray(np.asarray(srcs, np.int32)))
                self.pool = _pool_row_scatter(
                    self.pool, jnp.asarray(np.asarray(dsts, np.int32)), rows)
        req.tokens = prompt_tokens
        req.rev = self._next_rev()
        self.requests[request_id] = req
        self.touch(request_id)
        return req

    def gather_prompt_rows(self, model: str, request_id: int,
                           n_tokens: int) -> jax.Array:
        """KV rows of tokens ``[0, n_tokens)`` for EVERY layer, read
        through the request's own page table and stacked into one
        ``[n_kv_layers, n_tokens, *kv_shape]`` device array.  One gather
        for all layers: the suffix prefill is host-dispatch-bound, and
        the per-layer rows are sliced INSIDE its jitted attention stage.
        Shared pages are read in place (never copied)."""
        view = self.views[model]
        req = self.requests[request_id]
        check(req.n_swapped == 0,
              f"request {request_id} has swapped pages; call ensure_resident "
              f"before gathering prefix KV")
        typed = self.typed_pages(model)
        toks = np.arange(n_tokens)
        chunk = toks // view.tokens_per_page
        slots = (toks % view.tokens_per_page).astype(np.int32)
        pages = np.stack([np.asarray(req.tables[layer], np.int32)[chunk]
                          for layer in range(view.n_kv_layers)])
        return typed[jnp.asarray(pages), jnp.asarray(slots)[None, :]]

    def pages_needed_for_extend(self, request_id: int,
                                new_tokens: int = 1) -> int:
        """Pages a (would-be) ``extend_request`` would map, without mutating
        anything — lets callers make a multi-request extension atomic by
        checking the batch total against ``free_pages`` first."""
        req = self.requests[request_id]
        view = self.views[req.model]
        if not view.n_kv_layers:
            return 0
        have = len(req.tables[0])
        need = math.ceil(max(req.tokens + new_tokens, 1)
                         / view.tokens_per_page)
        return max(need - have, 0) * view.n_kv_layers

    def extend_request(self, request_id: int, new_tokens: int = 1) -> None:
        """Grow a request by ``new_tokens`` (decode); maps pages on demand.

        Atomic: the pages for every layer are taken in ONE ``_take``, so an
        ``OutOfPagesError`` leaves every layer table at its old (equal)
        length and the token count unchanged.
        """
        req = self.requests[request_id]
        view = self.views[req.model]
        if view.n_kv_layers:
            have = len(req.tables[0])
            need = math.ceil(max(req.tokens + new_tokens, 1)
                             / view.tokens_per_page)
            delta = need - have
            if delta > 0:
                pages = self._take(delta * view.n_kv_layers)
                for layer, tab in enumerate(req.tables):
                    tab.extend(pages[layer * delta:(layer + 1) * delta])
                req.rev = self._next_rev()
        req.tokens += new_tokens
        self.touch(request_id)

    def reserve_decode_block(self, request_id: int, k: int = 1) -> int:
        """Pre-map pages covering the next ``k`` decode tokens WITHOUT
        committing them (multi-step decode, DESIGN.md §9).

        Extends every layer table to cover ``tokens + k`` while leaving
        ``req.tokens`` untouched — the tokens are committed only after
        the dispatch returns (``commit_decode_block``), so a dispatch
        that stops early (EOS mid-block) never leaves phantom tokens in
        the accounting.  The revision bumps when pages are added, so the
        next ``batch_tables`` upload carries the reserved entries and
        the device scan can append KV without any host table mutation
        mid-dispatch.

        Ordering contract: the caller faults swapped pages back in FIRST
        (``ensure_resident``) — reserving on top of a swapped table would
        interleave fresh device ids with host-slot encodings and the
        batch-table build would reject the row anyway.

        Atomic like ``extend_request``: one ``_take`` for all layers, so
        ``OutOfPagesError`` leaves every table at its old length.
        Returns the number of pages mapped.
        """
        req = self.requests[request_id]
        view = self.views[req.model]
        if not view.n_kv_layers:
            self.touch(request_id)
            return 0
        check(req.n_swapped == 0,
              f"request {request_id} has swapped pages; call ensure_resident "
              f"before reserving a decode block")
        have = len(req.tables[0])
        need = math.ceil(max(req.tokens + k, 1) / view.tokens_per_page)
        delta = need - have
        if delta <= 0:
            self.touch(request_id)
            return 0
        pages = self._take(delta * view.n_kv_layers)
        for layer, tab in enumerate(req.tables):
            tab.extend(pages[layer * delta:(layer + 1) * delta])
        req.rev = self._next_rev()
        self.touch(request_id)
        if self.hooks is not None:
            self.hooks.kv_reserved(len(pages))
        return len(pages)

    def commit_decode_block(self, request_id: int, n_committed: int) -> int:
        """Commit ``n_committed`` tokens of a reserved decode block and
        return the unused reserved pages to the free list.

        The inverse of ``reserve_decode_block``: ``req.tokens`` advances
        by the tokens the device actually emitted (EOS / per-row budget
        may stop a K-block early) and any reserved chunk beyond
        ``ceil(tokens / tokens_per_page)`` is unmapped — trimmed pages go
        back in reverse order so the free list keeps handing out the
        lowest ids first (allocation order stays deterministic).  The
        revision bumps only when pages were trimmed.  Returns the number
        of pages returned.
        """
        req = self.requests[request_id]
        view = self.views[req.model]
        req.tokens += n_committed
        if not view.n_kv_layers:
            self.touch(request_id)
            return 0
        keep = math.ceil(max(req.tokens, 1) / view.tokens_per_page)
        if len(req.tables[0]) <= keep:
            self.touch(request_id)
            return 0
        trimmed = 0
        for tab in req.tables:
            extra = tab[keep:]
            del tab[keep:]
            for p in reversed(extra):
                if p <= _SWAP_BASE:      # reserved page swapped meanwhile
                    self.swap_free.append(_swap_decode(p))
                    req.n_swapped -= 1
                    self.swapped_now -= 1
                else:
                    self.free_list.append(p)
            trimmed += len(extra)
        self.unmap_events += trimmed
        req.rev = self._next_rev()
        self.touch(request_id)
        if self.hooks is not None and trimmed:
            self.hooks.kv_trimmed(trimmed)
        return trimmed

    def release_request(self, request_id: int) -> None:
        req = self.requests.pop(request_id)
        n = 0
        for _, _, page in req.device_entries():
            # prefix-shared pages survive until their LAST holder (other
            # sharing requests or the prefix tree) drops them
            if self._unref(page):
                self.free_list.append(page)
            n += 1
        for _, _, slot in req.swapped_entries():
            self.swap_free.append(slot)
            self.swapped_now -= 1
            n += 1
        self.unmap_events += n

    # ------------------------------------------------------------------
    # elastic boundary: host swap tier + live resize (DESIGN.md §8)
    # ------------------------------------------------------------------
    def touch(self, request_id: int) -> None:
        """Mark a request recently used (swap victims are least-recent)."""
        self._touch_clock += 1
        self.requests[request_id].last_touch = self._touch_clock

    def _swap_slots(self, n: int) -> List[int]:
        """Take ``n`` host-tier slots, growing the swap buffer on demand."""
        while len(self.swap_free) < n:
            old = 0 if self.swap_buffer is None else len(self.swap_buffer)
            cap = max(old * 2, n, 16)
            buf = np.zeros((cap, self.page_elems), self.dtype)
            if self.swap_buffer is not None:
                buf[:old] = self.swap_buffer
            self.swap_buffer = buf
            self.swap_free.extend(range(cap - 1, old - 1, -1))
        return [self.swap_free.pop() for _ in range(n)]

    def swap_out(self, request_id: int, max_pages: Optional[int] = None
                 ) -> int:
        """Move up to ``max_pages`` of a request's device pages to the host
        tier (coldest — lowest token chunks — first); returns the count.

        The freed device ids go straight back to the free list; table
        entries are rewritten to the swapped encoding and the request's
        revision bumps, so any cached batch table is invalidated.  Page
        CONTENTS move with the page (one device gather + one host copy),
        so a later fault-in is bit-for-bit invisible to attention.
        """
        req = self.requests[request_id]
        victims: List[Tuple[List[int], int, int]] = []
        view = self.views[req.model]
        chunks = len(req.tables[0]) if req.tables else 0
        # chunk-major: the lowest (oldest-token) chunk of every layer goes
        # first, so partial swaps shed the coldest KV across layers evenly
        for c in range(chunks):
            for layer in range(view.n_kv_layers):
                p = req.tables[layer][c]
                # prefix-shared pages never swap through a request: the
                # other holders (tree / sharing requests) still read them
                if p >= 0 and self.page_refs(p) == 1:
                    victims.append((req.tables[layer], c, p))
        for i, p in enumerate(req.state_pages):
            if p >= 0:
                victims.append((req.state_pages, i, p))
        if max_pages is not None:
            victims = victims[:max_pages]
        if not victims:
            return 0
        ids = np.asarray([p for _, _, p in victims], np.int32)
        slots = self._swap_slots(len(victims))
        if self.pool is not None:
            rows = np.asarray(_pool_row_gather(self.pool, jnp.asarray(ids)))
            self.swap_buffer[np.asarray(slots)] = rows
        for (tab, i, page), slot in zip(victims, slots):
            tab[i] = _swap_encode(slot)
            self.free_list.append(page)
        req.rev = self._next_rev()
        req.n_swapped += len(victims)
        self.swapped_now += len(victims)
        self.swap_out_pages += len(victims)
        if self.hooks is not None:
            self.hooks.kv_swap_out(len(victims))
        return len(victims)

    def ensure_resident(self, request_id: int) -> int:
        """Fault a request's swapped pages back onto the device (the
        "next touch" of the swap tier); returns how many were faulted.

        Atomic like every other mapping change: the device pages are taken
        in ONE ``_take``, so ``OutOfPagesError`` leaves the tables, the
        swap tier and the free list untouched.
        """
        req = self.requests[request_id]
        if req.n_swapped == 0:
            return 0
        entries = list(req.swapped_entries())
        pages = self._take(len(entries))
        if self.pool is not None:
            rows = self.swap_buffer[
                np.asarray([s for _, _, s in entries])].copy()
            self.pool = _pool_row_scatter(
                self.pool, jnp.asarray(np.asarray(pages, np.int32)),
                jnp.asarray(rows))
        for (tab, i, slot), page in zip(entries, pages):
            tab[i] = page
            self.swap_free.append(slot)
        req.rev = self._next_rev()
        req.n_swapped = 0
        self.swapped_now -= len(entries)
        self.swap_in_pages += len(entries)
        self.touch(request_id)
        if self.hooks is not None:
            self.hooks.kv_swap_in(len(entries))
        return len(entries)

    def swap_pages_out(self, pages: Sequence[int]) -> List[int]:
        """Move table-less device pages (prefix-tree leaves) to the host
        tier; returns their swapped encodings in order.

        The caller guarantees every page is SOLE-owned by it (refcount 1
        and in no request table) — the second-chance cache tier's shed
        path.  Contents move with the page, so a later fault-in is
        bit-exact."""
        if not pages:
            return []
        check(all(p >= 0 and self.page_refs(p) == 1 for p in pages),
              f"swap_pages_out requires sole-owned device pages: {list(pages)}")
        slots = self._swap_slots(len(pages))
        if self.pool is not None:
            ids = jnp.asarray(np.asarray(list(pages), np.int32))
            rows = np.asarray(_pool_row_gather(self.pool, ids))
            self.swap_buffer[np.asarray(slots)] = rows
        for p in pages:
            self.free_list.append(p)
        self.swapped_now += len(pages)
        self.swap_out_pages += len(pages)
        if self.hooks is not None:
            self.hooks.kv_swap_out(len(pages))
        return [_swap_encode(s) for s in slots]

    def fault_pages_in(self, encoded: Sequence[int]) -> List[int]:
        """Fault host-tier pages back onto the device (second-chance
        cache hit); returns the fresh device ids in order.  Atomic: ONE
        ``_take``, so ``OutOfPagesError`` leaves the swap tier intact."""
        if not encoded:
            return []
        check(all(e <= _SWAP_BASE for e in encoded),
              f"fault_pages_in takes swapped encodings only: {list(encoded)}")
        slots = [_swap_decode(e) for e in encoded]
        pages = self._take(len(slots))
        if self.pool is not None:
            rows = self.swap_buffer[np.asarray(slots)].copy()
            self.pool = _pool_row_scatter(
                self.pool, jnp.asarray(np.asarray(pages, np.int32)),
                jnp.asarray(rows))
        self.swap_free.extend(slots)
        self.swapped_now -= len(slots)
        self.swap_in_pages += len(slots)
        if self.hooks is not None:
            self.hooks.kv_swap_in(len(slots))
        return pages

    def release_cached_page(self, entry: int) -> bool:
        """Drop the prefix tree's hold on one table entry (device id or
        swapped encoding); True when a DEVICE page was actually freed."""
        if entry <= _SWAP_BASE:
            self.swap_free.append(_swap_decode(entry))
            self.swapped_now -= 1
            return False
        if self._unref(entry):
            self.free_list.append(entry)
            self.unmap_events += 1
            return True
        return False

    def swap_out_idle(self, need: int, protected=()) -> int:
        """Free ``need`` device pages by swapping the coldest pages of the
        longest-idle requests (skipping ``protected`` ids); returns how
        many were actually freed.

        The prefix cache sheds FIRST: its refcount-0 LRU leaves hold no
        in-flight work, so an elastic shrink reclaims them (to the
        second-chance swap tier) before touching any live request."""
        freed = 0
        if self.cache_provider is not None and need > 0:
            freed = self.cache_provider.shed(need)
        protected = set(protected)
        order = sorted(self.requests.values(), key=lambda r: r.last_touch)
        for req in order:
            if freed >= need:
                break
            if req.request_id in protected:
                continue
            freed += self.swap_out(req.request_id, need - freed)
        return freed

    def resize(self, new_budget: int, protected=()) -> Dict[str, int]:
        """Live-repartition entry point: grow or shrink the pool to
        ``new_budget`` pages at a step boundary.

        Growing copies the old buffer into the prefix of a larger one and
        appends fresh ids to the free list.  Shrinking swaps out the
        coldest pages of the longest-idle (non-``protected``) requests
        until the survivors fit, then compacts survivors into the retained
        prefix with ONE jitted gather and remaps every table atomically
        (all revisions bump; the batch-table cache drops).  Raises
        ``OutOfPagesError`` — with NO state change beyond completed swaps —
        when protected requests alone exceed the new budget.
        """
        new_budget = int(new_budget)
        check(new_budget >= 1, f"page budget must be >= 1, got {new_budget}")
        old_budget = self.page_budget
        if new_budget == old_budget:
            return {"page_budget": old_budget, "swapped_out": 0, "moved": 0}
        swapped = 0
        if new_budget > old_budget:
            if self.pool is not None:
                pad = jnp.zeros((new_budget - old_budget, self.page_elems),
                                self.pool.dtype)
                self.pool = jnp.concatenate([self.pool, pad], axis=0)
            # new ids go to the FRONT of the (pop-from-the-end) free list,
            # so existing low ids keep being preferred — allocation order
            # stays deterministic across grows
            self.free_list = list(range(new_budget - 1, old_budget - 1, -1)) \
                + self.free_list
            self.page_budget = new_budget
            self.resizes += 1
            if self.hooks is not None:
                self.hooks.kv_resize(old_budget, new_budget, 0, 0)
            return {"page_budget": new_budget, "swapped_out": 0, "moved": 0}

        # --- shrink ----------------------------------------------------
        device_mapped = self.mapped_pages
        if device_mapped > new_budget:
            swapped = self.swap_out_idle(device_mapped - new_budget,
                                         protected)
        if self.mapped_pages > new_budget:
            raise OutOfPagesError(
                f"cannot shrink to {new_budget} pages: {self.mapped_pages} "
                f"still mapped after swapping {swapped} (protected "
                f"requests hold too many pages)")
        # compact survivors into [0, new_budget): deterministic order —
        # requests by id, then layer-major table order, then cache-held
        # pages not in any table.  A prefix-shared page appears in many
        # tables but moves ONCE (one new id for all holders).
        old_ids: List[int] = []
        mapping: Dict[int, int] = {}
        entries: List[Tuple[List[int], int, int]] = []
        for rid in sorted(self.requests):
            req = self.requests[rid]
            for tab, i, page in req.device_entries():
                entries.append((tab, i, page))
                if page not in mapping:
                    mapping[page] = len(old_ids)
                    old_ids.append(page)
        if self.cache_provider is not None:
            for page in self.cache_provider.device_pages():
                if page not in mapping:
                    mapping[page] = len(old_ids)
                    old_ids.append(page)
        k = len(old_ids)
        perm = np.zeros(new_budget, np.int32)
        perm[:k] = np.asarray(old_ids, np.int32) if k else []
        if self.pool is not None:
            self.pool = _pool_row_gather(self.pool, jnp.asarray(perm))
        for tab, i, page in entries:
            tab[i] = mapping[page]
        self._refs = {mapping[p]: c for p, c in self._refs.items()
                      if p in mapping}
        if self.cache_provider is not None:
            self.cache_provider.remap(mapping)
        for req in self.requests.values():
            req.rev = self._next_rev()
        self._batch_cache.clear()
        self.free_list = list(range(new_budget - 1, k - 1, -1))
        self.page_budget = new_budget
        self.resizes += 1
        if self.hooks is not None:
            self.hooks.kv_resize(old_budget, new_budget, swapped, k)
        return {"page_budget": new_budget, "swapped_out": swapped,
                "moved": k}

    # ------------------------------------------------------------------
    # fast path: device views
    # ------------------------------------------------------------------
    def page_table_array(self, request_ids: List[int], layer: int,
                         max_pages: int) -> jax.Array:
        """[B, max_pages] int32 physical ids (-1 = unmapped) for one layer."""
        out = np.full((len(request_ids), max_pages), -1, np.int32)
        for i, rid in enumerate(request_ids):
            tab = self.requests[rid].tables[layer]
            out[i, : min(len(tab), max_pages)] = tab[: max_pages]
        return jnp.asarray(out)

    def batch_tables(self, model: str,
                     request_ids: Sequence[Optional[int]],
                     max_pages: int) -> jax.Array:
        """[n_layers, B, max_pages] int32 table for a batch of slots.

        ``None`` entries (empty batch slots) map to all ``-1`` rows.  The
        device array is cached per (model, slot assignment, max_pages) and
        re-uploaded only when a row's page mapping actually changed — a
        request decoding within its current last page reuses the cached
        array with zero host work.
        """
        view = self.views[model]
        key = (model,
               tuple(-1 if r is None else r for r in request_ids),
               max_pages)
        for rid in request_ids:
            if rid is not None and rid in self.requests:
                check(self.requests[rid].n_swapped == 0,
                      f"request {rid} has swapped pages; call "
                      f"ensure_resident before building batch tables")
        revs = tuple(
            -1 if rid is None or rid not in self.requests
            else self.requests[rid].rev
            for rid in request_ids)
        entry = self._batch_cache.get(key)
        if entry is not None and entry["revs"] == revs:
            return entry["dev"]
        if entry is None:
            buf = np.full((view.n_kv_layers, len(request_ids), max_pages),
                          -1, np.int32)
            old_revs: tuple = (None,) * len(request_ids)
        else:
            buf, old_revs = entry["buf"], entry["revs"]
        for i, rid in enumerate(request_ids):
            if old_revs[i] == revs[i]:
                continue
            buf[:, i, :] = -1
            if rid is not None and rid in self.requests:
                for layer, tab in enumerate(self.requests[rid].tables):
                    m = min(len(tab), max_pages)
                    buf[layer, i, :m] = tab[:m]
        # jnp.array COPIES: jnp.asarray may zero-copy-alias the numpy buffer
        # on CPU, and ``buf`` is mutated in place on later mapping changes —
        # an aliased upload would retroactively corrupt tables already
        # handed to in-flight steps.
        dev = jnp.array(buf)
        if len(self._batch_cache) > 64:     # bound stale slot assignments
            self._batch_cache.clear()
        self._batch_cache[key] = {"buf": buf, "revs": revs, "dev": dev}
        return dev

    def typed_pages(self, model: str) -> jax.Array:
        """The pool viewed as ``[n_pages, tokens_per_page, *kv_shape]``.

        Zero-copy reshape of the shared flat pool; the slack elements at the
        end of each page are invisible to the kernel.
        """
        view = self.views[model]
        used = view.tokens_per_page * view.per_token_elems
        return self.pool[:, :used].reshape(
            (self.page_budget, view.tokens_per_page) + view.kv_shape)

    def _token_coords(self, req: RequestPages, view: ModelView,
                      tokens: np.ndarray, layer: Optional[int] = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(pages, slots) int32 arrays for token indices of one request.

        ``layer=None`` vectorizes over ALL layers: ``tokens`` is [n] and the
        result is [n_layers * n] in layer-major order.
        """
        check(req.n_swapped == 0,
              f"request {req.request_id} has swapped pages; call "
              f"ensure_resident before writing KV")
        chunk = tokens // view.tokens_per_page
        slots = (tokens % view.tokens_per_page).astype(np.int32)
        if layer is not None:
            tab = np.asarray(req.tables[layer], np.int32)
            return tab[chunk], slots
        tabs = np.asarray(req.tables, np.int32)        # [L, chunks]
        pages = tabs[:, chunk].reshape(-1)             # [L * n]
        return pages, np.tile(slots, view.n_kv_layers)

    def write_tokens(self, model: str, layer: int, request_id: int,
                     start_token: int, kv: jax.Array) -> None:
        """Write ``kv [n_new, *kv_shape]`` at token offset ``start_token``.

        One jitted, donated-buffer scatter for the whole token range — the
        pool buffer is updated in place rather than rebound per token.
        """
        view = self.views[model]
        req = self.requests[request_id]
        n = kv.shape[0]
        flat = kv.reshape(n, view.per_token_elems)
        toks = np.arange(start_token, start_token + n)
        pages, slots = self._token_coords(req, view, toks, layer)
        self.pool = _pool_scatter(self.pool, flat, jnp.asarray(pages),
                                  jnp.asarray(slots))

    def write_prompt_layer(self, pool: jax.Array, model: str,
                           request_id: int, layer: int, layer_kv,
                           n_tokens: int, batch_index: int = 0,
                           start: int = 0) -> jax.Array:
        """Seed ONE layer's prompt KV from full-sequence attention outputs.

        ``layer_kv`` is the per-layer pair a streaming (layer-at-a-time)
        prefill produces: ``(k, v)`` each ``[B,S,KV,hd]`` for GQA or
        ``(latent, rope)`` ``[B,S,·]`` for MLA — the same bytes
        ``write_prompt_from_cache`` scatters, one layer at a time so KV
        lands in the pool while later layers are still executing.

        Pure with respect to the pool: takes and returns the (donated)
        buffer instead of touching ``self.pool``, so a pipeline scheduler
        can thread it through interleaved prefill/decode stages.

        ``start`` offsets the destination tokens: a cache-hit suffix
        prefill computes KV only for tokens ``[fork, true_len)`` and
        writes them at absolute positions starting at the fork
        (DESIGN.md §11) — rows of ``layer_kv`` stay 0-based.
        """
        view = self.views[model]
        req = self.requests[request_id]
        a, b = layer_kv
        toks = np.arange(start, start + n_tokens)
        pages, slots = self._token_coords(req, view, toks, layer)
        return _pool_pair_scatter(pool, a, b, jnp.asarray(pages),
                                  jnp.asarray(slots), n_tokens=n_tokens,
                                  batch_index=batch_index,
                                  mla=len(view.kv_shape) == 1)

    def write_prompt_from_cache(self, model: str, request_id: int,
                                cache: Dict, n_tokens: int,
                                batch_index: int = 0) -> None:
        """Seed a request's mapped pages from a dense prefill cache.

        ``cache`` is the model's contiguous decode-cache pytree (GQA
        ``{"k","v": [L,B,T,KV,hd]}`` or MLA ``{"latent","rope"}``); tokens
        ``[0, n_tokens)`` of row ``batch_index`` are scattered into the
        request's pages across ALL layers in one device dispatch.
        """
        view = self.views[model]
        req = self.requests[request_id]
        if "k" in cache:
            k = cache["k"][:, batch_index, :n_tokens]      # [L,n,KV,hd]
            v = cache["v"][:, batch_index, :n_tokens]
            kv = jnp.stack([k, v], axis=2)                 # [L,n,2,KV,hd]
        else:
            kv = jnp.concatenate(
                [cache["latent"][:, batch_index, :n_tokens],
                 cache["rope"][:, batch_index, :n_tokens]], axis=-1)
        L = kv.shape[0]
        check(L == view.n_kv_layers,
              f"prefill cache has {L} layers, view expects {view.n_kv_layers}")
        flat = kv.reshape(L * n_tokens, view.per_token_elems)
        toks = np.arange(n_tokens)
        pages, slots = self._token_coords(req, view, toks)
        self.pool = _pool_scatter(self.pool, flat, jnp.asarray(pages),
                                  jnp.asarray(slots))

    # ------------------------------------------------------------------
    def accounting_snapshot(self) -> Dict[str, int]:
        """Integer holder-class partition of the page budget — the pool
        half of a flight-recorder snapshot (DESIGN.md §13).  Pure reads;
        every field is deterministic given the session's input stream, so
        a replayed session must reproduce it bit-exactly.
        """
        live = set()
        for req in self.requests.values():
            for _, _, page in req.device_entries():
                live.add(page)
        return {
            "page_budget": self.page_budget,
            "free_pages": self.free_pages,
            "mapped_pages": self.mapped_pages,
            "request_pages": len(live),     # device pages held by live tables
            "swapped_now": self.swapped_now,
            "peak_mapped": self.peak_mapped,
            "swap_out_pages": self.swap_out_pages,
            "swap_in_pages": self.swap_in_pages,
            "resizes": self.resizes,
            # refcount summary (prefix sharing): pages with >1 holder and
            # the total explicit holder count across them
            "shared_pages": sum(1 for c in self._refs.values() if c > 1),
            "ref_total": sum(self._refs.values()),
        }

    def utilization(self) -> Dict[str, float]:
        frag = 0.0
        for rid, req in self.requests.items():
            view = self.views[req.model]
            if not view.n_kv_layers:
                continue
            used = req.tokens * view.per_token_elems * view.n_kv_layers
            held = sum(len(t) for t in req.tables) * self.page_elems
            frag += held - used
        return {
            "mapped_pages": self.mapped_pages,
            "free_pages": self.free_pages,
            "peak_mapped": self.peak_mapped,
            "internal_frag_bytes": frag * self.dtype.itemsize,
            # elastic-boundary signals (DESIGN.md §8)
            "page_budget": self.page_budget,
            "occupancy": self.mapped_pages / max(self.page_budget, 1),
            "swapped_pages": self.swapped_now,
            "swap_out_pages": self.swap_out_pages,
            "swap_in_pages": self.swap_in_pages,
            "swap_tier_bytes": (0 if self.swap_buffer is None
                                else self.swap_buffer.nbytes),
            "resizes": self.resizes,
        }
