"""Control lowering: host-driven vs device-resident decode step execution.

The paper's §3.3 persistent kernels keep the per-layer control loop on the
GPU.  The TPU/XLA analogue (DESIGN.md §2): a *fused* decode step — one XLA
program that scans over layers — is dispatched ONCE per token per batch;
layer transitions, the attention->FFN ping-pong and its collectives all
live inside the compiled program, exactly like a persistent kernel that
dispatches captured subgraphs.  The host keeps only admission and page
mapping, the paper's split.

Both lowering modes now serve decode from the virtualizer's SHARED paged
KV pool: steps take ``(tokens, pool, page_tables, lengths)`` and thread
the (donated) pool buffer through every layer's attention stage.  FFN
weights are symmetric: both modes gather each layer's expert / dense-MLP
slabs from the SHARED weights arena (``repro.core.weight_pool``) through
the model's slot table — there is no per-model device ``w_params`` tree;
the arena buffer and slot table are fetched from the pooled model's arena
at call time, so activating/evicting cold models never recompiles a step.

``HostDrivenStep`` is the ablation baseline (Table 3 row 1): every layer
issues separate attention-stage and FFN-stage dispatches with host Python
in between — 2L+2 dispatches/token instead of 1, plus 2L inter-pool
device transfers driven from the host.

``PagedFusedStep`` is lowering=ON over the pool: embed, every layer's
paged attention + proxy boundary + FFN, and the final logits are ONE
compiled ``lax.scan`` program consuming the same pooled param split
(kv_params / w_params) as the host-driven path.

``StreamingPrefill`` is the prompt-phase twin: per-layer full-sequence
attention + arena FFN with layer L+1's weight slabs uploading behind layer
L's attention, so a cold model's first token overlaps its own weight
upload instead of waiting for it (DESIGN.md §6).

Families that bypass split execution (SSM/hybrid/enc-dec/SWA) decode
through the fused dense-cache ``model.decode_step`` program compiled by
``runtime.engine.ModelRunner`` — there is no separate step class for them.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pools import PooledModel, transfer
from repro.kernels.ops import donate_argnums as _donate


class HostDrivenStep:
    """Per-layer host dispatch across the two pools (lowering OFF)."""

    def __init__(self, pooled: PooledModel, kv_device, w_device):
        self.pooled = pooled
        self.kv_device = kv_device
        self.w_device = w_device
        fns = pooled.stage_fns
        # execution placement follows the committed pool buffers: attention
        # stages run where kv_params live, FFN stages where the arena lives.
        self._embed = jax.jit(fns.embed)
        self._attn = jax.jit(fns.attn_stage, donate_argnums=_donate(2))
        self._ffn = jax.jit(fns.ffn_stage)
        self._combine = jax.jit(fns.combine)
        self._logits = jax.jit(fns.logits)
        # prompt-phase stage programs (the pipeline scheduler interleaves
        # prefill batches through the same per-layer machinery)
        self._pembed = jax.jit(fns.prefill_embed)
        self._pattn = jax.jit(fns.prefill_attn)
        self._plogits = jax.jit(fns.prefill_logits)

    def __call__(self, tokens, pool, page_tables, lengths
                 ) -> Tuple[jax.Array, jax.Array]:
        """tokens [B]; pool [n_pages, page_elems]; page_tables [L,B,P];
        lengths [B].  Returns (logits [B,V], updated pool)."""
        p_kv = self.pooled.kv_params
        abuf, slot_table = self.pooled.arena.acquire(self.pooled.cfg.name)
        x = self._embed(p_kv, tokens)
        for layer in range(self.pooled.stage_fns.n_layers):
            x, ffn_in, pool = self._attn(
                p_kv, x, pool, page_tables, lengths, layer)
            ffn_in_w = transfer(ffn_in, self.w_device)      # A-to-F
            ffn_out = self._ffn(abuf, slot_table, ffn_in_w, layer)
            ffn_out_kv = transfer(ffn_out, self.kv_device)  # F-to-A
            x = self._combine(x, ffn_out_kv)
        return self._logits(p_kv, x), pool

    def stage_generator(self, tokens, pool, page_tables, lengths):
        """Yield one pipeline stage at a time (for the layer-wise scheduler).

        Yields ("attn"|"ffn", layer) after issuing that stage's dispatch;
        the final return carries (logits, pool) in ``self.result``.
        """
        p_kv = self.pooled.kv_params
        abuf, slot_table = self.pooled.arena.acquire(self.pooled.cfg.name)
        x = self._embed(p_kv, tokens)
        for layer in range(self.pooled.stage_fns.n_layers):
            x, ffn_in, pool = self._attn(
                p_kv, x, pool, page_tables, lengths, layer)
            yield ("attn", layer)
            ffn_in_w = transfer(ffn_in, self.w_device)
            ffn_out = self._ffn(abuf, slot_table, ffn_in_w, layer)
            yield ("ffn", layer)
            ffn_out_kv = transfer(ffn_out, self.kv_device)
            x = self._combine(x, ffn_out_kv)
        yield ("logits", -1)
        self.result = (self._logits(p_kv, x), pool)


def logit_index(true_len):
    """Last-prompt-position index for ``prefill_logits``.

    A host int (every row shares one unpadded length) stays a scalar —
    the seed trace shape — while a per-row sequence from a coalesced
    same-model batch becomes a [B] int32 vector.
    """
    if isinstance(true_len, (int, np.integer)):
        return jnp.int32(int(true_len) - 1)
    return jnp.asarray(np.asarray(true_len, np.int32).reshape(-1) - 1)


class StreamingPrefill:
    """Arena-bounded prompt-phase execution with streamed weight uploads.

    The prompt phase used to be the one place a model's FULL param tree had
    to be device-resident; this runs it through the same ``(arena,
    slot_table)`` protocol as decode (DESIGN.md §6).  Per layer, the host
    issues:

      1. full-sequence attention for layer L (KV-pool side),
      2. the async upload of layer L+1's weight slabs
         (``WeightArena.prefetch_layer``) — hidden behind 1.,
      3. the layer's prompt KV scatter into the shared paged pool
         (``writer``), and
      4. the FFN gather out of the arena (``ffn_stage``) for layer L.

    Because uploads stream layer-by-layer behind attention, a COLD model's
    first token no longer waits for a monolithic weight upload: activation
    maps slots only (``upload=False``) and by the time prefill finishes
    every layer is resident, so the fused decode step (``PagedFusedStep``)
    dispatches with zero remaining upload work.  Used by BOTH lowering
    modes — prefill is per-request, off the per-token critical path, so
    per-layer dispatches cost nothing while buying the overlap.
    """

    def __init__(self, pooled: PooledModel, kv_device=None, w_device=None,
                 share: Optional[HostDrivenStep] = None):
        self.pooled = pooled
        self.kv_device = kv_device
        self.w_device = w_device
        if share is not None:
            # host-driven mode already jitted the same stage programs in
            # its HostDrivenStep — reuse them (one trace/compile cache per
            # model, whether a prompt runs here or via the scheduler)
            self._embed = share._pembed
            self._attn = share._pattn
            self._ffn = share._ffn
            self._combine = share._combine
            self._logits = share._plogits
        else:
            fns = pooled.stage_fns
            self._embed = jax.jit(fns.prefill_embed)
            self._attn = jax.jit(fns.prefill_attn)
            self._ffn = jax.jit(fns.ffn_stage)
            self._combine = jax.jit(fns.combine)
            self._logits = jax.jit(fns.prefill_logits)
        # prefix-cache programs (DESIGN.md §11) are StreamingPrefill-local
        # in BOTH lowering modes: suffix shapes depend on the fork point,
        # never on the decode lowering, so there is nothing to share
        fns = pooled.stage_fns
        self._suffix_attn = None
        self._suffix_ffn = None
        self._route = None
        if getattr(fns, "suffix_attn", None) is not None:
            self._suffix_attn = jax.jit(fns.suffix_attn, static_argnums=(5,))
            self._suffix_ffn = jax.jit(fns.suffix_ffn, static_argnums=(5,))
        if getattr(fns, "prefill_route", None) is not None:
            self._route = jax.jit(fns.prefill_route)
        #: per-layer routing of the last captured prompt pass:
        #: np.ndarray [S, L, k] (None when not captured / dense)
        self.captured_routes = None

    def __call__(self, tokens, true_len, pool, writer=None, *,
                 capture_routes: bool = False
                 ) -> Tuple[jax.Array, jax.Array]:
        """tokens [B,S] prompt ids; ``true_len`` the unpadded length whose
        last position's logits are returned — a host int shared by every
        row, or a length-B sequence for a coalesced same-model batch where
        each row carries its own prompt; ``writer(layer, layer_kv, pool)
        -> pool`` scatters one layer's prompt KV into the shared pool
        (None skips KV capture).  Returns (logits [B,V], pool)."""
        name = self.pooled.cfg.name
        arena = self.pooled.arena
        fns = self.pooled.stage_fns
        p_kv = self.pooled.kv_params
        arena.activate(name, upload=False)
        arena.prefetch_layer(name, 0)        # first FFN never stalls
        captured = [] if (capture_routes and self._route is not None) \
            else None
        self.captured_routes = None
        x = self._embed(p_kv, tokens)
        for layer in range(fns.n_layers):
            x, ffn_in, layer_kv = self._attn(p_kv, x, layer)
            # transfer hiding, weights edition: layer L+1's slabs upload
            # while layer L's attention is in flight
            arena.prefetch_layer(name, layer + 1)
            if writer is not None:
                pool = writer(layer, layer_kv, pool)
            if self.w_device is not None:
                ffn_in = transfer(ffn_in, self.w_device)     # A-to-F
            if captured is not None:
                captured.append(self._route(
                    arena.arena, arena.slot_table(name), ffn_in, layer))
            ffn_out = self._ffn(arena.arena, arena.slot_table(name),
                                ffn_in, layer)
            if self.kv_device is not None:
                ffn_out = transfer(ffn_out, self.kv_device)  # F-to-A
            x = self._combine(x, ffn_out)
        if captured is not None:
            # [L][B=1,S,k] -> [S, L, k] (the prefix tree's per-token axis);
            # ONE device_get at the end — a per-layer np.asarray would
            # sync the dispatch pipeline once per layer
            self.captured_routes = np.stack(
                [c[0] for c in jax.device_get(captured)], axis=1)
        return self._logits(p_kv, x, logit_index(true_len)), pool

    def suffix(self, tokens, true_suffix_len, fork, kv_extent, prefix_rows,
               pool, writer=None, slot_offsets=None, capacity: int = 0,
               capture_routes: bool = False) -> Tuple[jax.Array, jax.Array]:
        """Prefill ONLY the uncached suffix of a prompt (DESIGN.md §11).

        tokens: [1, S_suf] suffix ids zero-padded to the suffix bucket;
        ``true_suffix_len`` the unpadded suffix length (logits row);
        ``fork`` the cached-prefix length; ``kv_extent`` the PRODUCING
        pass's prefill bucket — the static KV reduction extent;
        ``prefix_rows``: the ``[n_kv_layers, fork, *kv_shape]`` device
        stack from ``KVVirtualizer.gather_prompt_rows`` (per-layer rows
        are sliced inside the jitted attention stage);
        ``writer(layer, layer_kv, pool)`` scatters suffix KV starting at
        token offset ``fork``; ``slot_offsets`` ([L, E] int32) the
        prefix's per-layer routed-pair counts and ``capacity`` the
        producing pass's expert capacity (MoE only).  Suffix groups are
        B=1 singletons.  Returns (logits [1, V], pool).

        The host loop is kept dispatch-lean on purpose — warm-turn TTFT
        is this loop: prefix rows and slot offsets upload ONCE (layer
        extraction happens in-program) and captured routes come back in
        one ``device_get`` at the end.
        """
        assert self._suffix_attn is not None, "model has no suffix path"
        name = self.pooled.cfg.name
        arena = self.pooled.arena
        fns = self.pooled.stage_fns
        p_kv = self.pooled.kv_params
        arena.activate(name, upload=False)
        arena.prefetch_layer(name, 0)
        B, S_suf = tokens.shape
        positions = jnp.broadcast_to(
            jnp.arange(S_suf, dtype=jnp.int32)[None, :] + jnp.int32(fork),
            (B, S_suf))
        off_all = None if slot_offsets is None else \
            jnp.asarray(np.asarray(slot_offsets, np.int32))
        captured = [] if (capture_routes and self._route is not None) \
            else None
        self.captured_routes = None
        x = self._embed(p_kv, tokens)
        for layer in range(fns.n_layers):
            x, ffn_in, layer_kv = self._suffix_attn(
                p_kv, x, prefix_rows, positions, layer, int(kv_extent))
            arena.prefetch_layer(name, layer + 1)
            if writer is not None:
                pool = writer(layer, layer_kv, pool)
            if self.w_device is not None:
                ffn_in = transfer(ffn_in, self.w_device)
            if captured is not None:
                captured.append(self._route(
                    arena.arena, arena.slot_table(name), ffn_in, layer))
            ffn_out = self._suffix_ffn(arena.arena, arena.slot_table(name),
                                       ffn_in, layer, off_all, int(capacity))
            if self.kv_device is not None:
                ffn_out = transfer(ffn_out, self.kv_device)
            x = self._combine(x, ffn_out)
        if captured is not None:
            self.captured_routes = np.stack(
                [c[0] for c in jax.device_get(captured)], axis=1)
        return self._logits(p_kv, x, logit_index(true_suffix_len)), pool


class PagedFusedStep:
    """Device-resident control (lowering ON) over the shared paged pool.

    One dispatch per token per batch: ``lax.scan`` over layer indices with
    the pool threaded through the carry, consuming the same pooled param
    split (kv_params on the KV device, w_params on the weights device)
    as :class:`HostDrivenStep` — the persistent-kernel analogue.

    ``postprocess`` (e.g. greedy sampling) is compiled into the same
    program so the host sees exactly one dispatch per decode step.
    """

    def __init__(self, pooled: PooledModel,
                 postprocess: Optional[Callable] = None, device=None):
        self.pooled = pooled
        fns = pooled.stage_fns
        # the attention-side params are committed to ONE device (the KV
        # pool's, where the page pool lives) so the fused program has a
        # single placement; FFN weights arrive per call as the shared
        # arena buffer + slot table (the engine colocates the arena with
        # the KV pool when lowering is on) — lowering=ON trades placement
        # freedom for one dispatch
        if device is None:
            leaves = jax.tree.leaves(pooled.kv_params)
            device = (next(iter(leaves[0].devices())) if leaves
                      else jax.devices()[0])
        self._p_kv = jax.device_put(pooled.kv_params, device)

        def step(p_kv, arena, slot_table, tokens, pool, page_tables,
                 lengths):
            x = fns.embed(p_kv, tokens)

            def body(carry, layer):
                x, pool = carry
                x, ffn_in, pool = fns.attn_stage(
                    p_kv, x, pool, page_tables, lengths, layer)
                ffn_out = fns.ffn_stage(arena, slot_table, ffn_in, layer)
                x = fns.combine(x, ffn_out)
                return (x, pool), None

            (x, pool), _ = jax.lax.scan(
                body, (x, pool), jnp.arange(fns.n_layers))
            logits = fns.logits(p_kv, x)
            out = postprocess(logits) if postprocess is not None else logits
            return out, pool

        self._step = jax.jit(step, donate_argnums=_donate(4))

    def __call__(self, tokens, pool, page_tables, lengths
                 ) -> Tuple[jax.Array, jax.Array]:
        abuf, slot_table = self.pooled.arena.acquire(self.pooled.cfg.name)
        return self._step(self._p_kv, abuf, slot_table,
                          tokens, pool, page_tables, lengths)


class MultiStepFusedStep:
    """Persistent multi-step decode: K tokens per host dispatch.

    An outer ``lax.scan`` over :class:`PagedFusedStep`'s body carrying
    ``(tokens, pool, lengths, done_mask)`` for K inner steps, with
    sampling (``runtime.sampler.sample_on_device``) compiled into the
    same program — logits NEVER leave the device; the host sees one
    dispatch returning ``[K, B]`` sampled token ids.

    Page tables are NOT in the carry: the virtualizer pre-reserves a
    block of pages covering all K tokens (``reserve_decode_block``)
    before dispatch, so the scan body indexes into the pre-extended
    table and no host table mutation happens mid-dispatch.  Per-row
    freezing (DESIGN.md §9):

    * ``done0 = steps_left <= 0`` freezes inactive batch-padding rows
      from step 0;
    * a row that samples its ``eos_id`` (or exhausts its per-row step
      budget) flips ``done`` — subsequent inner steps re-run its
      forward with frozen ``(token, length)`` but every state write is
      masked: emitted token is -1 (host trims by valid count), length
      and next-token freeze, and the spurious KV write beyond the
      frozen length lands either on a -1 table entry (dropped by the
      paged writer) or in a reserved page that attention never reads
      (reads stop at ``lengths``) and that ``commit_decode_block``
      returns to the free list.

    Valid tokens are therefore a strict prefix of each ``[K]`` row;
    the host commits ``(row >= 0).sum()`` tokens per request.  Greedy
    sampling never traces PRNG ops; with ``temperature > 0`` the inner
    step index is folded into ``key`` so a dispatch is replayable.
    """

    def __init__(self, pooled: PooledModel, k: int,
                 temperature: float = 0.0, top_k: int = 0, device=None):
        from repro.runtime.sampler import sample_on_device
        self.pooled = pooled
        self.k = int(k)
        assert self.k >= 1
        fns = pooled.stage_fns
        if device is None:
            leaves = jax.tree.leaves(pooled.kv_params)
            device = (next(iter(leaves[0].devices())) if leaves
                      else jax.devices()[0])
        self._p_kv = jax.device_put(pooled.kv_params, device)
        n_steps = self.k

        def step(p_kv, arena, slot_table, tokens, pool, page_tables,
                 lengths, steps_left, eos_ids, key):
            def inner(carry, t):
                toks, pool, lens, done = carry
                x = fns.embed(p_kv, toks)

                def body(c, layer):
                    x, pool = c
                    x, ffn_in, pool = fns.attn_stage(
                        p_kv, x, pool, page_tables, lens, layer)
                    ffn_out = fns.ffn_stage(arena, slot_table, ffn_in,
                                            layer)
                    x = fns.combine(x, ffn_out)
                    return (x, pool), None

                (x, pool), _ = jax.lax.scan(
                    body, (x, pool), jnp.arange(fns.n_layers))
                logits = fns.logits(p_kv, x)
                sampled = sample_on_device(
                    logits, key, t, temperature=temperature, top_k=top_k)
                out_tok = jnp.where(done, jnp.int32(-1), sampled)
                next_tok = jnp.where(done, toks, sampled)
                new_len = jnp.where(done, lens, lens + 1)
                hit_eos = (~done) & (eos_ids >= 0) & (sampled == eos_ids)
                new_done = done | hit_eos | (steps_left <= t + 1)
                return (next_tok, pool, new_len, new_done), out_tok

            done0 = steps_left <= 0
            (_, pool, _, _), out = jax.lax.scan(
                inner, (tokens, pool, lengths, done0),
                jnp.arange(n_steps))
            return out, pool

        self._step = jax.jit(step, donate_argnums=_donate(4))

    def __call__(self, tokens, pool, page_tables, lengths, steps_left,
                 eos_ids=None, key=None) -> Tuple[jax.Array, jax.Array]:
        """tokens [B]; pool; page_tables [L,B,P] PRE-EXTENDED to cover K
        tokens; lengths [B]; steps_left [B] int32 per-row token budget
        for this dispatch (0 freezes the row entirely); eos_ids [B]
        int32, -1 disables EOS for that row.  Returns
        (token ids [K,B] int32 with -1 past each row's valid prefix,
        updated pool)."""
        abuf, slot_table = self.pooled.arena.acquire(self.pooled.cfg.name)
        if eos_ids is None:
            eos_ids = jnp.full(tokens.shape, -1, jnp.int32)
        if key is None:
            key = jax.random.PRNGKey(0)   # greedy path never reads it
        return self._step(self._p_kv, abuf, slot_table, tokens, pool,
                          page_tables, lengths, steps_left, eos_ids, key)


def dispatch_count(n_layers: int, fused: bool,
                   decode_steps: int = 1) -> int:
    """Host dispatches to commit ``decode_steps`` decode tokens (the
    ablation's control metric).  Fused lowering commits the whole
    K-token block in ONE dispatch (``MultiStepFusedStep``); host-driven
    mode pays the full per-layer dispatch train per token."""
    if fused:
        return 1
    # embed + (attn + ffn + combine + 2 transfers) per layer + logits
    return (2 + n_layers * 5) * decode_steps
